//! **E5 — the headline speedup table.**
//!
//! "Who wins, by what factor, where's the crossover": fast (Algorithm 1)
//! vs naïve matvec across all four groups and a grid of (n, k, l), with
//! the paper-predicted asymptotic ratio `~n^l` (S_n worst case) /
//! `n^{l+1}` (O/Sp) alongside the measured one.

use equidiag::diagram::Diagram;
use equidiag::fastmult::{Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(150);
    let mut rng = Rng::new(5);
    println!("== E5: fast vs naive speedups across groups ==\n");
    let mut table = Table::new(vec![
        "group", "n", "k", "l", "diagram", "fast", "naive", "speedup", "~n^l",
    ]);

    let cases: Vec<(Group, usize, usize, usize)> = vec![
        (Group::Symmetric, 4, 2, 2),
        (Group::Symmetric, 6, 2, 2),
        (Group::Symmetric, 8, 2, 2),
        (Group::Symmetric, 4, 3, 3),
        (Group::Symmetric, 6, 3, 3),
        (Group::Symmetric, 4, 4, 2),
        (Group::Orthogonal, 4, 2, 2),
        (Group::Orthogonal, 8, 2, 2),
        (Group::Orthogonal, 4, 3, 3),
        (Group::Orthogonal, 6, 3, 3),
        (Group::Symplectic, 4, 2, 2),
        (Group::Symplectic, 8, 2, 2),
        (Group::Symplectic, 4, 3, 3),
        (Group::SpecialOrthogonal, 3, 3, 2),
        (Group::SpecialOrthogonal, 3, 4, 3),
    ];

    for (group, n, k, l) in cases {
        // A representative worst-ish diagram per group (with contraction
        // work so Step 1 actually runs).
        let d = match group {
            Group::Symmetric => Diagram::random_partition(l, k, &mut rng),
            Group::SpecialOrthogonal => match Diagram::random_jellyfish(l, k, n, &mut rng) {
                Ok(d) => d,
                Err(_) => continue,
            },
            _ => match Diagram::random_brauer(l, k, &mut rng) {
                Ok(d) => d,
                Err(_) => continue,
            },
        };
        let plan = MultPlan::new(group, &d, n).unwrap();
        let v = Tensor::random(n, k, &mut rng);
        let fast = bench_median(budget, || {
            let _ = plan.apply(&v).unwrap();
        });
        let naive = bench_median(budget, || {
            let _ = naive_apply(group, &d, &v).unwrap();
        });
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("{k}"),
            format!("{l}"),
            format!("{d}"),
            fast.pretty(),
            naive.pretty(),
            format!("{:.0}x", naive.median_s / fast.median_s),
            format!("{}", (n as u64).pow(l as u32)),
        ]);
    }
    table.print();
    println!(
        "\nthe speedup should grow with n and l — the paper's exponential gap\n\
         O(n^(l+k)) -> O(n^k) (S_n worst case) and better for the other groups."
    );
}
