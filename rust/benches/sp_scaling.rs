//! **E3 — §5.2.3 time complexity for Sp(n).**
//!
//! Claim: identical asymptotics to O(n) — `O(n^{k-1})` — because the
//! ε-weighted pair trace also touches only `n` non-zero form entries.
//! Even-n sweep at `(k, l) = (4, 4)`; the ε-signed top expansion is also
//! measured (it writes `n` signed positions per pair instead of `n` copies).

use equidiag::diagram::Diagram;
use equidiag::fastmult::{Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::tensor::Tensor;
use equidiag::util::timing::loglog_slope;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

const K: usize = 4;
const L: usize = 4;

fn contracting() -> Diagram {
    Diagram::from_blocks(
        L,
        K,
        vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
    )
    .unwrap()
}

fn cross_only() -> Diagram {
    Diagram::from_blocks(
        L,
        K,
        vec![vec![0, 4], vec![1, 5], vec![2, 6], vec![3, 7]],
    )
    .unwrap()
}

fn main() {
    let budget = Duration::from_millis(200);
    let ns: Vec<usize> = vec![2, 4, 6, 8, 10, 12, 14];
    let naive_cap = 6;

    println!("== E3: Sp(n) scaling, (k, l) = ({K}, {L}), n even ==\n");
    let mut rng = Rng::new(3);

    for (label, d, predicted_fast) in [
        ("contracting (b = 2, ε-traces + ε-copies)", contracting(), (K - 1) as f64),
        ("cross-only (d = 4, identity transfer)", cross_only(), 0.0),
    ] {
        let mut table = Table::new(vec!["n", "fast", "naive", "speedup"]);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        let (mut nxs, mut nys) = (Vec::new(), Vec::new());
        for &n in &ns {
            let plan = MultPlan::new(Group::Symplectic, &d, n).unwrap();
            let v = Tensor::random(n, K, &mut rng);
            let fast = bench_median(budget, || {
                let _ = plan.apply(&v).unwrap();
            });
            xs.push(n as f64);
            ys.push(fast.median_s);
            let cell = if n <= naive_cap {
                let nv = bench_median(budget, || {
                    let _ = naive_apply(Group::Symplectic, &d, &v).unwrap();
                });
                nxs.push(n as f64);
                nys.push(nv.median_s);
                (nv.pretty(), format!("{:.1}x", nv.median_s / fast.median_s))
            } else {
                ("-".into(), "-".into())
            };
            table.row(vec![format!("{n}"), fast.pretty(), cell.0, cell.1]);
        }
        let h = xs.len() / 2;
        let fast_slope = loglog_slope(&xs[h..], &ys[h..]);
        let nh = nxs.len() / 2;
        let naive_slope = loglog_slope(&nxs[nh..], &nys[nh..]);
        println!("{label}  [diagram {d}]");
        table.print();
        // Wall-clock includes the O(n^max(k,l)) memory traffic the paper's
        // model (Remark 37) counts as free.
        let wallclock_bound = predicted_fast.max(K.max(L) as f64);
        println!(
            "measured fast slope {fast_slope:.2} (paper arithmetic: <= {predicted_fast}, \
             + memory: <= {wallclock_bound}), naive slope {naive_slope:.2} (paper: {})\n",
            K + L
        );
    }
}
