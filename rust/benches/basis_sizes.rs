//! **E6 — spanning-set sizes (Theorems 5, 7, 9, 11).**
//!
//! Exact reproduction (no timing): enumerate the diagram families and
//! check the counts against the paper's closed forms —
//! `B(l+k, n) = Σ_{t≤n} S(l+k, t)` for S_n, `(l+k-1)!!` for O(n)/Sp(n)
//! (0 when l+k odd), and `C(l+k, n)·(l+k-n-1)!!` extra `H_α` elements for
//! SO(n).

use equidiag::diagram::{
    all_brauer_diagrams, all_jellyfish_diagrams, all_partition_diagrams, bell_bounded,
    double_factorial,
};
use equidiag::util::Table;

fn binomial(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
    }
    acc
}

fn main() {
    println!("== E6: spanning-set sizes vs closed forms ==\n");

    println!("S_n diagram basis |{{d_pi : <= n blocks}}| = B(l+k, n)   (Theorem 5)");
    let mut t = Table::new(vec!["l+k", "n", "enumerated", "B(l+k, n)", "match"]);
    for (l, k) in [(1usize, 1usize), (2, 1), (2, 2), (3, 2), (3, 3)] {
        for n in 1..=4usize {
            let count = all_partition_diagrams(l, k, Some(n)).len() as u128;
            let closed = bell_bounded(l + k, n);
            t.row(vec![
                format!("{}", l + k),
                format!("{n}"),
                format!("{count}"),
                format!("{closed}"),
                format!("{}", count == closed),
            ]);
            assert_eq!(count, closed);
        }
    }
    t.print();

    println!("\nBrauer spanning set |{{d_beta}}| = (l+k-1)!!   (Theorems 7, 9)");
    let mut t = Table::new(vec!["l", "k", "enumerated", "(l+k-1)!!", "match"]);
    for (l, k) in [
        (1usize, 1usize),
        (2, 2),
        (3, 1),
        (3, 3),
        (4, 2),
        (4, 4),
        (2, 1),
        (3, 2),
    ] {
        let count = all_brauer_diagrams(l, k).len() as u128;
        let closed = if (l + k) % 2 == 0 {
            double_factorial((l + k) as isize - 1)
        } else {
            0
        };
        t.row(vec![
            format!("{l}"),
            format!("{k}"),
            format!("{count}"),
            format!("{closed}"),
            format!("{}", count == closed),
        ]);
        assert_eq!(count, closed);
    }
    t.print();

    println!("\nSO(n) extra H_alpha elements = C(l+k, n) (l+k-n-1)!!   (Theorem 11)");
    let mut t = Table::new(vec!["l", "k", "n", "enumerated", "closed", "match"]);
    for (l, k, n) in [
        (2usize, 1usize, 3usize),
        (2, 3, 3),
        (3, 2, 3),
        (1, 4, 3),
        (2, 2, 2),
        (3, 1, 2),
        (2, 4, 4),
    ] {
        let count = all_jellyfish_diagrams(l, k, n).unwrap().len() as u128;
        let closed = binomial(l + k, n) * double_factorial((l + k - n) as isize - 1);
        t.row(vec![
            format!("{l}"),
            format!("{k}"),
            format!("{n}"),
            format!("{count}"),
            format!("{closed}"),
            format!("{}", count == closed),
        ]);
        assert_eq!(count, closed);
    }
    t.print();

    println!("\nall counts match the paper's closed forms.");
}
