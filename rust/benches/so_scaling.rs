//! **E4 — §5.2.4 time complexity for SO(n) free-vertex diagrams.**
//!
//! Claim (eq. 169): an `H_α` matvec costs
//! `O(n^{k-(n-s)} (n! + n^{s-1}))` vs `O(n^{l+k})` naïve. Two sweeps:
//!
//! 1. fixed n = 3, sweep k with all free vertices on the bottom (s = 0):
//!    predicted slope in the k-direction is `log n` per added pair;
//! 2. sweep s at fixed (n, k, l): the measured time is compared against the
//!    model flop count `step12_flops` (time/flop should be ~constant).

use equidiag::diagram::Diagram;
use equidiag::fastmult::{Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

/// Jellyfish diagram with all n free vertices at the bottom, `b` bottom
/// pairs and `t` top pairs: l = 2t, k = 2b + n.
fn bottom_free(n: usize, t: usize, b: usize) -> Diagram {
    let l = 2 * t;
    let k = 2 * b + n;
    let mut blocks: Vec<Vec<usize>> = Vec::new();
    for i in 0..t {
        blocks.push(vec![2 * i, 2 * i + 1]);
    }
    for i in 0..b {
        blocks.push(vec![l + 2 * i, l + 2 * i + 1]);
    }
    for i in 0..n {
        blocks.push(vec![l + 2 * b + i]);
    }
    Diagram::from_blocks(l, k, blocks).unwrap()
}

/// Jellyfish with `s` free vertices on top (rest on the bottom), one
/// bottom pair, no top pairs, d = 0: l = s, k = 2 + (n - s).
fn split_free(n: usize, s: usize) -> Diagram {
    let l = s;
    let k = 2 + (n - s);
    let mut blocks: Vec<Vec<usize>> = Vec::new();
    for i in 0..s {
        blocks.push(vec![i]);
    }
    blocks.push(vec![l, l + 1]);
    for i in 0..(n - s) {
        blocks.push(vec![l + 2 + i]);
    }
    Diagram::from_blocks(l, k, blocks).unwrap()
}

fn main() {
    let budget = Duration::from_millis(200);
    let mut rng = Rng::new(4);

    // Sweep 1: n = 3 fixed, grow k by adding bottom pairs.
    let n = 3usize;
    println!("== E4a: SO({n}) H_α, s = 0, growing k (bottom pairs) ==\n");
    let mut table = Table::new(vec!["k", "l", "fast", "naive", "speedup", "model flops"]);
    for b in 0..4usize {
        let d = bottom_free(n, 1, b);
        let (k, l) = (d.k, d.l);
        let plan = MultPlan::new(Group::SpecialOrthogonal, &d, n).unwrap();
        let v = Tensor::random(n, k, &mut rng);
        let fast = bench_median(budget, || {
            let _ = plan.apply(&v).unwrap();
        });
        let (ncell, scell) = if l + k <= 9 {
            let nv = bench_median(budget, || {
                let _ = naive_apply(Group::SpecialOrthogonal, &d, &v).unwrap();
            });
            (nv.pretty(), format!("{:.1}x", nv.median_s / fast.median_s))
        } else {
            ("-".into(), "-".into())
        };
        table.row(vec![
            format!("{k}"),
            format!("{l}"),
            fast.pretty(),
            ncell,
            scell,
            format!("{}", plan.flops()),
        ]);
    }
    table.print();

    // Sweep 2: move free vertices from bottom to top at fixed n.
    println!("\n== E4b: SO(n) H_α, sweeping s (free top vertices) ==\n");
    for n in [3usize, 4, 5] {
        let mut table = Table::new(vec![
            "n", "s", "k", "l", "fast", "model flops", "ns/flop",
        ]);
        for s in 0..=n {
            let d = split_free(n, s);
            let plan = MultPlan::new(Group::SpecialOrthogonal, &d, n).unwrap();
            let v = Tensor::random(n, d.k, &mut rng);
            let fast = bench_median(budget, || {
                let _ = plan.apply(&v).unwrap();
            });
            let flops = plan.flops().max(1);
            table.row(vec![
                format!("{n}"),
                format!("{s}"),
                format!("{}", d.k),
                format!("{}", d.l),
                fast.pretty(),
                format!("{flops}"),
                format!("{:.2}", fast.median_s * 1e9 / flops as f64),
            ]);
        }
        table.print();
        println!();
    }
    println!(
        "eq. (169) check: the ns/flop column should be roughly constant per n —\n\
         measured time tracks the model O(n^{{k-(n-s)}}(n! + n^{{s-1}}))."
    );
}
