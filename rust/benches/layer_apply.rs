//! **E7 — layer-level comparison + design ablations.**
//!
//! A full equivariant layer is `W v = Σ_d λ_d F(d) v`. Three ways to
//! compute it:
//!
//! 1. **fast, pre-factored plans** (this library's hot path),
//! 2. **fast, re-factoring each call** (ablation: how much does plan
//!    caching buy?),
//! 3. **materialised W matvec** (the `O(n^{2l} x n^{2k})`-memory baseline a
//!    practitioner would otherwise use).
//!
//! Sweep n at (k, l) = (2, 2) for S_n (15 diagrams) and O(n) (3 diagrams).

use equidiag::fastmult::{matrix_mult, Group};
use equidiag::layer::{EquivariantLinear, Init};
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(200);
    let mut rng = Rng::new(6);
    println!("== E7: equivariant layer apply, (k, l) = (2, 2) ==\n");

    for group in [Group::Symmetric, Group::Orthogonal] {
        println!("group {group}:");
        let mut table = Table::new(vec![
            "n",
            "terms",
            "fast (plans)",
            "fast (refactor)",
            "materialized W",
            "plan speedup",
            "vs W speedup",
        ]);
        for &n in &[4usize, 6, 8, 12, 16] {
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let diagrams: Vec<_> = layer.diagrams().cloned().collect();
            let coeffs = layer.coeffs.clone();
            let v = Tensor::random(n, 2, &mut rng);

            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let refactor = bench_median(budget, || {
                let mut out = Tensor::zeros(n, 2);
                for (d, &lam) in diagrams.iter().zip(&coeffs) {
                    let t = matrix_mult(group, d, &v).unwrap();
                    out.axpy(lam, &t);
                }
            });
            // Materialised baseline (skip at large n: n^4 x n^4 memory).
            let mat_cell = if n <= 8 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                format!("{n}"),
                format!("{}", diagrams.len()),
                fast.pretty(),
                refactor.pretty(),
                mat_cell.as_ref().map_or("-".into(), |m| m.pretty()),
                format!("{:.2}x", refactor.median_s / fast.median_s),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
        table.print();
        println!();
    }

    // Higher order: (k, l) = (3, 3) — the regime the paper targets, where
    // the materialised W is an n^3 × n^3 matrix (n^6 entries) and the
    // diagram path dominates.
    println!("higher order (k, l) = (3, 3):");
    let mut table = Table::new(vec![
        "group",
        "n",
        "terms",
        "fast (plans)",
        "materialized W",
        "W entries",
        "vs W speedup",
    ]);
    for (group, ns) in [
        (Group::Symmetric, vec![4usize, 6, 8]),
        (Group::Orthogonal, vec![4usize, 6, 8, 12]),
    ] {
        for &n in &ns {
            let layer =
                EquivariantLinear::new(group, n, 3, 3, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 3, &mut rng);
            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let entries = (n as u128).pow(6);
            let mat_cell = if entries <= 70_000 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                group.name().to_string(),
                format!("{n}"),
                format!("{}", layer.diagrams().count()),
                fast.pretty(),
                mat_cell.as_ref().map_or("- (memory)".into(), |m| m.pretty()),
                format!("{entries}"),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
    }
    table.print();
    println!(
        "\nablation notes: plan caching removes the per-call Factor cost;\n\
         the materialised-W baseline pays O(n^(l+k)) per matvec AND O(n^(l+k)) memory —\n\
         at (3,3) it is already out of the running beyond small n."
    );
}
