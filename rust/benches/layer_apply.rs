//! **E7 — layer-level comparison + design ablations.**
//!
//! A full equivariant layer is `W v = Σ_d λ_d F(d) v`. Ways to compute it:
//!
//! 1. **fused schedule** (this library's hot path): the whole diagram sum
//!    compiled into a prefix-sharing DAG, executed against a recycled
//!    scratch arena,
//! 2. **fast, per-term plans** (pre-fusion reference: one `MultPlan`
//!    application per spanning term),
//! 3. **fast, re-factoring each call** (ablation: how much does plan
//!    caching buy?),
//! 4. **materialised W matvec** (the `O(n^{2l} x n^{2k})`-memory baseline a
//!    practitioner would otherwise use).
//!
//! Emits `BENCH_fastmult.json` (fused vs per-term medians, arena allocation
//! counters, sharing ratios), `BENCH_planner.json` (the folded planner's
//! executed-node / scatter-pass counts vs the prefix-sharing path, cost
//! model estimates, fold ratios — with the per-config invariants asserted
//! before anything is timed), `BENCH_fusion.json` (strided fusion:
//! estimated + measured bytes moved by the fused gather-contract walk vs
//! the unfused materialized-permute walk, with the ≥ 30% byte-drop and
//! bitwise-equality invariants asserted), `BENCH_batch.json` (batch-axis
//! fused execution vs the item-parallel and per-term paths),
//! `BENCH_simd.json` (the same fused walk at `f64` vs `f32`, ~halved-bytes
//! invariant asserted) and `BENCH_tiling.json` (the cache-blocked streaming
//! walk: peak resident arena bytes tiled vs untiled over the feasible-`n`
//! sweep at `k = 4`, with the ≥ 4x peak drop on over-budget shapes and the
//! bitwise-identity invariants asserted) with stable schemas so the perf
//! trajectory is machine-readable. Set `BENCH_FAST=1` for the CI smoke
//! mode: smaller budgets, the JSON-emitting sections only.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::diagram::Diagram;
use equidiag::fastmult::{
    arena_peak_bytes, exec_stats, matrix_mult, reset_arena_peak, Group, LayerSchedule, MultPlan,
    ScratchArena, ScratchArenaOf,
};
use equidiag::layer::{spanning_plans, EquivariantLinear, Init};
use equidiag::tensor::{Scalar, Tensor, TensorOf};
use equidiag::util::{bench_median, max_threads, parallel_map, Rng, Table};
use std::sync::Arc;
use std::time::Duration;

fn fast_mode() -> bool {
    // Treat unset, empty and "0" as off so `BENCH_FAST=0` behaves as a
    // developer expects.
    !matches!(
        std::env::var("BENCH_FAST").as_deref(),
        Err(_) | Ok("") | Ok("0")
    )
}

struct FusedRow {
    group: &'static str,
    n: usize,
    k: usize,
    l: usize,
    terms: usize,
    per_term_us: f64,
    fused_us: f64,
    speedup: f64,
    sharing_ratio: f64,
    nodes: usize,
    chain_ops: usize,
}

/// Fused schedule vs the per-term reference path, plus the steady-state
/// arena allocation check. Returns the per-config rows and the arena
/// figures for the JSON.
fn fused_vs_per_term(budget: Duration, rng: &mut Rng) -> (Vec<FusedRow>, u64, u64, usize) {
    println!("fused schedule vs per-term plans:");
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "per-term",
        "fused",
        "speedup",
        "sharing",
    ]);
    let configs: &[(Group, usize, usize, usize)] = if fast_mode() {
        &[
            (Group::Symmetric, 5, 2, 2),
            (Group::Orthogonal, 6, 3, 3),
            (Group::Symplectic, 6, 2, 2),
        ]
    } else {
        &[
            (Group::Symmetric, 6, 2, 2),
            (Group::Symmetric, 5, 3, 3),
            (Group::Orthogonal, 8, 3, 3),
            (Group::Orthogonal, 12, 2, 2),
            (Group::Symplectic, 6, 2, 2),
            (Group::SpecialOrthogonal, 3, 3, 2),
        ]
    };
    let mut rows = Vec::new();
    // Steady-state allocation counting on a dedicated arena (first config):
    // warm one pass, then count fresh allocations over repeated passes.
    let mut steady_allocs = 0u64;
    let mut steady_reuses = 0u64;
    let mut high_water = 0usize;
    for (idx, &(group, n, k, l)) in configs.iter().enumerate() {
        let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng).unwrap();
        let v = Tensor::random(n, k, rng);
        // Sanity: the two paths agree (≤ 1e-12 — the folded class walk
        // reassociates the per-term additions) before we time them.
        let a = layer.forward(&v).unwrap();
        let b = layer.forward_per_term(&v).unwrap();
        assert!(
            a.allclose(&b, 1e-12),
            "fused and per-term disagree by {}",
            a.max_abs_diff(&b)
        );
        if idx == 0 {
            let mut arena = ScratchArena::new();
            let mut out = Tensor::zeros(n, l);
            layer
                .schedule()
                .execute(&v, &layer.coeffs, &mut out, &mut arena)
                .unwrap();
            let warm = arena.allocations();
            for _ in 0..10 {
                out.data.fill(0.0);
                layer
                    .schedule()
                    .execute(&v, &layer.coeffs, &mut out, &mut arena)
                    .unwrap();
            }
            steady_allocs = arena.allocations() - warm;
            steady_reuses = arena.reuses();
            high_water = arena.held_f64s();
        }
        let per_term = bench_median(budget, || {
            let _ = layer.forward_per_term(&v).unwrap();
        });
        let fused = bench_median(budget, || {
            let _ = layer.forward(&v).unwrap();
        });
        let stats = layer.schedule_stats();
        let speedup = per_term.median_s / fused.median_s;
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", layer.diagrams().count()),
            per_term.pretty(),
            fused.pretty(),
            format!("{speedup:.2}x"),
            format!("{:.0}%", stats.sharing_ratio() * 100.0),
        ]);
        rows.push(FusedRow {
            group: group.name(),
            n,
            k,
            l,
            terms: stats.terms,
            per_term_us: per_term.median_s * 1e6,
            fused_us: fused.median_s * 1e6,
            speedup,
            sharing_ratio: stats.sharing_ratio(),
            nodes: stats.nodes,
            chain_ops: stats.chain_ops,
        });
    }
    table.print();
    println!(
        "\nsteady-state arena: {steady_allocs} fresh allocations over 10 warmed passes \
         ({steady_reuses} reuses, high-water {high_water} f64s)"
    );
    (rows, steady_allocs, steady_reuses, high_water)
}

struct PlannerRow {
    group: &'static str,
    n: usize,
    k: usize,
    l: usize,
    terms: usize,
    prefix_nodes: usize,
    nodes: usize,
    classes: usize,
    /// Runtime counter delta of one execute — asserted equal to `classes`.
    measured_scatter_passes: u64,
    executed_ops_prefix: usize,
    executed_ops_folded: usize,
    estimated_flops: u128,
    estimated_bytes: u128,
    /// Σ `MultPlan::bytes_moved()` over the spanning terms — what the
    /// per-term reference path pays, for comparison with the folded
    /// `estimated_bytes`.
    per_term_estimated_bytes: u128,
    sharing_ratio: f64,
    fold_ratio: f64,
    per_term_us: f64,
    fused_us: f64,
    speedup: f64,
}

/// The perf-trajectory section: per k,l ≤ 4 config, the planner's
/// executed-node and scatter-pass counts against the prefix-sharing
/// (pre-folding) path, the cost model's flops/bytes estimate, and the
/// measured folded-vs-per-term speedup. Asserts the folding invariants —
/// classes strictly below terms, folded kernel invocations strictly below
/// the prefix path, and (single-threaded, so the process-wide counters are
/// exact) scatter passes per forward == classes, executed nodes per
/// forward == nodes. Emits `BENCH_planner.json`.
fn planner_section(budget: Duration, rng: &mut Rng) -> Vec<PlannerRow> {
    println!("\nfolded planner: executed ops and scatter passes vs the prefix path:");
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "classes",
        "nodes (prefix)",
        "exec ops (prefix)",
        "est flops",
        "speedup",
    ]);
    let configs: &[(Group, usize, usize, usize)] = if fast_mode() {
        &[
            (Group::Symmetric, 4, 2, 2),
            (Group::Symmetric, 3, 3, 2),
            (Group::Orthogonal, 5, 3, 3),
            (Group::Orthogonal, 4, 4, 2),
            (Group::Symplectic, 4, 2, 2),
            (Group::SpecialOrthogonal, 3, 2, 2),
        ]
    } else {
        &[
            (Group::Symmetric, 4, 2, 2),
            (Group::Symmetric, 3, 3, 2),
            (Group::Symmetric, 4, 3, 3),
            (Group::Orthogonal, 5, 3, 3),
            (Group::Orthogonal, 6, 2, 2),
            (Group::Orthogonal, 4, 4, 2),
            (Group::Orthogonal, 4, 4, 4),
            (Group::Symplectic, 4, 2, 2),
            (Group::Symplectic, 4, 3, 3),
            (Group::SpecialOrthogonal, 3, 2, 2),
        ]
    };
    let mut rows = Vec::new();
    for &(group, n, k, l) in configs {
        let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng).unwrap();
        let stats = layer.schedule_stats();
        // The acceptance invariants, per config.
        assert!(
            stats.classes < stats.terms,
            "{group} ({k},{l}): scatter passes must fold below the term count: {stats:?}"
        );
        assert!(
            stats.nodes <= stats.prefix_nodes,
            "{group} ({k},{l}): global CSE must not add nodes: {stats:?}"
        );
        assert!(
            stats.executed_ops() < stats.executed_ops_prefix(),
            "{group} ({k},{l}): folded kernel invocations must beat the prefix path: {stats:?}"
        );
        let v = Tensor::random(n, k, rng);
        // Runtime invariant, measured for EVERY config (single-threaded
        // here, so the process-wide counters are exact): one execute runs
        // exactly `classes` scatter passes and materialises exactly
        // `nodes` intermediates. The measured deltas — not the compile-time
        // numbers — are what the JSON reports as scatter_passes.
        let (measured_passes, measured_nodes) = {
            let mut arena = ScratchArena::new();
            let mut out = Tensor::zeros(n, l);
            let before = exec_stats();
            layer
                .schedule()
                .execute(&v, &layer.coeffs, &mut out, &mut arena)
                .unwrap();
            let after = exec_stats();
            (
                after.scatter_passes - before.scatter_passes,
                after.executed_nodes - before.executed_nodes,
            )
        };
        assert_eq!(
            measured_passes, stats.classes as u64,
            "{group} ({k},{l}): scatter passes per forward must equal the class count"
        );
        assert_eq!(
            measured_nodes, stats.nodes as u64,
            "{group} ({k},{l}): executed nodes per forward must equal the CSE node count"
        );
        // The per-term path's memory-traffic estimate (MultPlan's half of
        // the cost model), against the folded walk's estimated_bytes.
        let per_term_bytes: u128 = spanning_plans(group, n, k, l)
            .unwrap()
            .iter()
            .map(|p| p.bytes_moved())
            .fold(0u128, u128::saturating_add);
        let per_term = bench_median(budget, || {
            let _ = layer.forward_per_term(&v).unwrap();
        });
        let fused = bench_median(budget, || {
            let _ = layer.forward(&v).unwrap();
        });
        let speedup = per_term.median_s / fused.median_s;
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", stats.terms),
            format!("{}", stats.classes),
            format!("{} ({})", stats.nodes, stats.prefix_nodes),
            format!(
                "{} ({})",
                stats.executed_ops(),
                stats.executed_ops_prefix()
            ),
            format!("{}", stats.estimated_flops),
            format!("{speedup:.2}x"),
        ]);
        rows.push(PlannerRow {
            group: group.name(),
            n,
            k,
            l,
            terms: stats.terms,
            prefix_nodes: stats.prefix_nodes,
            nodes: stats.nodes,
            classes: stats.classes,
            measured_scatter_passes: measured_passes,
            executed_ops_prefix: stats.executed_ops_prefix(),
            executed_ops_folded: stats.executed_ops(),
            estimated_flops: stats.estimated_flops,
            estimated_bytes: stats.estimated_bytes,
            per_term_estimated_bytes: per_term_bytes,
            sharing_ratio: stats.sharing_ratio(),
            fold_ratio: stats.fold_ratio(),
            per_term_us: per_term.median_s * 1e6,
            fused_us: fused.median_s * 1e6,
            speedup,
        });
    }
    table.print();
    rows
}

fn write_planner_json(path: &str, rows: &[PlannerRow]) {
    let best = rows.iter().map(|r| r.speedup).fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"group\": \"{}\", \"n\": {}, \"k\": {}, \"l\": {}, \
                 \"terms\": {}, \"prefix_nodes\": {}, \"nodes\": {}, \
                 \"classes\": {}, \"scatter_passes\": {measured}, \
                 \"executed_ops_prefix\": {}, \"executed_ops_folded\": {}, \
                 \"estimated_flops\": {}, \"estimated_bytes\": {}, \
                 \"per_term_estimated_bytes\": {}, \
                 \"sharing_ratio\": {:.4}, \"fold_ratio\": {:.4}, \
                 \"per_term_us\": {:.3}, \"fused_us\": {:.3}, \
                 \"speedup\": {:.3}}}",
                r.group,
                r.n,
                r.k,
                r.l,
                r.terms,
                r.prefix_nodes,
                r.nodes,
                r.classes,
                r.executed_ops_prefix,
                r.executed_ops_folded,
                r.estimated_flops,
                r.estimated_bytes,
                r.per_term_estimated_bytes,
                r.sharing_ratio,
                r.fold_ratio,
                r.per_term_us,
                r.fused_us,
                r.speedup,
                measured = r.measured_scatter_passes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"planner\",\n  \"fast_mode\": {fast},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"best_speedup\": {best:.3}\n}}\n",
        fast = fast_mode(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

struct FusionRow {
    group: &'static str,
    n: usize,
    k: usize,
    l: usize,
    terms: usize,
    fused_nodes: usize,
    est_bytes_unfused: u128,
    est_bytes_fused: u128,
    est_drop: f64,
    measured_bytes_unfused: u64,
    measured_bytes_fused: u64,
    measured_drop: f64,
    unfused_us: f64,
    fused_us: f64,
    speedup: f64,
}

/// Strided fusion: the fused compile (permutes folded into gather-contract
/// kernels) against [`LayerSchedule::compile_unfused`] on configs whose
/// chains contain a non-identity permute feeding a contraction. Asserts,
/// per config: fusion fired, estimated flops unchanged, estimated *and*
/// measured bytes moved strictly below the unfused walk (≥ 30% lower —
/// these shapes are permute-dominated), and the two walks bitwise equal.
/// Measured deltas come from the process-wide `exec_stats().bytes_moved`
/// counter (single-threaded here, so exact). Emits `BENCH_fusion.json`.
fn fusion_section(budget: Duration, rng: &mut Rng) -> Vec<FusionRow> {
    println!("\nstrided fusion: gather-contract kernels vs materialized permutes:");
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "fused nodes",
        "est bytes (unfused)",
        "measured bytes (unfused)",
        "speedup",
    ]);
    let configs: &[(Group, usize, usize, usize)] = if fast_mode() {
        &[
            (Group::Symmetric, 5, 3, 2),
            (Group::Orthogonal, 5, 4, 2),
            (Group::Symplectic, 4, 4, 2),
        ]
    } else {
        &[
            (Group::Symmetric, 5, 3, 2),
            (Group::Symmetric, 3, 4, 2),
            (Group::Orthogonal, 5, 4, 2),
            (Group::Orthogonal, 5, 3, 1),
            (Group::Symplectic, 4, 4, 2),
            (Group::SpecialOrthogonal, 3, 3, 1),
        ]
    };
    let mut rows = Vec::new();
    for &(group, n, k, l) in configs {
        let plans = spanning_plans(group, n, k, l).unwrap();
        let fused = LayerSchedule::compile(group, n, k, l, &plans).unwrap();
        let unfused = LayerSchedule::compile_unfused(group, n, k, l, &plans).unwrap();
        let fs = fused.stats();
        let us = unfused.stats();
        assert!(
            fs.fused_nodes > 0,
            "{group} ({k},{l}): config must contain a non-identity permute feeding a \
             contraction: {fs:?}"
        );
        assert_eq!(
            fs.estimated_flops, us.estimated_flops,
            "{group} ({k},{l}): fusion must not change flops"
        );
        assert!(
            fs.estimated_bytes < us.estimated_bytes,
            "{group} ({k},{l}): fused bytes must be strictly below unfused"
        );
        let coeffs: Vec<f64> = (0..plans.len()).map(|_| rng.gaussian()).collect();
        let v = Tensor::random(n, k, rng);
        let mut arena = ScratchArena::new();
        // Bitwise equality of the two walks before timing anything.
        let mut a = Tensor::zeros(n, l);
        let mut b = Tensor::zeros(n, l);
        fused.execute(&v, &coeffs, &mut a, &mut arena).unwrap();
        unfused.execute(&v, &coeffs, &mut b, &mut arena).unwrap();
        assert!(
            a.allclose(&b, 0.0),
            "{group} ({k},{l}): fused walk diverges by {}",
            a.max_abs_diff(&b)
        );
        // Measured bytes of one execute each (warm arena, single-threaded
        // so the process-wide counter delta is exact).
        let measure = |s: &LayerSchedule, arena: &mut ScratchArena| -> u64 {
            let mut out = Tensor::zeros(n, l);
            let before = exec_stats().bytes_moved;
            s.execute(&v, &coeffs, &mut out, arena).unwrap();
            exec_stats().bytes_moved - before
        };
        let measured_fused = measure(&fused, &mut arena);
        let measured_unfused = measure(&unfused, &mut arena);
        assert!(
            measured_fused < measured_unfused,
            "{group} ({k},{l}): fused walk must measurably move fewer bytes \
             ({measured_fused} vs {measured_unfused})"
        );
        let est_drop = 1.0 - fs.estimated_bytes as f64 / us.estimated_bytes as f64;
        let measured_drop = 1.0 - measured_fused as f64 / measured_unfused as f64;
        assert!(
            est_drop >= 0.30 && measured_drop >= 0.30,
            "{group} ({k},{l}): bytes-moved drop below 30% (est {est_drop:.2}, \
             measured {measured_drop:.2})"
        );
        // Time the *warm* path both sides optimise for: one arena per
        // variant, warmed before the clock starts, reused every iteration
        // (a cold arena would pay identical allocation costs on both sides
        // and dilute the measured difference).
        let mut timing_out = Tensor::zeros(n, l);
        let mut unfused_arena = ScratchArena::new();
        unfused
            .execute(&v, &coeffs, &mut timing_out, &mut unfused_arena)
            .unwrap();
        let unfused_t = bench_median(budget, || {
            timing_out.data.fill(0.0);
            unfused
                .execute(&v, &coeffs, &mut timing_out, &mut unfused_arena)
                .unwrap();
        });
        let mut fused_arena = ScratchArena::new();
        fused
            .execute(&v, &coeffs, &mut timing_out, &mut fused_arena)
            .unwrap();
        let fused_t = bench_median(budget, || {
            timing_out.data.fill(0.0);
            fused
                .execute(&v, &coeffs, &mut timing_out, &mut fused_arena)
                .unwrap();
        });
        let speedup = unfused_t.median_s / fused_t.median_s;
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", fs.terms),
            format!("{}", fs.fused_nodes),
            format!("{} ({})", fs.estimated_bytes, us.estimated_bytes),
            format!("{measured_fused} ({measured_unfused})"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(FusionRow {
            group: group.name(),
            n,
            k,
            l,
            terms: fs.terms,
            fused_nodes: fs.fused_nodes,
            est_bytes_unfused: us.estimated_bytes,
            est_bytes_fused: fs.estimated_bytes,
            est_drop,
            measured_bytes_unfused: measured_unfused,
            measured_bytes_fused: measured_fused,
            measured_drop,
            unfused_us: unfused_t.median_s * 1e6,
            fused_us: fused_t.median_s * 1e6,
            speedup,
        });
    }
    table.print();
    rows
}

fn write_fusion_json(path: &str, rows: &[FusionRow]) {
    let best = rows.iter().map(|r| r.measured_drop).fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"group\": \"{}\", \"n\": {}, \"k\": {}, \"l\": {}, \
                 \"terms\": {}, \"fused_nodes\": {}, \
                 \"est_bytes_unfused\": {}, \"est_bytes_fused\": {}, \
                 \"est_drop\": {:.4}, \
                 \"measured_bytes_unfused\": {}, \"measured_bytes_fused\": {}, \
                 \"measured_drop\": {:.4}, \
                 \"unfused_us\": {:.3}, \"fused_us\": {:.3}, \"speedup\": {:.3}}}",
                r.group,
                r.n,
                r.k,
                r.l,
                r.terms,
                r.fused_nodes,
                r.est_bytes_unfused,
                r.est_bytes_fused,
                r.est_drop,
                r.measured_bytes_unfused,
                r.measured_bytes_fused,
                r.measured_drop,
                r.unfused_us,
                r.fused_us,
                r.speedup
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"strided_fusion\",\n  \"fast_mode\": {fast},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"best_bytes_drop\": {best:.4}\n}}\n",
        fast = fast_mode(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

struct BatchRow {
    group: &'static str,
    n: usize,
    k: usize,
    l: usize,
    terms: usize,
    batch: usize,
    per_term_us: f64,
    item_parallel_us: f64,
    fused_batch_us: f64,
    speedup_vs_item_parallel: f64,
    speedup_vs_per_term: f64,
}

/// Batch-axis fusion: one schedule walk per batch (`forward_batch`)
/// against (a) the PR-1-style item-parallel path — per-item fused
/// schedule, scoped threads across items — and (b) the sequential
/// per-term reference. Emits `BENCH_batch.json`.
fn fused_batch_section(budget: Duration, rng: &mut Rng) -> Vec<BatchRow> {
    let batch = if fast_mode() { 16usize } else { 64 };
    println!("\nfused-batch vs item-parallel vs per-term ({batch}-item batch):");
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "per-term",
        "item-parallel",
        "fused-batch",
        "vs item-par",
        "vs per-term",
    ]);
    let configs: &[(Group, usize, usize, usize)] = if fast_mode() {
        &[
            (Group::Symmetric, 5, 2, 2),
            (Group::Orthogonal, 6, 3, 3),
            (Group::Symplectic, 6, 2, 2),
        ]
    } else {
        &[
            (Group::Symmetric, 6, 2, 2),
            (Group::Symmetric, 5, 3, 3),
            (Group::Orthogonal, 8, 3, 3),
            (Group::Orthogonal, 12, 2, 2),
            (Group::Symplectic, 6, 2, 2),
            (Group::SpecialOrthogonal, 3, 3, 2),
        ]
    };
    let mut rows = Vec::new();
    for &(group, n, k, l) in configs {
        let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng).unwrap();
        let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, rng)).collect();
        // Sanity: fused-batch agrees with per-item forward before timing.
        let check = layer.forward_batch(&inputs).unwrap();
        for (v, b) in inputs.iter().zip(&check) {
            let want = layer.forward(v).unwrap();
            assert!(
                want.allclose(b, 1e-12),
                "fused batch diverges by {}",
                want.max_abs_diff(b)
            );
        }
        let per_term = bench_median(budget, || {
            for v in &inputs {
                let _ = layer.forward_per_term(v).unwrap();
            }
        });
        let item_parallel = bench_median(budget, || {
            let _ = parallel_map(&inputs, max_threads(), |v| layer.forward(v).unwrap());
        });
        let fused = bench_median(budget, || {
            let _ = layer.forward_batch(&inputs).unwrap();
        });
        let vs_item = item_parallel.median_s / fused.median_s;
        let vs_term = per_term.median_s / fused.median_s;
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", layer.diagrams().count()),
            per_term.pretty(),
            item_parallel.pretty(),
            fused.pretty(),
            format!("{vs_item:.2}x"),
            format!("{vs_term:.2}x"),
        ]);
        rows.push(BatchRow {
            group: group.name(),
            n,
            k,
            l,
            terms: layer.diagrams().count(),
            batch,
            per_term_us: per_term.median_s * 1e6,
            item_parallel_us: item_parallel.median_s * 1e6,
            fused_batch_us: fused.median_s * 1e6,
            speedup_vs_item_parallel: vs_item,
            speedup_vs_per_term: vs_term,
        });
    }
    table.print();
    rows
}

fn write_batch_json(path: &str, rows: &[BatchRow]) {
    let best = rows
        .iter()
        .map(|r| r.speedup_vs_item_parallel)
        .fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"group\": \"{}\", \"n\": {}, \"k\": {}, \"l\": {}, \
                 \"terms\": {}, \"batch\": {}, \"per_term_us\": {:.3}, \
                 \"item_parallel_us\": {:.3}, \"fused_batch_us\": {:.3}, \
                 \"speedup_vs_item_parallel\": {:.3}, \
                 \"speedup_vs_per_term\": {:.3}}}",
                r.group,
                r.n,
                r.k,
                r.l,
                r.terms,
                r.batch,
                r.per_term_us,
                r.item_parallel_us,
                r.fused_batch_us,
                r.speedup_vs_item_parallel,
                r.speedup_vs_per_term
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"batch_fused\",\n  \"fast_mode\": {fast},\n  \
         \"threads\": {threads},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"best_speedup_vs_item_parallel\": {best:.3}\n}}\n",
        fast = fast_mode(),
        threads = max_threads(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

struct SimdRow {
    group: &'static str,
    n: usize,
    k: usize,
    l: usize,
    terms: usize,
    f64_us: f64,
    f32_us: f64,
    measured_bytes_f64: u64,
    measured_bytes_f32: u64,
    bytes_ratio: f64,
    speedup: f64,
}

/// Scalar width: the same fused schedule walked at `f64` (the bitwise
/// reference width) and at `f32`. The kernels, DAG and kernel plans are
/// identical — only the element width changes — so the `f32` walk must
/// move ~half the measured bytes, and its output must track the `f64`
/// reference within the scaled tolerance; both are asserted before
/// anything is timed. Emits `BENCH_simd.json`.
fn simd_section(budget: Duration, rng: &mut Rng) -> Vec<SimdRow> {
    println!("\nscalar width: fused schedule at f64 vs f32:");
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "bytes f64",
        "bytes f32",
        "ratio",
        "f64",
        "f32",
        "speedup",
    ]);
    let configs: &[(Group, usize, usize, usize)] = if fast_mode() {
        &[(Group::Symmetric, 5, 3, 2), (Group::Orthogonal, 5, 4, 2)]
    } else {
        &[
            (Group::Symmetric, 5, 3, 2),
            (Group::Symmetric, 8, 2, 2),
            (Group::Orthogonal, 5, 4, 2),
            (Group::Symplectic, 4, 4, 2),
            (Group::SpecialOrthogonal, 3, 3, 2),
        ]
    };
    let mut rows = Vec::new();
    for &(group, n, k, l) in configs {
        let plans = spanning_plans(group, n, k, l).unwrap();
        let schedule = LayerSchedule::compile(group, n, k, l, &plans).unwrap();
        let terms = schedule.stats().terms;
        let coeffs: Vec<f64> = (0..plans.len()).map(|_| rng.gaussian()).collect();
        let v = Tensor::random(n, k, rng);
        let v32 = v.cast::<f32>();
        let mut arena = ScratchArena::new();
        let mut arena32 = ScratchArenaOf::<f32>::new();
        let mut out = Tensor::zeros(n, l);
        let mut out32 = TensorOf::<f32>::zeros(n, l);
        // Accuracy invariant before timing: the f32 walk tracks the f64
        // reference within the scaled tolerance.
        schedule.execute(&v, &coeffs, &mut out, &mut arena).unwrap();
        schedule
            .execute(&v32, &coeffs, &mut out32, &mut arena32)
            .unwrap();
        let scale = out.data.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
        assert!(
            out32
                .cast::<f64>()
                .allclose(&out, 64.0 * <f32 as Scalar>::TOLERANCE * scale),
            "{group} ({k},{l}): f32 walk diverges by {}",
            out32.cast::<f64>().max_abs_diff(&out)
        );
        // Measured bytes of one warm execute per width (single-threaded,
        // so the process-wide counter delta is exact).
        let measured_bytes_f64 = {
            let before = exec_stats().bytes_moved;
            out.data.fill(0.0);
            schedule.execute(&v, &coeffs, &mut out, &mut arena).unwrap();
            exec_stats().bytes_moved - before
        };
        let measured_bytes_f32 = {
            let before = exec_stats().bytes_moved;
            out32.data.fill(0.0);
            schedule
                .execute(&v32, &coeffs, &mut out32, &mut arena32)
                .unwrap();
            exec_stats().bytes_moved - before
        };
        let bytes_ratio = measured_bytes_f32 as f64 / measured_bytes_f64 as f64;
        assert!(
            bytes_ratio <= 0.55,
            "{group} ({k},{l}): f32 must move ~half the measured bytes \
             ({measured_bytes_f32} vs {measured_bytes_f64}, ratio {bytes_ratio:.3})"
        );
        let f64_t = bench_median(budget, || {
            out.data.fill(0.0);
            schedule.execute(&v, &coeffs, &mut out, &mut arena).unwrap();
        });
        let f32_t = bench_median(budget, || {
            out32.data.fill(0.0);
            schedule
                .execute(&v32, &coeffs, &mut out32, &mut arena32)
                .unwrap();
        });
        let speedup = f64_t.median_s / f32_t.median_s;
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{terms}"),
            format!("{measured_bytes_f64}"),
            format!("{measured_bytes_f32}"),
            format!("{bytes_ratio:.3}"),
            f64_t.pretty(),
            f32_t.pretty(),
            format!("{speedup:.2}x"),
        ]);
        rows.push(SimdRow {
            group: group.name(),
            n,
            k,
            l,
            terms,
            f64_us: f64_t.median_s * 1e6,
            f32_us: f32_t.median_s * 1e6,
            measured_bytes_f64,
            measured_bytes_f32,
            bytes_ratio,
            speedup,
        });
    }
    table.print();
    rows
}

fn write_simd_json(path: &str, rows: &[SimdRow]) {
    let worst_ratio = rows.iter().map(|r| r.bytes_ratio).fold(f64::MIN, f64::max);
    let best = rows.iter().map(|r| r.speedup).fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"group\": \"{}\", \"n\": {}, \"k\": {}, \"l\": {}, \
                 \"terms\": {}, \"f64_us\": {:.3}, \"f32_us\": {:.3}, \
                 \"measured_bytes_f64\": {}, \"measured_bytes_f32\": {}, \
                 \"bytes_ratio\": {:.4}, \"speedup\": {:.3}}}",
                r.group,
                r.n,
                r.k,
                r.l,
                r.terms,
                r.f64_us,
                r.f32_us,
                r.measured_bytes_f64,
                r.measured_bytes_f32,
                r.bytes_ratio,
                r.speedup
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"scalar_simd\",\n  \"fast_mode\": {fast},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"worst_bytes_ratio\": {worst_ratio:.4},\n  \
         \"best_speedup\": {best:.3}\n}}\n",
        fast = fast_mode(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

struct TilingRow {
    n: usize,
    k: usize,
    l: usize,
    budget_bytes: usize,
    over_budget: bool,
    chains: usize,
    plan_peak_bytes: u128,
    untiled_peak_bytes: u64,
    tiled_peak_bytes: u64,
    peak_drop: f64,
    bitwise_equal: bool,
    untiled_us: f64,
    tiled_us: f64,
    speedup: f64,
}

/// Tiled streaming: a chain-heavy `k = 4` schedule — three singleton
/// bottom blocks lower to a contraction chain `n^4 → n^3 → n^2 → n` —
/// walked untiled vs streamed under a 512-byte tile budget across the
/// feasible-`n` sweep, plus one under-budget control row where the
/// degenerate skip must leave the walk untouched. Peak resident arena
/// bytes are bracketed per walk with `reset_arena_peak()`; on every
/// over-budget shape the streamed peak must sit at least 4x below the
/// untiled peak, bitwise-identically — all asserted before anything is
/// timed. Emits `BENCH_tiling.json`.
fn tiling_section(budget: Duration, rng: &mut Rng) -> Vec<TilingRow> {
    const TILE_BUDGET: usize = 512;
    println!("\ntiled streaming: peak arena bytes, cache-blocked chain walk vs untiled:");
    let mut table = Table::new(vec![
        "n",
        "(k,l)",
        "budget",
        "chains",
        "peak untiled",
        "peak tiled",
        "drop",
        "untiled",
        "tiled",
        "speedup",
    ]);
    let (k, l) = (4usize, 1usize);
    let ns: &[usize] = if fast_mode() { &[8, 10] } else { &[8, 10, 12, 14] };
    // The sweep under the tiny budget, then the under-budget control.
    let mut configs: Vec<(usize, usize)> = ns.iter().map(|&n| (n, TILE_BUDGET)).collect();
    configs.push((8, 1 << 20));
    let mut rows = Vec::new();
    for &(n, tile_budget) in &configs {
        let d = Diagram::from_blocks(1, k, vec![vec![0, 1], vec![2], vec![3], vec![4]]).unwrap();
        let plan = Arc::new(MultPlan::new(Group::Symmetric, &d, n).unwrap());
        let plan_peak = plan.peak_intermediate_bytes();
        let sched =
            LayerSchedule::compile_budgeted(Group::Symmetric, n, k, l, &[plan], tile_budget)
                .unwrap();
        let chains = sched.stats().tiled_chains;
        assert!(chains > 0, "n = {n}: the contraction chain must plan a tiled walk");
        // The largest interior (n^3 f64s) overflows the tiny budget; the
        // control row fits outright and must skip streaming entirely.
        let over_budget = n.pow(3) * 8 > tile_budget;
        let coeffs = vec![rng.gaussian()];
        let v = Tensor::random(n, k, rng);
        let mut untiled_arena = ScratchArena::new();
        let mut tiled_arena = ScratchArena::new();
        let mut a = Tensor::zeros(n, l);
        let mut b = Tensor::zeros(n, l);
        // Bitwise identity (not just allclose), and proof that streaming
        // engages exactly on the over-budget shapes.
        let streamed_before = exec_stats().tiled_chains;
        sched.execute(&v, &coeffs, &mut a, &mut untiled_arena).unwrap();
        sched
            .execute_tiled(&v, &coeffs, &mut b, &mut tiled_arena)
            .unwrap();
        assert_eq!(a.data, b.data, "n = {n}: tiled walk must diverge nowhere");
        let streamed = exec_stats().tiled_chains - streamed_before;
        assert_eq!(
            streamed > 0, over_budget,
            "n = {n}: streaming must engage exactly on over-budget shapes (streamed {streamed})"
        );
        // Peak resident arena bytes of one warm walk each. The arenas are
        // warm from the check above and every buffer is returned between
        // walks, so each bracket starts from zero checked-out bytes.
        reset_arena_peak();
        sched.execute(&v, &coeffs, &mut a, &mut untiled_arena).unwrap();
        let untiled_peak = arena_peak_bytes() as u64;
        reset_arena_peak();
        sched
            .execute_tiled(&v, &coeffs, &mut b, &mut tiled_arena)
            .unwrap();
        let tiled_peak = arena_peak_bytes() as u64;
        if over_budget {
            assert!(
                tiled_peak * 4 <= untiled_peak,
                "n = {n}: streamed peak must sit at least 4x below untiled \
                 ({tiled_peak} vs {untiled_peak} bytes)"
            );
        } else {
            assert_eq!(
                tiled_peak, untiled_peak,
                "n = {n}: under budget the degenerate skip must leave the walk untouched"
            );
        }
        let peak_drop = untiled_peak as f64 / tiled_peak as f64;
        let untiled_t = bench_median(budget, || {
            a.data.fill(0.0);
            sched.execute(&v, &coeffs, &mut a, &mut untiled_arena).unwrap();
        });
        let tiled_t = bench_median(budget, || {
            b.data.fill(0.0);
            sched
                .execute_tiled(&v, &coeffs, &mut b, &mut tiled_arena)
                .unwrap();
        });
        let speedup = untiled_t.median_s / tiled_t.median_s;
        table.row(vec![
            format!("{n}"),
            format!("({k},{l})"),
            format!("{tile_budget}"),
            format!("{chains}"),
            format!("{untiled_peak}"),
            format!("{tiled_peak}"),
            format!("{peak_drop:.1}x"),
            untiled_t.pretty(),
            tiled_t.pretty(),
            format!("{speedup:.2}x"),
        ]);
        rows.push(TilingRow {
            n,
            k,
            l,
            budget_bytes: tile_budget,
            over_budget,
            chains,
            plan_peak_bytes: plan_peak,
            untiled_peak_bytes: untiled_peak,
            tiled_peak_bytes: tiled_peak,
            peak_drop,
            bitwise_equal: true,
            untiled_us: untiled_t.median_s * 1e6,
            tiled_us: tiled_t.median_s * 1e6,
            speedup,
        });
    }
    table.print();
    rows
}

fn write_tiling_json(path: &str, rows: &[TilingRow]) {
    let best = rows
        .iter()
        .filter(|r| r.over_budget)
        .map(|r| r.peak_drop)
        .fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"n\": {}, \"k\": {}, \"l\": {}, \"budget_bytes\": {}, \
                 \"over_budget\": {}, \"chains\": {}, \"plan_peak_bytes\": {}, \
                 \"untiled_peak_bytes\": {}, \"tiled_peak_bytes\": {}, \
                 \"peak_drop\": {:.3}, \"bitwise_equal\": {}, \
                 \"untiled_us\": {:.3}, \"tiled_us\": {:.3}, \"speedup\": {:.3}}}",
                r.n,
                r.k,
                r.l,
                r.budget_bytes,
                r.over_budget,
                r.chains,
                r.plan_peak_bytes,
                r.untiled_peak_bytes,
                r.tiled_peak_bytes,
                r.peak_drop,
                r.bitwise_equal,
                r.untiled_us,
                r.tiled_us,
                r.speedup
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"tiled_streaming\",\n  \"fast_mode\": {fast},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"best_peak_drop\": {best:.3}\n}}\n",
        fast = fast_mode(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn write_json(
    path: &str,
    rows: &[FusedRow],
    steady_allocs: u64,
    steady_reuses: u64,
    high_water: usize,
) {
    let best = rows.iter().map(|r| r.speedup).fold(f64::MIN, f64::max);
    let configs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"group\": \"{}\", \"n\": {}, \"k\": {}, \"l\": {}, \
                 \"terms\": {}, \"per_term_us\": {:.3}, \"fused_us\": {:.3}, \
                 \"speedup\": {:.3}, \"sharing_ratio\": {:.4}, \"nodes\": {}, \
                 \"chain_ops\": {}}}",
                r.group,
                r.n,
                r.k,
                r.l,
                r.terms,
                r.per_term_us,
                r.fused_us,
                r.speedup,
                r.sharing_ratio,
                r.nodes,
                r.chain_ops
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fastmult_schedule\",\n  \"fast_mode\": {fast},\n  \
         \"configs\": [\n{configs}\n  ],\n  \
         \"best_speedup\": {best:.3},\n  \
         \"arena\": {{\n    \"steady_state_allocations\": {steady_allocs},\n    \
         \"reuses\": {steady_reuses},\n    \
         \"high_water_f64s\": {high_water}\n  }}\n}}\n",
        fast = fast_mode(),
        configs = configs.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let budget = if fast_mode() {
        Duration::from_millis(25)
    } else {
        Duration::from_millis(200)
    };
    let mut rng = Rng::new(6);
    println!("== E7: equivariant layer apply ==\n");

    let (rows, steady_allocs, steady_reuses, high_water) = fused_vs_per_term(budget, &mut rng);
    write_json(
        "BENCH_fastmult.json",
        &rows,
        steady_allocs,
        steady_reuses,
        high_water,
    );

    let planner_rows = planner_section(budget, &mut rng);
    write_planner_json("BENCH_planner.json", &planner_rows);

    let fusion_rows = fusion_section(budget, &mut rng);
    write_fusion_json("BENCH_fusion.json", &fusion_rows);

    let batch_rows = fused_batch_section(budget, &mut rng);
    write_batch_json("BENCH_batch.json", &batch_rows);

    let simd_rows = simd_section(budget, &mut rng);
    write_simd_json("BENCH_simd.json", &simd_rows);

    let tiling_rows = tiling_section(budget, &mut rng);
    write_tiling_json("BENCH_tiling.json", &tiling_rows);

    if fast_mode() {
        println!("\n(BENCH_FAST set — skipping the refactor/materialised-W ablations)");
        return;
    }

    println!("\n(k, l) = (2, 2) ablations:\n");
    for group in [Group::Symmetric, Group::Orthogonal] {
        println!("group {group}:");
        let mut table = Table::new(vec![
            "n",
            "terms",
            "fast (plans)",
            "fast (refactor)",
            "materialized W",
            "plan speedup",
            "vs W speedup",
        ]);
        for &n in &[4usize, 6, 8, 12, 16] {
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let diagrams: Vec<_> = layer.diagrams().cloned().collect();
            let coeffs = layer.coeffs.clone();
            let v = Tensor::random(n, 2, &mut rng);

            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let refactor = bench_median(budget, || {
                let mut out = Tensor::zeros(n, 2);
                for (d, &lam) in diagrams.iter().zip(&coeffs) {
                    let t = matrix_mult(group, d, &v).unwrap();
                    out.axpy(lam, &t);
                }
            });
            // Materialised baseline (skip at large n: n^4 x n^4 memory).
            let mat_cell = if n <= 8 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                format!("{n}"),
                format!("{}", diagrams.len()),
                fast.pretty(),
                refactor.pretty(),
                mat_cell.as_ref().map_or("-".into(), |m| m.pretty()),
                format!("{:.2}x", refactor.median_s / fast.median_s),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
        table.print();
        println!();
    }

    // Higher order: (k, l) = (3, 3) — the regime the paper targets, where
    // the materialised W is an n^3 × n^3 matrix (n^6 entries) and the
    // diagram path dominates.
    println!("higher order (k, l) = (3, 3):");
    let mut table = Table::new(vec![
        "group",
        "n",
        "terms",
        "fast (plans)",
        "materialized W",
        "W entries",
        "vs W speedup",
    ]);
    for (group, ns) in [
        (Group::Symmetric, vec![4usize, 6, 8]),
        (Group::Orthogonal, vec![4usize, 6, 8, 12]),
    ] {
        for &n in &ns {
            let layer =
                EquivariantLinear::new(group, n, 3, 3, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 3, &mut rng);
            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let entries = (n as u128).pow(6);
            let mat_cell = if entries <= 70_000 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                group.name().to_string(),
                format!("{n}"),
                format!("{}", layer.diagrams().count()),
                fast.pretty(),
                mat_cell.as_ref().map_or("- (memory)".into(), |m| m.pretty()),
                format!("{entries}"),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
    }
    table.print();

    // Batched vs sequential: the batched parallel engine (scoped worker
    // threads across items + the fused schedule per item + batch-shared
    // bias) against 64 plain `forward` calls.
    println!("\nbatched forward, 64-item batch vs 64 sequential forward calls:");
    let batch = 64usize;
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "sequential x64",
        "forward_batch",
        "speedup",
    ]);
    let mut batched_speedups: Vec<f64> = Vec::new();
    for (group, n, k, l) in [
        (Group::Symmetric, 6usize, 3usize, 3usize),
        (Group::Symmetric, 8, 3, 3),
        (Group::Orthogonal, 8, 3, 3),
        (Group::Orthogonal, 12, 2, 2),
    ] {
        let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), &mut rng).unwrap();
        let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, &mut rng)).collect();
        // Sanity: the two paths agree before we time them.
        let check = layer.forward_batch(&inputs).unwrap();
        for (v, b) in inputs.iter().zip(&check) {
            assert!(layer.forward(v).unwrap().allclose(b, 1e-9));
        }
        let seq = bench_median(budget, || {
            for v in &inputs {
                let _ = layer.forward(v).unwrap();
            }
        });
        let bat = bench_median(budget, || {
            let _ = layer.forward_batch(&inputs).unwrap();
        });
        let speedup = seq.median_s / bat.median_s;
        batched_speedups.push(speedup);
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", layer.diagrams().count()),
            seq.pretty(),
            bat.pretty(),
            format!("{speedup:.2}x"),
        ]);
    }
    table.print();
    let best = batched_speedups.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\nbatched-vs-sequential speedup: best {best:.2}x over {} shapes \
         (threads available: {})",
        batched_speedups.len(),
        equidiag::util::max_threads()
    );

    println!(
        "\nablation notes: the fused schedule removes the per-term permute and\n\
         shared contraction prefixes; plan caching removes the per-call Factor\n\
         cost; the materialised-W baseline pays O(n^(l+k)) per matvec AND\n\
         O(n^(l+k)) memory — at (3,3) it is out of the running beyond small n."
    );
}
