//! **E7 — layer-level comparison + design ablations.**
//!
//! A full equivariant layer is `W v = Σ_d λ_d F(d) v`. Three ways to
//! compute it:
//!
//! 1. **fast, pre-factored plans** (this library's hot path),
//! 2. **fast, re-factoring each call** (ablation: how much does plan
//!    caching buy?),
//! 3. **materialised W matvec** (the `O(n^{2l} x n^{2k})`-memory baseline a
//!    practitioner would otherwise use).
//!
//! Sweep n at (k, l) = (2, 2) for S_n (15 diagrams) and O(n) (3 diagrams).

use equidiag::fastmult::{matrix_mult, Group};
use equidiag::layer::{EquivariantLinear, Init};
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(200);
    let mut rng = Rng::new(6);
    println!("== E7: equivariant layer apply, (k, l) = (2, 2) ==\n");

    for group in [Group::Symmetric, Group::Orthogonal] {
        println!("group {group}:");
        let mut table = Table::new(vec![
            "n",
            "terms",
            "fast (plans)",
            "fast (refactor)",
            "materialized W",
            "plan speedup",
            "vs W speedup",
        ]);
        for &n in &[4usize, 6, 8, 12, 16] {
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let diagrams: Vec<_> = layer.diagrams().cloned().collect();
            let coeffs = layer.coeffs.clone();
            let v = Tensor::random(n, 2, &mut rng);

            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let refactor = bench_median(budget, || {
                let mut out = Tensor::zeros(n, 2);
                for (d, &lam) in diagrams.iter().zip(&coeffs) {
                    let t = matrix_mult(group, d, &v).unwrap();
                    out.axpy(lam, &t);
                }
            });
            // Materialised baseline (skip at large n: n^4 x n^4 memory).
            let mat_cell = if n <= 8 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                format!("{n}"),
                format!("{}", diagrams.len()),
                fast.pretty(),
                refactor.pretty(),
                mat_cell.as_ref().map_or("-".into(), |m| m.pretty()),
                format!("{:.2}x", refactor.median_s / fast.median_s),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
        table.print();
        println!();
    }

    // Higher order: (k, l) = (3, 3) — the regime the paper targets, where
    // the materialised W is an n^3 × n^3 matrix (n^6 entries) and the
    // diagram path dominates.
    println!("higher order (k, l) = (3, 3):");
    let mut table = Table::new(vec![
        "group",
        "n",
        "terms",
        "fast (plans)",
        "materialized W",
        "W entries",
        "vs W speedup",
    ]);
    for (group, ns) in [
        (Group::Symmetric, vec![4usize, 6, 8]),
        (Group::Orthogonal, vec![4usize, 6, 8, 12]),
    ] {
        for &n in &ns {
            let layer =
                EquivariantLinear::new(group, n, 3, 3, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 3, &mut rng);
            let fast = bench_median(budget, || {
                let _ = layer.forward(&v).unwrap();
            });
            let entries = (n as u128).pow(6);
            let mat_cell = if entries <= 70_000 {
                let w = layer.materialize_weight().unwrap();
                let bias = layer.materialize_bias().unwrap();
                let m = bench_median(budget, || {
                    let mut out = w.matvec(&v.data).unwrap();
                    for (o, b) in out.iter_mut().zip(&bias.data) {
                        *o += b;
                    }
                });
                Some(m)
            } else {
                None
            };
            table.row(vec![
                group.name().to_string(),
                format!("{n}"),
                format!("{}", layer.diagrams().count()),
                fast.pretty(),
                mat_cell.as_ref().map_or("- (memory)".into(), |m| m.pretty()),
                format!("{entries}"),
                mat_cell
                    .as_ref()
                    .map_or("-".into(), |m| format!("{:.1}x", m.median_s / fast.median_s)),
            ]);
        }
    }
    table.print();

    // Batched vs sequential: the batched parallel engine (scoped worker
    // threads across items + one input permute per distinct σ_k per item +
    // batch-shared bias) against 64 plain `forward` calls.
    println!("\nbatched forward, 64-item batch vs 64 sequential forward calls:");
    let batch = 64usize;
    let mut table = Table::new(vec![
        "group",
        "n",
        "(k,l)",
        "terms",
        "sequential x64",
        "forward_batch",
        "speedup",
    ]);
    let mut batched_speedups: Vec<f64> = Vec::new();
    for (group, n, k, l) in [
        (Group::Symmetric, 6usize, 3usize, 3usize),
        (Group::Symmetric, 8, 3, 3),
        (Group::Orthogonal, 8, 3, 3),
        (Group::Orthogonal, 12, 2, 2),
    ] {
        let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), &mut rng).unwrap();
        let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, &mut rng)).collect();
        // Sanity: the two paths agree before we time them.
        let check = layer.forward_batch(&inputs).unwrap();
        for (v, b) in inputs.iter().zip(&check) {
            assert!(layer.forward(v).unwrap().allclose(b, 1e-9));
        }
        let seq = bench_median(budget, || {
            for v in &inputs {
                let _ = layer.forward(v).unwrap();
            }
        });
        let bat = bench_median(budget, || {
            let _ = layer.forward_batch(&inputs).unwrap();
        });
        let speedup = seq.median_s / bat.median_s;
        batched_speedups.push(speedup);
        table.row(vec![
            group.name().to_string(),
            format!("{n}"),
            format!("({k},{l})"),
            format!("{}", layer.diagrams().count()),
            seq.pretty(),
            bat.pretty(),
            format!("{speedup:.2}x"),
        ]);
    }
    table.print();
    let best = batched_speedups.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\nbatched-vs-sequential speedup: best {best:.2}x over {} shapes \
         (threads available: {})",
        batched_speedups.len(),
        equidiag::util::max_threads()
    );

    println!(
        "\nablation notes: plan caching removes the per-call Factor cost;\n\
         the materialised-W baseline pays O(n^(l+k)) per matvec AND O(n^(l+k)) memory —\n\
         at (3,3) it is already out of the running beyond small n."
    );
}
