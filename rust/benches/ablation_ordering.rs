//! **E10 — design-choice ablations.**
//!
//! (a) Definition 31 orders bottom-row blocks by size, *largest at the far
//! right* (contracted first), and §5.2.1 justifies it via eqs. (115)/(116):
//! any other order costs more. We ablate: the same multi-block contraction
//! run in ascending vs descending order, against the eq.-(115) flop model.
//!
//! (b) The orbit basis (Maron et al.) vs the paper's diagram basis: an
//! orbit matvec via the Möbius expansion over fast diagram plans vs the
//! naïve orbit matvec — quantifying what the diagram framework buys the
//! standard parameterisation.

use equidiag::diagram::{Diagram, PlanarLayout};
use equidiag::fastmult::sn;
use equidiag::functor::orbit::{orbit_apply_naive, OrbitPlan};
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(200);
    let mut rng = Rng::new(9);

    // ---- (a) bottom-block ordering -------------------------------------
    println!("== E10a: Definition 31 block ordering (S_n Step 1) ==\n");
    // k = 6, two bottom blocks of sizes 1 and 5 (l = 0): contracting the
    // big block first leaves an O(n) tail; contracting the small block
    // first walks the full n^5 tensor twice.
    let mut table = Table::new(vec![
        "n",
        "paper order (asc, big first)",
        "reversed (desc)",
        "ratio",
        "model ratio",
    ]);
    for &n in &[4usize, 6, 8, 10] {
        let asc = PlanarLayout {
            l: 0,
            k: 6,
            top_blocks: vec![],
            cross_blocks: vec![],
            bottom_blocks: vec![1, 5],
            free_top: 0,
            free_bottom: 0,
        };
        let desc = PlanarLayout {
            bottom_blocks: vec![5, 1],
            ..asc.clone()
        };
        let v = Tensor::random(n, 6, &mut rng);
        let t_asc = bench_median(budget, || {
            let _ = sn::planar_mult(&asc, &v);
        });
        let t_desc = bench_median(budget, || {
            let _ = sn::planar_mult(&desc, &v);
        });
        let model_ratio =
            sn::step1_flops(&desc, n) as f64 / sn::step1_flops(&asc, n) as f64;
        table.row(vec![
            format!("{n}"),
            t_asc.pretty(),
            t_desc.pretty(),
            format!("{:.2}x", t_desc.median_s / t_asc.median_s),
            format!("{model_ratio:.2}x"),
        ]);
    }
    table.print();
    println!(
        "\nthe paper's ordering (eq. 115) is strictly cheaper; the measured ratio\n\
         tracks the flop-model ratio up to memory effects.\n"
    );

    // ---- (b) orbit basis on the fast path -------------------------------
    println!("== E10b: orbit basis (Maron et al.) via the diagram fast path ==\n");
    let mut table = Table::new(vec![
        "n",
        "orbit diagram terms",
        "fast (Mobius+plans)",
        "naive orbit",
        "speedup",
    ]);
    // The all-singletons (2,2) orbit element — the worst case (most
    // coarsenings: Bell(4) = 15 diagram terms).
    let d = Diagram::from_blocks(2, 2, vec![vec![0], vec![1], vec![2], vec![3]]).unwrap();
    for &n in &[4usize, 6, 8] {
        let plan = OrbitPlan::new(&d, n).unwrap();
        let v = Tensor::random(n, 2, &mut rng);
        let fast = bench_median(budget, || {
            let _ = plan.apply(&v).unwrap();
        });
        let naive = bench_median(budget, || {
            let _ = orbit_apply_naive(&d, &v);
        });
        table.row(vec![
            format!("{n}"),
            format!("{}", plan.num_terms()),
            fast.pretty(),
            naive.pretty(),
            format!("{:.1}x", naive.median_s / fast.median_s),
        ]);
    }
    table.print();
}
