//! **E9 — serving-path throughput/latency.**
//!
//! The coordinator under closed-loop load: sweep worker count and batching
//! window, report req/s and latency. The native diagram-net route carries
//! the load; the PJRT route is exercised separately if artifacts exist.
//!
//! Emits `BENCH_throughput.json` (requests/sec, plan-cache hit rate,
//! batched-vs-sequential speedup) so the perf trajectory is machine-
//! readable from PR 1 onward, and `BENCH_robustness.json` from the
//! fault-injection section: availability and p99 under seeded chaos
//! (panics/stalls/errors), shed rates under overload, and worker-restart
//! counts — with the exactly-one-terminal-outcome invariant asserted.
//!
//! The silent-failure section emits `BENCH_integrity.json`: shadow-
//! verification coverage and detection counts under seeded bit-flips (a
//! realistic sampled run plus a fully-verified run where every flip must
//! be caught), zero false positives on clean traffic, watchdog
//! time-to-recovery for a wedged slot, and a brownout engage/recover
//! cycle under a tiny arena budget.
//!
//! Set `BENCH_FAST=1` to shrink the sweep and request counts (CI smoke).

use equidiag::config::ServerConfig;
use equidiag::coordinator::{
    ChaosPlan, Coordinator, MetricsSnapshot, ModelKind, CHAOS_PANIC_PREFIX,
};
use equidiag::error::Error;
use equidiag::fastmult::{factor_runs, Group, PlanCache};
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::runtime::HloService;
use equidiag::tensor::Tensor;
use equidiag::util::executor::hw_threads;
use equidiag::util::{Rng, Table};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 8;

fn test_net() -> EquivariantNet {
    // Same seed every time: every run after the first hits the plan cache.
    let mut rng = Rng::new(42);
    EquivariantNet::new(
        Group::Symmetric,
        N,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap()
}

struct LoadResult {
    rps: f64,
    snapshot: MetricsSnapshot,
}

fn run_load(workers: usize, window_us: u64, max_batch: usize, requests: usize) -> LoadResult {
    let mut coord = Coordinator::new(ServerConfig {
        workers,
        max_batch,
        batch_window: Duration::from_micros(window_us),
        queue_capacity: 4096,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(test_net()));
    let handle = Arc::new(coord.start());
    let clients = 8;
    let per_client = requests / clients;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per_client {
                let v = Tensor::random(N, 2, &mut rng);
                h.infer("m", v).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snapshot = handle.metrics();
    let rps = (clients * per_client) as f64 / wall;
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
    LoadResult { rps, snapshot }
}

/// One point of the cores-scaling sweep.
struct ScalePoint {
    workers: usize,
    rps: f64,
    /// `rps / (workers × rps@1)` — 1.0 is perfect linear scaling.
    efficiency: f64,
}

/// Worker counts 1, 2, 4, … up to the hardware thread count (always
/// included, even when not a power of two).
fn scaling_worker_counts() -> Vec<usize> {
    let hw = hw_threads();
    let mut counts = Vec::new();
    let mut w = 1usize;
    while w < hw {
        counts.push(w);
        w *= 2;
    }
    counts.push(hw);
    counts.dedup();
    counts
}

/// Mixed-model bursty load for the scaling sweep: two routes with
/// different network depths share the pool, and each client submits
/// bursts of 8 (4 per route) before draining the responses — closer to a
/// real serving mix than the single-route closed loop above.
fn run_mixed_burst(workers: usize, requests: usize) -> f64 {
    let mut coord = Coordinator::new(ServerConfig {
        workers,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        queue_capacity: 4096,
        ..ServerConfig::default()
    });
    coord.register("shallow", ModelKind::net(test_net()));
    let deep = {
        let mut rng = Rng::new(43);
        EquivariantNet::new(
            Group::Symmetric,
            N,
            &[2, 2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap()
    };
    coord.register("deep", ModelKind::net(deep));
    let handle = Arc::new(coord.start());
    let clients = 8usize;
    let per_client = requests / clients;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(300 + c as u64);
            let mut sent = 0usize;
            while sent < per_client {
                let burst = 8.min(per_client - sent);
                let mut receivers = Vec::with_capacity(burst);
                for b in 0..burst {
                    let route = if b % 2 == 0 { "shallow" } else { "deep" };
                    let v = Tensor::random(N, 2, &mut rng);
                    receivers.push(h.submit(route, v).unwrap());
                }
                for rx in receivers {
                    rx.recv().unwrap().unwrap();
                }
                sent += burst;
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
    (clients * per_client) as f64 / wall
}

/// Sweep worker counts over the mixed bursty harness and report scaling
/// efficiency against the 1-worker baseline.
fn run_scaling_sweep(fast: bool) -> Vec<ScalePoint> {
    let requests = if fast { 320 } else { 1600 };
    let mut points = Vec::new();
    let mut base_rps = 0f64;
    for workers in scaling_worker_counts() {
        let rps = run_mixed_burst(workers, requests);
        if workers == 1 {
            base_rps = rps;
        }
        let efficiency = if base_rps > 0.0 {
            rps / (workers as f64 * base_rps)
        } else {
            0.0
        };
        points.push(ScalePoint {
            workers,
            rps,
            efficiency,
        });
    }
    points
}

/// Plan-cache behaviour the serving stack relies on, measured explicitly:
/// the first model build factors every diagram (misses), every later build
/// of the same architecture is all hits, and serving requests never
/// re-factors.
struct CacheReport {
    first_model_misses: u64,
    second_model_hit_rate: f64,
    second_request_misses: u64,
    /// `Factor` executions during the second request, counted at the
    /// `MultPlan::new` level — catches re-factoring even if a regression
    /// bypasses the cache (cache-miss counters cannot see that).
    second_request_factor_runs: u64,
}

fn measure_cache() -> CacheReport {
    let cache = PlanCache::global();
    let before = cache.stats();
    let net = test_net();
    let after_first = cache.stats();
    let first_model_misses = after_first.misses - before.misses;

    let _replica = test_net();
    let after_second = cache.stats();
    let second_build_hits = after_second.hits - after_first.hits;
    let second_build_misses = after_second.misses - after_first.misses;
    let second_model_hit_rate = if second_build_hits + second_build_misses == 0 {
        0.0
    } else {
        second_build_hits as f64 / (second_build_hits + second_build_misses) as f64
    };

    // Serve two requests through a coordinator; the second (and any later)
    // request must not add a single miss.
    let mut coord = Coordinator::new(ServerConfig::default());
    coord.register("m", ModelKind::net(net));
    let handle = coord.start();
    let mut rng = Rng::new(7);
    handle.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
    let before_second = cache.stats();
    let factor_before = factor_runs();
    handle.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
    let after_requests = cache.stats();
    let factor_after = factor_runs();
    handle.shutdown();

    CacheReport {
        first_model_misses,
        second_model_hit_rate,
        second_request_misses: after_requests.misses - before_second.misses,
        second_request_factor_runs: factor_after - factor_before,
    }
}

fn write_json(
    path: &str,
    best_rps: f64,
    seq_rps: f64,
    batched_rps: f64,
    batched_snapshot: &MetricsSnapshot,
    cache: &CacheReport,
    scaling: &[ScalePoint],
) {
    let stats = PlanCache::global().stats();
    let pool = equidiag::util::executor::global_stats();
    let shard_rates: Vec<String> = PlanCache::global()
        .shard_stats()
        .iter()
        .map(|s| {
            let lookups = s.hits + s.misses;
            let rate = if lookups > 0 {
                s.hits as f64 / lookups as f64
            } else {
                0.0
            };
            format!("{rate:.4}")
        })
        .collect();
    let points: Vec<String> = scaling
        .iter()
        .map(|p| {
            format!(
                "{{\"workers\": {}, \"requests_per_sec\": {:.1}, \"efficiency\": {:.4}}}",
                p.workers, p.rps, p.efficiency
            )
        })
        .collect();
    let half_hw = (hw_threads() / 2).max(1);
    let eff_half = scaling
        .iter()
        .min_by_key(|p| p.workers.abs_diff(half_hw))
        .map_or(0.0, |p| p.efficiency);
    let json = format!(
        "{{\n  \"bench\": \"coordinator_throughput\",\n  \"n\": {N},\n  \
         \"requests_per_sec_best\": {best_rps:.1},\n  \
         \"requests_per_sec_sequential\": {seq_rps:.1},\n  \
         \"requests_per_sec_batched\": {batched_rps:.1},\n  \
         \"batched_vs_sequential_speedup\": {speedup:.3},\n  \
         \"mean_batch_size\": {mean_batch:.3},\n  \
         \"mean_batch_exec_us\": {exec_us:.1},\n  \
         \"scaling\": {{\n    \
         \"hw_threads\": {hw},\n    \
         \"efficiency_at_half_hw\": {eff_half:.4},\n    \
         \"points\": [{points}],\n    \
         \"executor\": {{\"workers\": {xw}, \"executed\": {xe}, \"steals\": {xs}, \
         \"parks\": {xp}, \"injector_pushes\": {xi}}},\n    \
         \"plan_cache_shards\": {shards},\n    \
         \"shard_hit_rates\": [{rates}]\n  }},\n  \
         \"plan_cache\": {{\n    \"hits\": {hits},\n    \"misses\": {misses},\n    \
         \"hit_rate\": {hit_rate:.4},\n    \
         \"first_model_misses\": {fmm},\n    \
         \"second_model_hit_rate\": {smhr:.4},\n    \
         \"second_request_misses\": {srm},\n    \
         \"second_request_factor_runs\": {srf}\n  }}\n}}\n",
        speedup = batched_rps / seq_rps,
        mean_batch = batched_snapshot.mean_batch_size,
        exec_us = batched_snapshot.mean_batch_exec_s * 1e6,
        hw = hw_threads(),
        points = points.join(", "),
        xw = pool.workers,
        xe = pool.executed,
        xs = pool.steals,
        xp = pool.parks,
        xi = pool.injector_pushes,
        shards = stats.shards,
        rates = shard_rates.join(", "),
        hits = stats.hits,
        misses = stats.misses,
        hit_rate = stats.hit_rate(),
        fmm = cache.first_model_misses,
        smhr = cache.second_model_hit_rate,
        srm = cache.second_request_misses,
        srf = cache.second_request_factor_runs,
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

/// Keep expected chaos-injected panics off stderr while real panics
/// still print through the previous hook.
fn install_chaos_panic_hook() {
    let old = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let payload = info.payload();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        if !msg.starts_with(CHAOS_PANIC_PREFIX) {
            old(info);
        }
    }));
}

/// Per-route terminal-outcome tally. Every `infer` call lands in exactly
/// one bucket, so `total()` equals the submitted count iff the
/// exactly-one-outcome invariant holds (and the call returning at all
/// certifies the no-hang invariant).
#[derive(Default)]
struct Outcomes {
    ok: AtomicU64,
    deadline: AtomicU64,
    overloaded: AtomicU64,
    typed_error: AtomicU64,
}

impl Outcomes {
    fn record(&self, result: &Result<Tensor, Error>) {
        match result {
            Ok(_) => &self.ok,
            Err(Error::DeadlineExceeded) => &self.deadline,
            Err(Error::Overloaded { .. }) => &self.overloaded,
            Err(_) => &self.typed_error,
        }
        .fetch_add(1, Ordering::Relaxed);
    }
    fn total(&self) -> u64 {
        self.ok.load(Ordering::Relaxed)
            + self.deadline.load(Ordering::Relaxed)
            + self.overloaded.load(Ordering::Relaxed)
            + self.typed_error.load(Ordering::Relaxed)
    }
    fn availability(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        self.ok.load(Ordering::Relaxed) as f64 / self.total() as f64
    }
}

struct ChaosReport {
    healthy: Outcomes,
    chaotic: Outcomes,
    submitted_per_route: u64,
    snapshot: MetricsSnapshot,
    injected: (u64, u64, u64),
    recovered_probes_ok: u64,
    wall_s: f64,
}

/// Chaos scenario: one healthy route and one route wrapped in a seeded
/// fault plan (panics + stalls + errors) share a 4-worker pool under
/// closed-loop load with a generous request timeout. Asserts the
/// fault-tolerance invariants and returns the tallies for the JSON.
fn run_chaos(fast: bool) -> ChaosReport {
    // Closed-loop with 4 clients per route, so chaotic batches hold ≤ 4
    // items and the chaotic model sees ≥ per_client model calls — at a
    // 12% panic rate the chance of a zero-panic run is ≪ 1e-5 even in
    // fast mode, keeping the `worker_restarts > 0` assertion stable.
    let per_client = if fast { 100 } else { 200 };
    let clients_per_route = 4u64;
    let plan = Arc::new(
        ChaosPlan::new(42)
            .with_panics(120)
            .with_stalls(40, Duration::from_millis(2))
            .with_errors(40),
    );
    let mut coord = Coordinator::new(ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        queue_capacity: 4096,
        request_timeout: Some(Duration::from_secs(2)),
        ..ServerConfig::default()
    });
    coord.register("healthy", ModelKind::net(test_net()));
    coord.register("chaotic", ModelKind::chaos(ModelKind::net(test_net()), plan.clone()));
    let handle = Arc::new(coord.start());
    let healthy = Arc::new(Outcomes::default());
    let chaotic = Arc::new(Outcomes::default());
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for (route, tally) in [("healthy", &healthy), ("chaotic", &chaotic)] {
        for c in 0..clients_per_route {
            let h = handle.clone();
            let tally = tally.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + c);
                for _ in 0..per_client {
                    let v = Tensor::random(N, 2, &mut rng);
                    tally.record(&h.infer(route, v));
                }
            }));
        }
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snapshot = handle.metrics();
    // Recovery: after the storm, a respawned pool serves healthy traffic.
    let mut rng = Rng::new(77);
    let mut recovered_probes_ok = 0u64;
    for _ in 0..20 {
        if handle.infer("healthy", Tensor::random(N, 2, &mut rng)).is_ok() {
            recovered_probes_ok += 1;
        }
    }
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }

    let submitted_per_route = clients_per_route * per_client;
    let report = ChaosReport {
        healthy: Arc::try_unwrap(healthy).ok().unwrap(),
        chaotic: Arc::try_unwrap(chaotic).ok().unwrap(),
        submitted_per_route,
        snapshot,
        injected: plan.injected(),
        recovered_probes_ok,
        wall_s,
    };

    // Invariants (the acceptance gate for the fault-tolerant coordinator).
    assert_eq!(
        report.healthy.total(),
        submitted_per_route,
        "healthy route lost or duplicated terminal outcomes"
    );
    assert_eq!(
        report.chaotic.total(),
        submitted_per_route,
        "chaotic route lost or duplicated terminal outcomes"
    );
    assert!(
        report.healthy.availability() >= 0.99,
        "healthy-route availability {} < 0.99 under chaos",
        report.healthy.availability()
    );
    assert!(
        report.snapshot.worker_restarts > 0,
        "no worker was ever respawned despite injected panics ({:?} injected)",
        report.injected
    );
    assert!(
        report.snapshot.batch_panics > 0,
        "no batch panic was caught despite injected panics"
    );
    assert_eq!(
        report.recovered_probes_ok, 20,
        "pool did not recover after the chaos storm"
    );
    report
}

struct OverloadReport {
    submitted: u64,
    outcomes: Outcomes,
    snapshot: MetricsSnapshot,
}

/// Overload scenario: a single worker pinned by an always-stalling model,
/// a 5ms request timeout, and an inflight cap of 2 — a burst of submits
/// must split cleanly into admission sheds, deadline sheds, and (late)
/// responses, with both shed counters provably non-zero.
fn run_overload() -> OverloadReport {
    let plan = Arc::new(ChaosPlan::new(7).with_stalls(1000, Duration::from_millis(200)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        request_timeout: Some(Duration::from_millis(5)),
        max_inflight_per_model: Some(2),
        ..ServerConfig::default()
    });
    coord.register("stuck", ModelKind::chaos(ModelKind::net(test_net()), plan));
    let handle = coord.start();
    let mut rng = Rng::new(11);
    let submitted = 40u64;
    let outcomes = Outcomes::default();
    let mut receivers = Vec::new();
    for _ in 0..submitted {
        // submit() (not infer) so the burst outruns the stalled worker:
        // door rejections are tallied immediately, accepted items' typed
        // outcomes are collected afterwards.
        match handle.submit("stuck", Tensor::random(N, 2, &mut rng)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => outcomes.record(&Err(e)),
        }
    }
    for rx in receivers {
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(result) => outcomes.record(&result),
            Err(_) => outcomes.record(&Err(Error::Coordinator(
                "no terminal outcome delivered".into(),
            ))),
        }
    }
    let snapshot = handle.metrics();
    handle.shutdown();

    assert_eq!(
        outcomes.total(),
        submitted,
        "overload burst lost terminal outcomes"
    );
    assert!(
        snapshot.shed_admission > 0,
        "inflight cap 2 under a 40-deep burst must shed by admission"
    );
    assert!(
        snapshot.shed_expired > 0,
        "5ms deadline behind a 200ms stall must shed by expiry"
    );
    OverloadReport {
        submitted,
        outcomes,
        snapshot,
    }
}

fn write_robustness_json(path: &str, chaos: &ChaosReport, overload: &OverloadReport) {
    let s = &chaos.snapshot;
    let json = format!(
        "{{\n  \"bench\": \"coordinator_robustness\",\n  \"n\": {N},\n  \
         \"chaos\": {{\n    \
         \"submitted_per_route\": {spr},\n    \
         \"availability_healthy\": {ah:.4},\n    \
         \"availability_chaotic\": {ac:.4},\n    \
         \"healthy_ok\": {hok},\n    \
         \"chaotic_ok\": {cok},\n    \
         \"chaotic_typed_errors\": {cerr},\n    \
         \"chaotic_deadline\": {cdl},\n    \
         \"p50_latency_ms\": {p50:.3},\n    \
         \"p99_latency_ms\": {p99:.3},\n    \
         \"p99_batch_exec_ms\": {p99e:.3},\n    \
         \"worker_restarts\": {restarts},\n    \
         \"batch_panics\": {panics},\n    \
         \"shed_expired\": {shed},\n    \
         \"injected_panics\": {ip},\n    \
         \"injected_stalls\": {is},\n    \
         \"injected_errors\": {ie},\n    \
         \"recovered_probes_ok\": {rec},\n    \
         \"wall_s\": {wall:.2}\n  }},\n  \
         \"overload\": {{\n    \
         \"submitted\": {osub},\n    \
         \"ok\": {ook},\n    \
         \"overloaded\": {oover},\n    \
         \"deadline\": {odl},\n    \
         \"shed_admission\": {oadm},\n    \
         \"shed_expired\": {oexp}\n  }}\n}}\n",
        spr = chaos.submitted_per_route,
        ah = chaos.healthy.availability(),
        ac = chaos.chaotic.availability(),
        hok = chaos.healthy.ok.load(Ordering::Relaxed),
        cok = chaos.chaotic.ok.load(Ordering::Relaxed),
        cerr = chaos.chaotic.typed_error.load(Ordering::Relaxed),
        cdl = chaos.chaotic.deadline.load(Ordering::Relaxed),
        p50 = s.p50_latency_s * 1e3,
        p99 = s.p99_latency_s * 1e3,
        p99e = s.p99_batch_exec_s * 1e3,
        restarts = s.worker_restarts,
        panics = s.batch_panics,
        shed = s.shed_expired,
        ip = chaos.injected.0,
        is = chaos.injected.1,
        ie = chaos.injected.2,
        rec = chaos.recovered_probes_ok,
        wall = chaos.wall_s,
        osub = overload.submitted,
        ook = overload.outcomes.ok.load(Ordering::Relaxed),
        oover = overload.outcomes.overloaded.load(Ordering::Relaxed),
        odl = overload.outcomes.deadline.load(Ordering::Relaxed),
        oadm = overload.snapshot.shed_admission,
        oexp = overload.snapshot.shed_expired,
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// Poll the coordinator's metrics until `pred` holds or `timeout`
/// passes (shadow verification and the supervisor sweeps run
/// asynchronously); returns the last snapshot either way.
fn wait_metrics(
    handle: &equidiag::coordinator::CoordinatorHandle,
    timeout: Duration,
    pred: impl Fn(&MetricsSnapshot) -> bool,
) -> MetricsSnapshot {
    let deadline = Instant::now() + timeout;
    loop {
        let snap = handle.metrics();
        if pred(&snap) || Instant::now() >= deadline {
            return snap;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

struct IntegrityReport {
    // Realistic sampling: 5% shadow verification under 1% bit-flips.
    realistic_served: u64,
    realistic_flips: u64,
    realistic_verified: u64,
    realistic_mismatches: u64,
    // Full verification under always-on flips: every flip must be caught.
    full_flips: u64,
    full_mismatches: u64,
    full_quarantines: u64,
    full_recompiles: u64,
    // Fully-verified clean traffic: zero mismatches allowed.
    clean_served: u64,
    clean_mismatches: u64,
    // Watchdog: wall time from wedged submit to the typed BatchStuck.
    watchdog_stuck_ms: f64,
    watchdog_kills: u64,
    watchdog_probes_ok: u64,
    // Brownout cycle under a 1-byte budget.
    brownout_engage_ms: f64,
    brownout_recover_ms: f64,
    brownout_engagements: u64,
    brownout_recoveries: u64,
}

/// Closed-loop load over a route whose responses are silently bit-flipped
/// at `flip_per_mille`, with `verify_per_mille` shadow verification;
/// waits for the async verifier to drain before snapshotting.
fn run_verified_load(
    flip_per_mille: u64,
    verify_per_mille: usize,
    requests: usize,
) -> (u64, MetricsSnapshot, MetricsSnapshot) {
    let mut coord = Coordinator::new(ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        queue_capacity: 4096,
        verify_per_mille,
        ..ServerConfig::default()
    });
    let plan = Arc::new(ChaosPlan::new(606).with_bit_flips(flip_per_mille));
    let kind = ModelKind::net(test_net());
    coord.register(
        "m",
        if flip_per_mille > 0 {
            ModelKind::chaos(kind, plan.clone())
        } else {
            kind
        },
    );
    let handle = Arc::new(coord.start());
    let start = handle.metrics();
    let clients = 4usize;
    let per_client = requests / clients;
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(600 + c as u64);
            for _ in 0..per_client {
                // Flips are silent: every request still resolves Ok.
                h.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // The Bresenham sampler verifies exactly ⌊served × per_mille / 1000⌋
    // responses; wait for the spare-capacity verifier to reach that.
    let served = (clients * per_client) as u64;
    let expect = served * verify_per_mille as u64 / 1000;
    let snapshot = wait_metrics(&handle, Duration::from_secs(60), |s| {
        s.shadow_verifications >= expect
    });
    let flips = plan.injected_silent().0;
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
    (flips, start, snapshot)
}

/// Silent-failure defense scenarios: sampled + full shadow verification
/// under bit-flips, a clean-traffic false-positive check, watchdog
/// time-to-recovery, and one brownout engage/recover cycle.
fn run_integrity(fast: bool) -> IntegrityReport {
    // Realistic operating point: 1% of batches flipped, 5% of responses
    // shadow-verified. Coverage is exact by construction; detections are
    // reported, not asserted (they depend on flip/sample alignment).
    let requests = if fast { 400 } else { 2000 };
    let (flips_r, _, snap_r) = run_verified_load(10, 50, requests);
    let realistic_served = requests as u64;
    let realistic_verified = snap_r.shadow_verifications;
    assert_eq!(
        realistic_verified,
        realistic_served * 50 / 1000,
        "Bresenham sampling must hit the exact configured fraction"
    );
    assert!(
        snap_r.integrity_mismatches <= realistic_verified,
        "cannot detect more than was verified"
    );

    // Certainty point: every batch flipped, every response verified —
    // each flipped response must be detected, exactly once.
    let full_requests = if fast { 100 } else { 400 };
    let (flips_f, start_f, snap_f) = run_verified_load(1000, 1000, full_requests);
    assert!(flips_f > 0);
    assert_eq!(
        snap_f.integrity_mismatches, flips_f,
        "full verification must catch every injected flip (one per batch)"
    );
    assert_eq!(snap_f.degraded_models, 1);
    let full_quarantines = snap_f.schedule_quarantines - start_f.schedule_quarantines;
    assert!(full_quarantines >= 1, "mismatches must quarantine schedules");

    // Clean traffic, fully verified: any mismatch is a false positive.
    let (_, _, snap_c) = run_verified_load(0, 1000, full_requests);
    assert_eq!(snap_c.shadow_verifications, full_requests as u64);
    assert_eq!(
        snap_c.integrity_mismatches, 0,
        "shadow verification false-positived on clean traffic"
    );

    // Watchdog: a 30s injected stall behind a 150ms floor; measure the
    // wall time until the waiter gets the typed BatchStuck, then probe
    // that the respawned pool still serves.
    let stall_plan = Arc::new(ChaosPlan::new(13).with_long_stalls(1000, Duration::from_secs(30)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        request_timeout: Some(Duration::from_millis(150)),
        watchdog_factor: 4.0,
        ..ServerConfig::default()
    });
    coord.register("wedged", ModelKind::chaos(ModelKind::net(test_net()), stall_plan));
    coord.register("ok", ModelKind::net(test_net()));
    let handle = coord.start();
    let mut rng = Rng::new(607);
    let t0 = Instant::now();
    let rx = handle.submit("wedged", Tensor::random(N, 2, &mut rng)).unwrap();
    match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
        Err(Error::BatchStuck) => {}
        other => panic!("expected BatchStuck, got {other:?}"),
    }
    let watchdog_stuck_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut watchdog_probes_ok = 0u64;
    for _ in 0..20 {
        if handle.infer("ok", Tensor::random(N, 2, &mut rng)).is_ok() {
            watchdog_probes_ok += 1;
        }
    }
    let snap_w = handle.metrics();
    assert_eq!(snap_w.watchdog_kills, 1);
    assert_eq!(watchdog_probes_ok, 20, "pool did not survive the reap");
    handle.shutdown();

    // Brownout: a 1-byte budget engages under any traffic; recovery
    // follows once the load stops and the under-budget window elapses.
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        queue_capacity: 4096,
        arena_budget_bytes: Some(1),
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(test_net()));
    let handle = coord.start();
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(15);
    while handle.metrics().brownout_state == 0 && Instant::now() < deadline {
        handle.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
    }
    let brownout_engage_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let snap_b = wait_metrics(&handle, Duration::from_secs(30), |s| {
        s.brownout_state == 0 && s.brownout_recoveries >= 1
    });
    let brownout_recover_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(snap_b.brownout_engagements >= 1, "brownout never engaged");
    assert!(snap_b.brownout_recoveries >= 1, "brownout never recovered");
    handle.shutdown();

    IntegrityReport {
        realistic_served,
        realistic_flips: flips_r,
        realistic_verified,
        realistic_mismatches: snap_r.integrity_mismatches,
        full_flips: flips_f,
        full_mismatches: snap_f.integrity_mismatches,
        full_quarantines,
        full_recompiles: snap_f.schedule_recompiles,
        clean_served: full_requests as u64,
        clean_mismatches: snap_c.integrity_mismatches,
        watchdog_stuck_ms,
        watchdog_kills: snap_w.watchdog_kills,
        watchdog_probes_ok,
        brownout_engage_ms,
        brownout_recover_ms,
        brownout_engagements: snap_b.brownout_engagements,
        brownout_recoveries: snap_b.brownout_recoveries,
    }
}

fn write_integrity_json(path: &str, r: &IntegrityReport) {
    let json = format!(
        "{{\n  \"bench\": \"coordinator_integrity\",\n  \"n\": {N},\n  \
         \"shadow_verification\": {{\n    \
         \"realistic\": {{\"served\": {rs}, \"flipped_batches\": {rf}, \
         \"verified\": {rv}, \"mismatches\": {rm}}},\n    \
         \"full\": {{\"flipped_batches\": {ff}, \"mismatches\": {fm}, \
         \"quarantines\": {fq}, \"recompiles\": {fr}}},\n    \
         \"clean\": {{\"served\": {cs}, \"mismatches\": {cm}}}\n  }},\n  \
         \"watchdog\": {{\n    \
         \"time_to_batch_stuck_ms\": {ws:.1},\n    \
         \"kills\": {wk},\n    \
         \"recovered_probes_ok\": {wp}\n  }},\n  \
         \"brownout\": {{\n    \
         \"engage_ms\": {be:.1},\n    \
         \"recover_ms\": {br:.1},\n    \
         \"engagements\": {ben},\n    \
         \"recoveries\": {brc}\n  }}\n}}\n",
        rs = r.realistic_served,
        rf = r.realistic_flips,
        rv = r.realistic_verified,
        rm = r.realistic_mismatches,
        ff = r.full_flips,
        fm = r.full_mismatches,
        fq = r.full_quarantines,
        fr = r.full_recompiles,
        cs = r.clean_served,
        cm = r.clean_mismatches,
        ws = r.watchdog_stuck_ms,
        wk = r.watchdog_kills,
        wp = r.watchdog_probes_ok,
        be = r.brownout_engage_ms,
        br = r.brownout_recover_ms,
        ben = r.brownout_engagements,
        brc = r.brownout_recoveries,
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    println!("== E9: coordinator throughput (closed-loop, 8 clients) ==\n");

    let cache = measure_cache();
    println!(
        "plan cache: first model build ran Factor {} times; an identical \
         replica hit the cache {:.0}% of the time; the second request added \
         {} misses",
        cache.first_model_misses,
        cache.second_model_hit_rate * 100.0,
        cache.second_request_misses
    );
    assert_eq!(
        cache.second_request_misses, 0,
        "serving must never miss on a cached plan"
    );
    assert_eq!(
        cache.second_request_factor_runs, 0,
        "serving must never run Factor at all (even bypassing the cache)"
    );

    let requests = if fast { 400 } else { 2000 };
    let worker_counts: &[usize] = if fast { &[4] } else { &[1, 2, 4, 8] };
    let shapes: &[(u64, usize)] = if fast {
        &[(0, 1), (1000, 64)]
    } else {
        &[(0, 1), (200, 16), (1000, 64)]
    };
    let mut table = Table::new(vec![
        "workers",
        "window",
        "max batch",
        "req/s",
        "mean latency",
        "mean batch",
        "batch exec",
    ]);
    let mut best_rps = 0f64;
    let mut seq_rps = 0f64;
    let mut batched_rps = 0f64;
    let mut batched_snapshot: Option<MetricsSnapshot> = None;
    for &workers in worker_counts {
        for &(window_us, max_batch) in shapes {
            let r = run_load(workers, window_us, max_batch, requests);
            if r.rps > best_rps {
                best_rps = r.rps;
            }
            // The fixed-worker comparison pair for the JSON: batched
            // (64-deep window) vs sequential (max_batch = 1) at 4 workers.
            if workers == 4 && max_batch == 1 {
                seq_rps = r.rps;
            }
            if workers == 4 && max_batch == 64 {
                batched_rps = r.rps;
                batched_snapshot = Some(r.snapshot.clone());
            }
            table.row(vec![
                format!("{workers}"),
                format!("{window_us} us"),
                format!("{max_batch}"),
                format!("{:.0}", r.rps),
                format!("{:.0} us", r.snapshot.mean_latency_s * 1e6),
                format!("{:.2}", r.snapshot.mean_batch_size),
                format!("{:.0} us", r.snapshot.mean_batch_exec_s * 1e6),
            ]);
        }
    }
    table.print();
    println!(
        "\nbatched (4 workers, max batch 64) vs sequential (4 workers, max \
         batch 1): {:.2}x",
        batched_rps / seq_rps
    );

    println!(
        "\n== cores scaling: mixed shallow/deep bursty load, workers 1..{} ==\n",
        hw_threads()
    );
    let scaling = run_scaling_sweep(fast);
    let mut scale_table = Table::new(vec!["workers", "req/s", "speedup", "efficiency"]);
    for p in &scaling {
        scale_table.row(vec![
            format!("{}", p.workers),
            format!("{:.0}", p.rps),
            // efficiency = rps / (workers × rps@1), so speedup over the
            // 1-worker baseline is efficiency × workers.
            format!("{:.2}x", p.efficiency * p.workers as f64),
            format!("{:.0}%", p.efficiency * 100.0),
        ]);
    }
    scale_table.print();
    let half_hw = (hw_threads() / 2).max(1);
    if let Some(p) = scaling.iter().min_by_key(|p| p.workers.abs_diff(half_hw)) {
        println!(
            "\nparallel efficiency at {} workers (nearest half the {} hardware \
             threads): {:.0}%",
            p.workers,
            hw_threads(),
            p.efficiency * 100.0
        );
    }

    write_json(
        "BENCH_throughput.json",
        best_rps,
        seq_rps,
        batched_rps,
        batched_snapshot.as_ref().expect("4-worker batched run"),
        &cache,
        &scaling,
    );

    println!("\n== robustness: seeded chaos + overload ==\n");
    install_chaos_panic_hook();
    let chaos = run_chaos(fast);
    println!(
        "chaos ({} req/route, injected {:?} panic/stall/error): healthy \
         availability {:.4}, chaotic availability {:.4}, p99 {:.1} ms, \
         {} worker restarts, {} batch panics caught, pool recovered",
        chaos.submitted_per_route,
        chaos.injected,
        chaos.healthy.availability(),
        chaos.chaotic.availability(),
        chaos.snapshot.p99_latency_s * 1e3,
        chaos.snapshot.worker_restarts,
        chaos.snapshot.batch_panics,
    );
    let overload = run_overload();
    println!(
        "overload (burst {} into stalled 1-worker pool): {} admission sheds, \
         {} deadline sheds, every request got a terminal outcome",
        overload.submitted,
        overload.snapshot.shed_admission,
        overload.snapshot.shed_expired,
    );
    write_robustness_json("BENCH_robustness.json", &chaos, &overload);

    println!("\n== integrity: bit-flip shadow detection, watchdog, brownout ==\n");
    let integrity = run_integrity(fast);
    println!(
        "shadow verification: realistic run verified {}/{} responses under \
         {} flipped batches ({} caught); full run caught {}/{} flips with \
         {} schedule quarantines; clean run {} false positives",
        integrity.realistic_verified,
        integrity.realistic_served,
        integrity.realistic_flips,
        integrity.realistic_mismatches,
        integrity.full_mismatches,
        integrity.full_flips,
        integrity.full_quarantines,
        integrity.clean_mismatches,
    );
    println!(
        "watchdog: wedged waiter freed in {:.0} ms, {} kill(s), all {} \
         recovery probes served; brownout: engaged in {:.0} ms, recovered \
         {:.0} ms after load stopped",
        integrity.watchdog_stuck_ms,
        integrity.watchdog_kills,
        integrity.watchdog_probes_ok,
        integrity.brownout_engage_ms,
        integrity.brownout_recover_ms,
    );
    write_integrity_json("BENCH_integrity.json", &integrity);

    // PJRT route (single-owner-thread service).
    if std::path::Path::new("artifacts/pair_trace.hlo.txt").exists() {
        match HloService::spawn("artifacts/pair_trace.hlo.txt") {
            Ok(svc) => {
                let batch = 4usize;
                let n = 8usize;
                let reps = 500;
                let t0 = Instant::now();
                for r in 0..reps {
                    let data = vec![r as f32; batch * n * n];
                    let _ = svc.run_f32(vec![(data, vec![batch, n, n])]).unwrap();
                }
                let wall = t0.elapsed().as_secs_f64();
                println!(
                    "\nPJRT pallas-kernel route: {:.0} exec/s ({:.0} matrices/s)",
                    reps as f64 / wall,
                    (reps * batch) as f64 / wall
                );
            }
            Err(e) => println!("\n(PJRT route unavailable: {e})"),
        }
    } else {
        println!("\n(artifacts missing — `make artifacts` enables the PJRT row)");
    }
}
