//! **E9 — serving-path throughput/latency.**
//!
//! The coordinator under closed-loop load: sweep worker count and batching
//! window, report req/s and latency. The native diagram-net route carries
//! the load; the PJRT route is exercised separately if artifacts exist.

use equidiag::config::ServerConfig;
use equidiag::coordinator::{Coordinator, ModelKind};
use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::runtime::HloService;
use equidiag::tensor::Tensor;
use equidiag::util::{Rng, Table};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_load(workers: usize, window_us: u64, max_batch: usize, requests: usize) -> (f64, f64, f64) {
    let n = 8;
    let mut rng = Rng::new(42);
    let net = EquivariantNet::new(
        Group::Symmetric,
        n,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap();
    let mut coord = Coordinator::new(ServerConfig {
        workers,
        max_batch,
        batch_window: Duration::from_micros(window_us),
        queue_capacity: 4096,
    });
    coord.register("m", ModelKind::net(net));
    let handle = Arc::new(coord.start());
    let clients = 8;
    let per_client = requests / clients;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per_client {
                let v = Tensor::random(8, 2, &mut rng);
                h.infer("m", v).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = handle.metrics();
    let out = (
        (clients * per_client) as f64 / wall,
        snap.mean_latency_s * 1e6,
        snap.mean_batch_size,
    );
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
    out
}

fn main() {
    println!("== E9: coordinator throughput (closed-loop, 8 clients) ==\n");
    let requests = 2000;
    let mut table = Table::new(vec![
        "workers",
        "window",
        "max batch",
        "req/s",
        "mean latency",
        "mean batch",
    ]);
    for &workers in &[1usize, 2, 4, 8] {
        for &(window_us, max_batch) in &[(0u64, 1usize), (200, 16), (1000, 64)] {
            let (rps, lat_us, mb) = run_load(workers, window_us, max_batch, requests);
            table.row(vec![
                format!("{workers}"),
                format!("{window_us} us"),
                format!("{max_batch}"),
                format!("{rps:.0}"),
                format!("{lat_us:.0} us"),
                format!("{mb:.2}"),
            ]);
        }
    }
    table.print();

    // PJRT route (single-owner-thread service).
    if std::path::Path::new("artifacts/pair_trace.hlo.txt").exists() {
        let svc = HloService::spawn("artifacts/pair_trace.hlo.txt").unwrap();
        let batch = 4usize;
        let n = 8usize;
        let reps = 500;
        let t0 = Instant::now();
        for r in 0..reps {
            let data = vec![r as f32; batch * n * n];
            let _ = svc.run_f32(vec![(data, vec![batch, n, n])]).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "\nPJRT pallas-kernel route: {:.0} exec/s ({:.0} matrices/s)",
            reps as f64 / wall,
            (reps * batch) as f64 / wall
        );
    } else {
        println!("\n(artifacts missing — `make artifacts` enables the PJRT row)");
    }
}
