//! **E9 — serving-path throughput/latency.**
//!
//! The coordinator under closed-loop load: sweep worker count and batching
//! window, report req/s and latency. The native diagram-net route carries
//! the load; the PJRT route is exercised separately if artifacts exist.
//!
//! Emits `BENCH_throughput.json` (requests/sec, plan-cache hit rate,
//! batched-vs-sequential speedup) so the perf trajectory is machine-
//! readable from PR 1 onward.

use equidiag::config::ServerConfig;
use equidiag::coordinator::{Coordinator, MetricsSnapshot, ModelKind};
use equidiag::fastmult::{factor_runs, Group, PlanCache};
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::runtime::HloService;
use equidiag::tensor::Tensor;
use equidiag::util::{Rng, Table};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 8;

fn test_net() -> EquivariantNet {
    // Same seed every time: every run after the first hits the plan cache.
    let mut rng = Rng::new(42);
    EquivariantNet::new(
        Group::Symmetric,
        N,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap()
}

struct LoadResult {
    rps: f64,
    snapshot: MetricsSnapshot,
}

fn run_load(workers: usize, window_us: u64, max_batch: usize, requests: usize) -> LoadResult {
    let mut coord = Coordinator::new(ServerConfig {
        workers,
        max_batch,
        batch_window: Duration::from_micros(window_us),
        queue_capacity: 4096,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(test_net()));
    let handle = Arc::new(coord.start());
    let clients = 8;
    let per_client = requests / clients;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per_client {
                let v = Tensor::random(N, 2, &mut rng);
                h.infer("m", v).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snapshot = handle.metrics();
    let rps = (clients * per_client) as f64 / wall;
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
    LoadResult { rps, snapshot }
}

/// Plan-cache behaviour the serving stack relies on, measured explicitly:
/// the first model build factors every diagram (misses), every later build
/// of the same architecture is all hits, and serving requests never
/// re-factors.
struct CacheReport {
    first_model_misses: u64,
    second_model_hit_rate: f64,
    second_request_misses: u64,
    /// `Factor` executions during the second request, counted at the
    /// `MultPlan::new` level — catches re-factoring even if a regression
    /// bypasses the cache (cache-miss counters cannot see that).
    second_request_factor_runs: u64,
}

fn measure_cache() -> CacheReport {
    let cache = PlanCache::global();
    let before = cache.stats();
    let net = test_net();
    let after_first = cache.stats();
    let first_model_misses = after_first.misses - before.misses;

    let _replica = test_net();
    let after_second = cache.stats();
    let second_build_hits = after_second.hits - after_first.hits;
    let second_build_misses = after_second.misses - after_first.misses;
    let second_model_hit_rate = if second_build_hits + second_build_misses == 0 {
        0.0
    } else {
        second_build_hits as f64 / (second_build_hits + second_build_misses) as f64
    };

    // Serve two requests through a coordinator; the second (and any later)
    // request must not add a single miss.
    let mut coord = Coordinator::new(ServerConfig::default());
    coord.register("m", ModelKind::net(net));
    let handle = coord.start();
    let mut rng = Rng::new(7);
    handle.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
    let before_second = cache.stats();
    let factor_before = factor_runs();
    handle.infer("m", Tensor::random(N, 2, &mut rng)).unwrap();
    let after_requests = cache.stats();
    let factor_after = factor_runs();
    handle.shutdown();

    CacheReport {
        first_model_misses,
        second_model_hit_rate,
        second_request_misses: after_requests.misses - before_second.misses,
        second_request_factor_runs: factor_after - factor_before,
    }
}

fn write_json(
    path: &str,
    best_rps: f64,
    seq_rps: f64,
    batched_rps: f64,
    batched_snapshot: &MetricsSnapshot,
    cache: &CacheReport,
) {
    let stats = PlanCache::global().stats();
    let json = format!(
        "{{\n  \"bench\": \"coordinator_throughput\",\n  \"n\": {N},\n  \
         \"requests_per_sec_best\": {best_rps:.1},\n  \
         \"requests_per_sec_sequential\": {seq_rps:.1},\n  \
         \"requests_per_sec_batched\": {batched_rps:.1},\n  \
         \"batched_vs_sequential_speedup\": {speedup:.3},\n  \
         \"mean_batch_size\": {mean_batch:.3},\n  \
         \"mean_batch_exec_us\": {exec_us:.1},\n  \
         \"plan_cache\": {{\n    \"hits\": {hits},\n    \"misses\": {misses},\n    \
         \"hit_rate\": {hit_rate:.4},\n    \
         \"first_model_misses\": {fmm},\n    \
         \"second_model_hit_rate\": {smhr:.4},\n    \
         \"second_request_misses\": {srm},\n    \
         \"second_request_factor_runs\": {srf}\n  }}\n}}\n",
        speedup = batched_rps / seq_rps,
        mean_batch = batched_snapshot.mean_batch_size,
        exec_us = batched_snapshot.mean_batch_exec_s * 1e6,
        hits = stats.hits,
        misses = stats.misses,
        hit_rate = stats.hit_rate(),
        fmm = cache.first_model_misses,
        smhr = cache.second_model_hit_rate,
        srm = cache.second_request_misses,
        srf = cache.second_request_factor_runs,
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    println!("== E9: coordinator throughput (closed-loop, 8 clients) ==\n");

    let cache = measure_cache();
    println!(
        "plan cache: first model build ran Factor {} times; an identical \
         replica hit the cache {:.0}% of the time; the second request added \
         {} misses",
        cache.first_model_misses,
        cache.second_model_hit_rate * 100.0,
        cache.second_request_misses
    );
    assert_eq!(
        cache.second_request_misses, 0,
        "serving must never miss on a cached plan"
    );
    assert_eq!(
        cache.second_request_factor_runs, 0,
        "serving must never run Factor at all (even bypassing the cache)"
    );

    let requests = 2000;
    let mut table = Table::new(vec![
        "workers",
        "window",
        "max batch",
        "req/s",
        "mean latency",
        "mean batch",
        "batch exec",
    ]);
    let mut best_rps = 0f64;
    let mut seq_rps = 0f64;
    let mut batched_rps = 0f64;
    let mut batched_snapshot: Option<MetricsSnapshot> = None;
    for &workers in &[1usize, 2, 4, 8] {
        for &(window_us, max_batch) in &[(0u64, 1usize), (200, 16), (1000, 64)] {
            let r = run_load(workers, window_us, max_batch, requests);
            if r.rps > best_rps {
                best_rps = r.rps;
            }
            // The fixed-worker comparison pair for the JSON: batched
            // (64-deep window) vs sequential (max_batch = 1) at 4 workers.
            if workers == 4 && max_batch == 1 {
                seq_rps = r.rps;
            }
            if workers == 4 && max_batch == 64 {
                batched_rps = r.rps;
                batched_snapshot = Some(r.snapshot.clone());
            }
            table.row(vec![
                format!("{workers}"),
                format!("{window_us} us"),
                format!("{max_batch}"),
                format!("{:.0}", r.rps),
                format!("{:.0} us", r.snapshot.mean_latency_s * 1e6),
                format!("{:.2}", r.snapshot.mean_batch_size),
                format!("{:.0} us", r.snapshot.mean_batch_exec_s * 1e6),
            ]);
        }
    }
    table.print();
    println!(
        "\nbatched (4 workers, max batch 64) vs sequential (4 workers, max \
         batch 1): {:.2}x",
        batched_rps / seq_rps
    );

    write_json(
        "BENCH_throughput.json",
        best_rps,
        seq_rps,
        batched_rps,
        batched_snapshot.as_ref().expect("4-worker batched run"),
        &cache,
    );

    // PJRT route (single-owner-thread service).
    if std::path::Path::new("artifacts/pair_trace.hlo.txt").exists() {
        match HloService::spawn("artifacts/pair_trace.hlo.txt") {
            Ok(svc) => {
                let batch = 4usize;
                let n = 8usize;
                let reps = 500;
                let t0 = Instant::now();
                for r in 0..reps {
                    let data = vec![r as f32; batch * n * n];
                    let _ = svc.run_f32(vec![(data, vec![batch, n, n])]).unwrap();
                }
                let wall = t0.elapsed().as_secs_f64();
                println!(
                    "\nPJRT pallas-kernel route: {:.0} exec/s ({:.0} matrices/s)",
                    reps as f64 / wall,
                    (reps * batch) as f64 / wall
                );
            }
            Err(e) => println!("\n(PJRT route unavailable: {e})"),
        }
    } else {
        println!("\n(artifacts missing — `make artifacts` enables the PJRT row)");
    }
}
