//! **E1 — §5.2.1 time complexity for S_n.**
//!
//! The paper claims the fast algorithm costs `O(n^k)` in the worst case
//! (smallest bottom block of size 1), `O(n)` when the only bottom block has
//! size k, and is effectively free when there are no bottom blocks — vs
//! `O(n^{l+k})` naïve. We measure all three diagram families at fixed
//! `(k, l) = (3, 3)` over a sweep of `n` and report the fitted log–log
//! slopes next to the predicted exponents.

use equidiag::diagram::Diagram;
use equidiag::fastmult::{Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::tensor::Tensor;
use equidiag::util::timing::loglog_slope;
use equidiag::util::{bench_median, Rng, Table};
use std::time::Duration;

const K: usize = 3;
const L: usize = 3;

/// Worst case: bottom blocks of size 1 (plus cross blocks): cost O(n^k).
fn worst_case() -> Diagram {
    // top: cross uppers {0},{1},{2}? need l=3: one cross + 2 top-only;
    // bottom: one cross lower + 2 singleton bottom blocks.
    Diagram::from_blocks(
        L,
        K,
        vec![vec![0, 1], vec![2, 3], vec![4], vec![5]],
    )
    .unwrap()
}

/// Best contracting case: a single bottom block of size k: cost O(n).
fn best_case() -> Diagram {
    Diagram::from_blocks(L, K, vec![vec![0, 1, 2], vec![3, 4, 5]]).unwrap()
}

/// Free case: no bottom-only blocks (pure cross): memory moves only.
fn free_case() -> Diagram {
    Diagram::from_blocks(L, K, vec![vec![0, 3], vec![1, 4], vec![2, 5]]).unwrap()
}

fn main() {
    let budget = Duration::from_millis(200);
    let ns: Vec<usize> = vec![2, 3, 4, 6, 8, 10, 12];
    let naive_cap = 8; // n^{l+k} = n^6 beyond this is too slow to sweep

    println!("== E1: S_n scaling, (k, l) = ({K}, {L}) ==\n");
    let mut rng = Rng::new(1);

    for (label, d, predicted_fast) in [
        ("worst case (|B_b| = 1)", worst_case(), K as f64),
        ("best case (one block of size k)", best_case(), 1.0),
        ("free case (b = 0)", free_case(), 0.0),
    ] {
        let mut table = Table::new(vec!["n", "fast", "naive", "speedup"]);
        let mut xs = Vec::new();
        let mut fast_ys = Vec::new();
        let mut naive_xs = Vec::new();
        let mut naive_ys = Vec::new();
        for &n in &ns {
            let plan = MultPlan::new(Group::Symmetric, &d, n).unwrap();
            let v = Tensor::random(n, K, &mut rng);
            let fast = bench_median(budget, || {
                let _ = plan.apply(&v).unwrap();
            });
            xs.push(n as f64);
            fast_ys.push(fast.median_s);
            let naive_cell = if n <= naive_cap {
                let nv = bench_median(budget, || {
                    let _ = naive_apply(Group::Symmetric, &d, &v).unwrap();
                });
                naive_xs.push(n as f64);
                naive_ys.push(nv.median_s);
                (nv.pretty(), format!("{:.1}x", nv.median_s / fast.median_s))
            } else {
                ("-".to_string(), "-".to_string())
            };
            table.row(vec![
                format!("{n}"),
                fast.pretty(),
                naive_cell.0,
                naive_cell.1,
            ]);
        }
        // Fit slopes on the larger-n half (asymptotic regime).
        let h = xs.len() / 2;
        let fast_slope = loglog_slope(&xs[h..], &fast_ys[h..]);
        let nh = naive_xs.len() / 2;
        let naive_slope = loglog_slope(&naive_xs[nh..], &naive_ys[nh..]);
        println!("{label}  [diagram {d}]");
        table.print();
        // The paper's cost model (Remark 37) counts memory moves as free;
        // wall-clock additionally pays O(n^max(k,l)) input reads / output
        // writes, so the measured slope is bounded by
        // max(arithmetic exponent, k, l).
        let wallclock_bound = predicted_fast.max(K.max(L) as f64);
        println!(
            "measured fast slope {fast_slope:.2} (paper arithmetic: <= {predicted_fast}, \
             + memory: <= {wallclock_bound}), naive slope {naive_slope:.2} (paper: {})\n",
            K + L
        );
    }
}
