//! λ-coefficient folding must never go stale: the folded class structure
//! lives in the shape-keyed `PlanCache` entry (weight-independent), while
//! the coefficients are gathered from the layer's own `coeffs` on every
//! execute. These tests mutate weights in place (a real SGD step), re-run
//! forward/backward, and assert the folded path still matches the per-term
//! reference to ≤ 1e-12 — and that two same-shape layers sharing one
//! compiled schedule produce independent, correct outputs. All four groups.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::fastmult::Group;
use equidiag::layer::{transpose_sign, EquivariantLinear, Init};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;
use std::sync::Arc;

const GROUPS: [Group; 4] = [
    Group::Symmetric,
    Group::Orthogonal,
    Group::SpecialOrthogonal,
    Group::Symplectic,
];

fn dim_for(group: Group) -> usize {
    if group == Group::Symplectic {
        4
    } else {
        3
    }
}

/// One SGD step on `L = ½‖forward(v)‖²`, mutating the layer's coefficient
/// buffers in place (exactly what `nn::train` does between forwards).
fn train_step(layer: &mut EquivariantLinear, v: &Tensor, lr: f64) {
    let out = layer.forward(v).unwrap();
    let mut grads = layer.zero_grads();
    layer.backward(v, &out, &mut grads).unwrap();
    for (c, g) in layer.coeffs.iter_mut().zip(&grads.coeffs) {
        *c -= lr * g;
    }
    for (c, g) in layer.bias_coeffs.iter_mut().zip(&grads.bias_coeffs) {
        *c -= lr * g;
    }
}

#[test]
fn folded_path_tracks_in_place_weight_updates() {
    let mut rng = Rng::new(0xF01D);
    for group in GROUPS {
        let n = dim_for(group);
        let mut layer =
            EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        let v = Tensor::random(n, 2, &mut rng);
        // Pre-update agreement (sanity).
        let before = layer.forward(&v).unwrap();
        assert!(before.allclose(&layer.forward_per_term(&v).unwrap(), 1e-12));
        // Mutate every coefficient in place via a real train step…
        train_step(&mut layer, &v, 0.05);
        // …and the folded walk must see the new weights immediately: the
        // class structure is weight-independent, the λ-gather is per-call.
        let fused = layer.forward(&v).unwrap();
        let reference = layer.forward_per_term(&v).unwrap();
        assert!(
            fused.allclose(&reference, 1e-12),
            "{group}: stale folded coefficients after in-place update, diff {}",
            fused.max_abs_diff(&reference)
        );
        assert!(
            fused.max_abs_diff(&before) > 0.0,
            "{group}: the train step should have changed the output"
        );
        // Backward after the update matches the per-term transposed-plan
        // reference too.
        let g = Tensor::random(n, 2, &mut rng);
        let mut grads = layer.zero_grads();
        let grad_v = layer.backward(&v, &g, &mut grads).unwrap();
        let cache = equidiag::fastmult::PlanCache::global();
        let mut want_gv = Tensor::zeros(n, 2);
        for (i, d) in layer.diagrams().enumerate() {
            let plan = cache.get_or_build(group, &d.transpose(), n).unwrap();
            let bt = plan.apply(&g).unwrap();
            let sign = transpose_sign(group, d, n);
            assert!(
                (grads.coeffs[i] - sign * bt.dot(&v)).abs() <= 1e-12,
                "{group} coeff {i}: stale backward gradient"
            );
            if layer.coeffs[i] != 0.0 {
                want_gv.axpy(layer.coeffs[i] * sign, &bt);
            }
        }
        assert!(
            grad_v.allclose(&want_gv, 1e-12),
            "{group}: input gradient diverges by {}",
            grad_v.max_abs_diff(&want_gv)
        );
    }
}

#[test]
fn shared_schedule_layers_keep_independent_weights() {
    let mut rng = Rng::new(0xF02D);
    for group in GROUPS {
        let n = dim_for(group);
        let a = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        let mut b = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        // Same shape ⇒ one compiled schedule, shared through the global
        // PlanCache.
        assert!(
            Arc::ptr_eq(a.schedule(), b.schedule()),
            "{group}: same-shape layers must share one schedule"
        );
        // Give b distinctly different weights and check both layers still
        // match their own per-term references (the shared structure holds
        // no coefficients).
        for c in b.coeffs.iter_mut() {
            *c = -2.0 * *c + 0.125;
        }
        let v = Tensor::random(n, 2, &mut rng);
        let fa = a.forward(&v).unwrap();
        let fb = b.forward(&v).unwrap();
        assert!(
            fa.allclose(&a.forward_per_term(&v).unwrap(), 1e-12),
            "{group}: layer a diverges from its reference"
        );
        assert!(
            fb.allclose(&b.forward_per_term(&v).unwrap(), 1e-12),
            "{group}: layer b diverges from its reference"
        );
        if a.coeffs.iter().any(|&c| c != 0.0) {
            assert!(
                fa.max_abs_diff(&fb) > 0.0,
                "{group}: different weights must give different outputs"
            );
        }
    }
}
