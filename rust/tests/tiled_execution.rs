//! Tiled streaming execution (`docs/tiled_execution.md`) must be **bitwise
//! identical** to the untiled schedule walk on every execute variant: the
//! windowed kernels replay the exact per-element loop bodies of the full
//! kernels over disjoint output slabs, so no float is ever computed in a
//! different order. These tests pin that contract across all four groups,
//! forward and backward (map) walks, single and batched inputs, and both
//! scalar types, plus the degenerate paths (under-budget shapes and
//! `tile_bytes = 0`) that must skip tiling entirely.

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use equidiag::diagram::Diagram;
use equidiag::fastmult::{
    arena_peak_bytes, arena_stats, exec_stats, reset_arena_peak, Group, LayerSchedule, MultPlan,
    PooledArenaOf,
};
use equidiag::layer::spanning_plans;
use equidiag::tensor::{BatchTensorOf, Scalar, TensorOf};
use equidiag::util::Rng;

/// Tile-chain and arena counters are process-global; serialise every test
/// in this binary so deltas are attributable to the walk under test.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Small enough (8 f64s / 16 f32s) that every chain with a non-trivial
/// stored node walks multiple tiles at the test shapes below.
const TINY_BUDGET: usize = 64;

/// The shapes exercised by the bitwise sweep: every group, orders deep
/// enough (`k >= 3`) that strided fusion leaves slab-local chains for the
/// tiling planner to pick up.
fn shapes() -> Vec<(Group, usize, usize, usize)> {
    vec![
        (Group::Symmetric, 3, 3, 2),
        (Group::Symmetric, 4, 3, 1),
        (Group::Orthogonal, 3, 3, 1),
        (Group::Orthogonal, 3, 2, 2),
        // l + k >= n with (l + k - n) even: jellyfish diagrams included.
        (Group::SpecialOrthogonal, 3, 3, 2),
        (Group::Symplectic, 4, 3, 1),
    ]
}

struct Fixture<S: Scalar> {
    schedule: LayerSchedule,
    coeffs: Vec<f64>,
    v: TensorOf<S>,
    batch: Vec<TensorOf<S>>,
    l: usize,
    n: usize,
}

fn fixture<S: Scalar>(
    group: Group,
    n: usize,
    k: usize,
    l: usize,
    budget: usize,
    seed: u64,
) -> Fixture<S> {
    let plans = spanning_plans(group, n, k, l).unwrap();
    let schedule = LayerSchedule::compile_budgeted(group, n, k, l, &plans, budget).unwrap();
    let mut rng = Rng::new(seed);
    let coeffs = rng.gaussian_vec(plans.len());
    let v = TensorOf::<S>::random(n, k, &mut rng);
    let batch = (0..3).map(|_| TensorOf::<S>::random(n, k, &mut rng)).collect();
    Fixture {
        schedule,
        coeffs,
        v,
        batch,
        l,
        n,
    }
}

/// Run every untiled/tiled execute pair on one fixture and assert exact
/// bitwise equality of the outputs (and of every mapped term buffer).
fn check_bitwise<S: Scalar>(group: Group, n: usize, k: usize, l: usize, seed: u64) {
    let fx = fixture::<S>(group, n, k, l, TINY_BUDGET, seed);
    let sched = &fx.schedule;
    let mut arena = PooledArenaOf::<S>::get();
    let label = format!("{group} n={n} k={k} l={l}");

    // Forward: sequential and work-stealing tiled walks against untiled.
    let mut want = TensorOf::<S>::zeros(fx.n, fx.l);
    sched.execute(&fx.v, &fx.coeffs, &mut want, &mut arena).unwrap();
    let mut got = TensorOf::<S>::zeros(fx.n, fx.l);
    sched
        .execute_tiled(&fx.v, &fx.coeffs, &mut got, &mut arena)
        .unwrap();
    assert_eq!(want.data, got.data, "execute_tiled diverged: {label}");
    let mut got_par = TensorOf::<S>::zeros(fx.n, fx.l);
    sched
        .execute_tiled_parallel(&fx.v, &fx.coeffs, &mut got_par, &mut arena)
        .unwrap();
    assert_eq!(
        want.data, got_par.data,
        "execute_tiled_parallel diverged: {label}"
    );

    // Subset walks, partition by partition (the parallel-forward split).
    for classes in sched.cost_partitions(3) {
        let mut want = TensorOf::<S>::zeros(fx.n, fx.l);
        sched
            .execute_subset(&fx.v, &fx.coeffs, &classes, &mut want, &mut arena)
            .unwrap();
        let mut got = TensorOf::<S>::zeros(fx.n, fx.l);
        sched
            .execute_subset_tiled(&fx.v, &fx.coeffs, &classes, &mut got, &mut arena)
            .unwrap();
        assert_eq!(want.data, got.data, "execute_subset_tiled diverged: {label}");
    }

    // Backward-style map walks: every term's buffer must match exactly.
    let mut want_terms: Vec<(usize, Vec<S>)> = Vec::new();
    sched
        .execute_map(&fx.v, &mut arena, |i, bt| {
            want_terms.push((i, bt.data.clone()));
            Ok(())
        })
        .unwrap();
    let mut got_terms: Vec<(usize, Vec<S>)> = Vec::new();
    sched
        .execute_map_tiled(&fx.v, &mut arena, |i, bt| {
            got_terms.push((i, bt.data.clone()));
            Ok(())
        })
        .unwrap();
    assert_eq!(want_terms, got_terms, "execute_map_tiled diverged: {label}");

    // Multi-row walks (the channel layer's fan-out).
    let mut rng = Rng::new(seed ^ 0x5EED);
    let rows: Vec<Vec<f64>> = (0..2).map(|_| rng.gaussian_vec(fx.coeffs.len())).collect();
    let mut want_outs: Vec<TensorOf<S>> =
        (0..2).map(|_| TensorOf::<S>::zeros(fx.n, fx.l)).collect();
    sched
        .execute_multi(&fx.v, &rows, &mut want_outs, &mut arena)
        .unwrap();
    let mut got_outs: Vec<TensorOf<S>> =
        (0..2).map(|_| TensorOf::<S>::zeros(fx.n, fx.l)).collect();
    sched
        .execute_multi_tiled(&fx.v, &rows, &mut got_outs, &mut arena)
        .unwrap();
    for (w, g) in want_outs.iter().zip(&got_outs) {
        assert_eq!(w.data, g.data, "execute_multi_tiled diverged: {label}");
    }

    // Batched walks: pack three items and compare every variant.
    let refs: Vec<&TensorOf<S>> = fx.batch.iter().collect();
    let vb = BatchTensorOf::pack_refs(&refs).unwrap();
    let mut want_b = BatchTensorOf::<S>::zeros(fx.n, fx.l, vb.batch());
    sched
        .execute_batch(&vb, &fx.coeffs, &mut want_b, &mut arena)
        .unwrap();
    let mut got_b = BatchTensorOf::<S>::zeros(fx.n, fx.l, vb.batch());
    sched
        .execute_batch_tiled(&vb, &fx.coeffs, &mut got_b, &mut arena)
        .unwrap();
    for b in 0..vb.batch() {
        assert_eq!(
            want_b.item(b),
            got_b.item(b),
            "execute_batch_tiled diverged: {label} item {b}"
        );
    }

    let mut want_bm: Vec<(usize, Vec<S>)> = Vec::new();
    sched
        .execute_batch_map(&vb, &mut arena, |i, bt| {
            for b in 0..bt.batch() {
                want_bm.push((i, bt.item(b).to_vec()));
            }
            Ok(())
        })
        .unwrap();
    let mut got_bm: Vec<(usize, Vec<S>)> = Vec::new();
    sched
        .execute_batch_map_tiled(&vb, &mut arena, |i, bt| {
            for b in 0..bt.batch() {
                got_bm.push((i, bt.item(b).to_vec()));
            }
            Ok(())
        })
        .unwrap();
    assert_eq!(want_bm, got_bm, "execute_batch_map_tiled diverged: {label}");

    let mut want_bo: Vec<BatchTensorOf<S>> = (0..2)
        .map(|_| BatchTensorOf::<S>::zeros(fx.n, fx.l, vb.batch()))
        .collect();
    sched
        .execute_batch_multi(&vb, &rows, &mut want_bo, &mut arena)
        .unwrap();
    let mut got_bo: Vec<BatchTensorOf<S>> = (0..2)
        .map(|_| BatchTensorOf::<S>::zeros(fx.n, fx.l, vb.batch()))
        .collect();
    sched
        .execute_batch_multi_tiled(&vb, &rows, &mut got_bo, &mut arena)
        .unwrap();
    for (w, g) in want_bo.iter().zip(&got_bo) {
        for b in 0..vb.batch() {
            assert_eq!(
                w.item(b),
                g.item(b),
                "execute_batch_multi_tiled diverged: {label} item {b}"
            );
        }
    }
}

#[test]
fn tiled_matches_untiled_bitwise_f64() {
    let _g = lock();
    for (i, (group, n, k, l)) in shapes().into_iter().enumerate() {
        check_bitwise::<f64>(group, n, k, l, 0x71AE + i as u64);
    }
}

#[test]
fn tiled_matches_untiled_bitwise_f32() {
    let _g = lock();
    for (i, (group, n, k, l)) in shapes().into_iter().enumerate() {
        check_bitwise::<f32>(group, n, k, l, 0xF32 + i as u64);
    }
}

/// A single (1,3)-partition diagram whose Step-1 runs two consecutive
/// single-axis contractions before the transfer: `{o1,i1}, {i2}, {i3}`.
/// Compiled alone (no cross-diagram CSE) this is a guaranteed slab-local
/// chain ending at an order-1 node, so streaming engages deterministically
/// under a tiny budget.
fn chain_schedule(n: usize, budget: usize) -> LayerSchedule {
    let d = Diagram::from_blocks(1, 3, vec![vec![0, 1], vec![2], vec![3]]).unwrap();
    let plan = Arc::new(MultPlan::new(Group::Symmetric, &d, n).unwrap());
    LayerSchedule::compile_budgeted(Group::Symmetric, n, 3, 1, &[plan], budget).unwrap()
}

#[test]
fn tiny_budget_actually_streams_chains() {
    let _g = lock();
    let n = 4;
    let sched = chain_schedule(n, TINY_BUDGET);
    assert!(
        sched.stats().tiled_chains > 0,
        "the planner must tile a two-contraction chain"
    );
    assert_eq!(sched.tile_budget_bytes(), TINY_BUDGET);
    let mut rng = Rng::new(7);
    let v = TensorOf::<f64>::random(n, 3, &mut rng);
    let mut arena = PooledArenaOf::<f64>::get();
    let mut want = TensorOf::<f64>::zeros(n, 1);
    sched.execute(&v, &[1.0], &mut want, &mut arena).unwrap();
    let before = exec_stats().tiled_chains;
    let mut got = TensorOf::<f64>::zeros(n, 1);
    sched.execute_tiled(&v, &[1.0], &mut got, &mut arena).unwrap();
    assert!(
        exec_stats().tiled_chains > before,
        "a {TINY_BUDGET}-byte budget must stream the chain tile by tile"
    );
    assert_eq!(want.data, got.data, "streamed chain diverged from untiled");
}

#[test]
fn under_budget_shapes_skip_tiling_entirely() {
    let _g = lock();
    // A 1 MiB budget dwarfs every n=4 k=3 intermediate, so the tiled entry
    // points must fall through to the plain walk and pay zero overhead.
    let fx = fixture::<f64>(Group::Symmetric, 4, 3, 2, 1 << 20, 11);
    let mut arena = PooledArenaOf::<f64>::get();
    let mut want = TensorOf::<f64>::zeros(fx.n, fx.l);
    fx.schedule
        .execute(&fx.v, &fx.coeffs, &mut want, &mut arena)
        .unwrap();
    let before = exec_stats().tiled_chains;
    let mut got = TensorOf::<f64>::zeros(fx.n, fx.l);
    fx.schedule
        .execute_tiled(&fx.v, &fx.coeffs, &mut got, &mut arena)
        .unwrap();
    assert_eq!(want.data, got.data);
    assert_eq!(
        exec_stats().tiled_chains,
        before,
        "an under-budget shape must not walk any tiles"
    );
}

#[test]
fn zero_budget_disables_streaming() {
    let _g = lock();
    let fx = fixture::<f64>(Group::Symmetric, 4, 3, 2, 0, 13);
    let mut arena = PooledArenaOf::<f64>::get();
    let mut want = TensorOf::<f64>::zeros(fx.n, fx.l);
    fx.schedule
        .execute(&fx.v, &fx.coeffs, &mut want, &mut arena)
        .unwrap();
    let before = exec_stats().tiled_chains;
    let mut got = TensorOf::<f64>::zeros(fx.n, fx.l);
    fx.schedule
        .execute_tiled(&fx.v, &fx.coeffs, &mut got, &mut arena)
        .unwrap();
    assert_eq!(want.data, got.data);
    assert_eq!(exec_stats().tiled_chains, before, "budget 0 must mean off");
}

#[test]
fn warm_tiled_walk_allocates_nothing() {
    let _g = lock();
    // Use the deterministic streaming chain so the warm path exercises the
    // stage ping-pong buffers, then a full spanning-set schedule so node
    // buffers and index scratch are covered too.
    let chain = chain_schedule(4, TINY_BUDGET);
    let fx = fixture::<f64>(Group::Symmetric, 4, 3, 2, TINY_BUDGET, 17);
    let mut arena = PooledArenaOf::<f64>::get();
    let mut out1 = TensorOf::<f64>::zeros(4, 1);
    let mut out = TensorOf::<f64>::zeros(fx.n, fx.l);
    // Warm the arena: stage buffers, node buffers, and index scratch all
    // reach steady-state capacity within a few walks.
    for _ in 0..3 {
        chain.execute_tiled(&fx.v, &[1.0], &mut out1, &mut arena).unwrap();
        fx.schedule
            .execute_tiled(&fx.v, &fx.coeffs, &mut out, &mut arena)
            .unwrap();
    }
    let warm = arena_stats();
    for _ in 0..5 {
        chain.execute_tiled(&fx.v, &[1.0], &mut out1, &mut arena).unwrap();
        fx.schedule
            .execute_tiled(&fx.v, &fx.coeffs, &mut out, &mut arena)
            .unwrap();
    }
    let after = arena_stats();
    assert_eq!(
        warm.allocations, after.allocations,
        "warm tiled walks must reuse every stage/node buffer"
    );
    assert_eq!(
        warm.index_allocations, after.index_allocations,
        "warm tiled walks must reuse all index scratch"
    );
}

#[test]
fn tiled_peak_arena_at_least_halves_on_chain_heavy_shapes() {
    let _g = lock();
    // A three-contraction chain at n=6: the untiled walk must hold the
    // order-3 (216-element) and order-2 (36-element) intermediates at
    // once, while the tiled walk holds only span-sized stage slabs plus
    // the order-1 output.
    let n = 6;
    let d = Diagram::from_blocks(1, 4, vec![vec![0, 1], vec![2], vec![3], vec![4]]).unwrap();
    let plan = Arc::new(MultPlan::new(Group::Symmetric, &d, n).unwrap());
    let sched =
        LayerSchedule::compile_budgeted(Group::Symmetric, n, 4, 1, &[plan], 512).unwrap();
    assert!(sched.stats().tiled_chains > 0, "chain must be tiled");
    let mut rng = Rng::new(19);
    let v = TensorOf::<f64>::random(n, 4, &mut rng);
    let mut arena = PooledArenaOf::<f64>::get();
    let mut out = TensorOf::<f64>::zeros(n, 1);
    // Warm both paths first so the peaks measure resident bytes, not
    // first-touch allocation order.
    sched.execute(&v, &[1.0], &mut out, &mut arena).unwrap();
    sched.execute_tiled(&v, &[1.0], &mut out, &mut arena).unwrap();
    reset_arena_peak();
    sched.execute(&v, &[1.0], &mut out, &mut arena).unwrap();
    let peak_untiled = arena_peak_bytes();
    reset_arena_peak();
    sched.execute_tiled(&v, &[1.0], &mut out, &mut arena).unwrap();
    let peak_tiled = arena_peak_bytes();
    assert!(
        peak_tiled * 2 <= peak_untiled,
        "tiled walk peak {peak_tiled} B must be at most half of untiled {peak_untiled} B"
    );
}
