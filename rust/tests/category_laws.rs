//! The monoidal-functor laws of Section 4 as executable properties:
//! functoriality (Θ(d₂ • d₁) = Θ(d₂)Θ(d₁) with the n^c scalar),
//! monoidality (Θ(d₁ ⊗ d₂) = Θ(d₁) ⊗ Θ(d₂)), the interchange law
//! (eq. 43), and strictness of the unit.

use equidiag::diagram::{compose, tensor_product, Diagram};
use equidiag::fastmult::Group;
use equidiag::functor::materialize;
use equidiag::linalg::Matrix;
use equidiag::util::prop::{check, Config};

fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows * b.rows, a.cols * b.cols);
    for i in 0..a.rows {
        for j in 0..a.cols {
            let v = a.get(i, j);
            if v == 0.0 {
                continue;
            }
            for p in 0..b.rows {
                for q in 0..b.cols {
                    out.set(i * b.rows + p, j * b.cols + q, v * b.get(p, q));
                }
            }
        }
    }
    out
}

fn scaled(m: &Matrix, s: f64) -> Matrix {
    let mut out = m.clone();
    for x in &mut out.data {
        *x *= s;
    }
    out
}

#[test]
fn theta_functoriality_property() {
    check(Config::default().cases(80), "Θ functorial", |rng| {
        let n = 2 + rng.below(2);
        let k = rng.below(3);
        let l = rng.below(3);
        let m = rng.below(3);
        let d1 = Diagram::random_partition(l, k, rng); // k -> l
        let d2 = Diagram::random_partition(m, l, rng); // l -> m
        let m1 = materialize(Group::Symmetric, &d1, n).map_err(|e| e.to_string())?;
        let m2 = materialize(Group::Symmetric, &d2, n).map_err(|e| e.to_string())?;
        let prod = m2.matmul(&m1).map_err(|e| e.to_string())?;
        let c = compose(&d2, &d1).map_err(|e| e.to_string())?;
        let mc =
            materialize(Group::Symmetric, &c.diagram, n).map_err(|e| e.to_string())?;
        let want = scaled(&mc, (n as f64).powi(c.removed_components as i32));
        if prod.max_abs_diff(&want) < 1e-9 {
            Ok(())
        } else {
            Err(format!("Θ({d2} • {d1}) != Θ(d2)Θ(d1)"))
        }
    });
}

#[test]
fn theta_monoidality_property() {
    check(Config::default().cases(60), "Θ monoidal", |rng| {
        let n = 2;
        let d1 = Diagram::random_partition(rng.below(3), rng.below(3), rng);
        let d2 = Diagram::random_partition(rng.below(3), rng.below(3), rng);
        let m1 = materialize(Group::Symmetric, &d1, n).map_err(|e| e.to_string())?;
        let m2 = materialize(Group::Symmetric, &d2, n).map_err(|e| e.to_string())?;
        let t = tensor_product(&d1, &d2);
        let mt = materialize(Group::Symmetric, &t, n).map_err(|e| e.to_string())?;
        let want = kron(&m1, &m2);
        if mt.max_abs_diff(&want) < 1e-12 {
            Ok(())
        } else {
            Err(format!("Θ({d1} ⊗ {d2}) != Θ(d1) ⊗ Θ(d2)"))
        }
    });
}

#[test]
fn x_functor_monoidality_on_brauer() {
    check(Config::default().cases(40), "X monoidal", |rng| {
        let n = 2;
        let mk = |rng: &mut equidiag::util::Rng| {
            let l = rng.below(3);
            let k = if l % 2 == 0 { 2 * rng.below(2) } else { 1 + 2 * rng.below(1) };
            Diagram::random_brauer(l, k, rng)
        };
        let (d1, d2) = match (mk(rng), mk(rng)) {
            (Ok(a), Ok(b)) => (a, b),
            _ => return Ok(()),
        };
        let m1 = materialize(Group::Symplectic, &d1, n).map_err(|e| e.to_string())?;
        let m2 = materialize(Group::Symplectic, &d2, n).map_err(|e| e.to_string())?;
        let t = tensor_product(&d1, &d2);
        let mt = materialize(Group::Symplectic, &t, n).map_err(|e| e.to_string())?;
        let want = kron(&m1, &m2);
        if mt.max_abs_diff(&want) < 1e-12 {
            Ok(())
        } else {
            Err(format!("X({d1} ⊗ {d2}) != X(d1) ⊗ X(d2)"))
        }
    });
}

/// The interchange law (eq. 43) at the diagram level:
/// (1 ⊗ g) • (f ⊗ 1) = f ⊗ g for composable shapes.
#[test]
fn interchange_law() {
    check(Config::default().cases(60), "interchange", |rng| {
        let f = Diagram::random_partition(rng.below(3), rng.below(3), rng); // a -> b
        let g = Diagram::random_partition(rng.below(3), rng.below(3), rng); // c -> d
        let id_b = Diagram::identity(f.l);
        let id_c = Diagram::identity(g.k);
        // top: 1_b ⊗ g : b + c -> b + d ; bottom: f ⊗ 1_c : a + c -> b + c
        let top = tensor_product(&id_b, &g);
        let bottom = tensor_product(&f, &id_c);
        let lhs = compose(&top, &bottom).map_err(|e| e.to_string())?;
        let want = tensor_product(&f, &g);
        if lhs.removed_components == 0 && lhs.diagram == want {
            Ok(())
        } else {
            Err(format!("interchange failed for f={f}, g={g}"))
        }
    });
}

/// The unit object is strict: tensoring with the empty diagram is identity.
#[test]
fn unit_strictness() {
    check(Config::default().cases(40), "unit", |rng| {
        let d = Diagram::random_partition(rng.below(4), rng.below(4), rng);
        let unit = Diagram::from_blocks(0, 0, vec![]).map_err(|e| e.to_string())?;
        if tensor_product(&d, &unit) == d && tensor_product(&unit, &d) == d {
            Ok(())
        } else {
            Err(format!("unit not strict for {d}"))
        }
    });
}

/// Composing with permutation diagrams only permutes rows/columns; the n^c
/// scalar never appears (no closed middle components).
#[test]
fn permutations_compose_freely() {
    check(Config::default().cases(60), "perm compose", |rng| {
        let k = 1 + rng.below(4);
        let d = Diagram::random_partition(1 + rng.below(3), k, rng);
        let sigma = Diagram::permutation(&rng.permutation(k));
        let c = compose(&d, &sigma).map_err(|e| e.to_string())?;
        if c.removed_components == 0 {
            Ok(())
        } else {
            Err("permutation composition created middle components".into())
        }
    });
}
