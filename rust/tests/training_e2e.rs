//! End-to-end training integration: multi-layer equivariant networks learn
//! invariant/equivariant targets through the fast path, the loss curve
//! decreases, and the trained model generalises to permuted inputs.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{train, Activation, Adam, EquivariantNet, Loss, Sgd, TrainConfig};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;

/// Learn the row-sum map A ↦ A·1 (an S_n-equivariant order-2 → order-1
/// target in the diagram span).
#[test]
fn learns_equivariant_row_sum() {
    let n = 4;
    let mut rng = Rng::new(901);
    let mut net = EquivariantNet::new(
        Group::Symmetric,
        n,
        &[2, 1],
        Activation::Identity,
        Init::Normal(0.1),
        &mut rng,
    )
    .unwrap();
    let data: Vec<(Tensor, Tensor)> = (0..64)
        .map(|_| {
            let x = Tensor::random(n, 2, &mut rng);
            let mut y = Tensor::zeros(n, 1);
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += x.get(&[i, j]);
                }
                y.set(&[i], s);
            }
            (x, y)
        })
        .collect();
    let mut opt = Adam::new(0.05);
    let report = train(
        &mut net,
        &data,
        &mut opt,
        &TrainConfig {
            steps: 400,
            batch_size: 8,
            ..TrainConfig::default()
        },
    )
    .unwrap();
    assert!(
        report.final_loss(20) < 1e-4,
        "row-sum not learned: final loss {}",
        report.final_loss(20)
    );
    // Generalisation: a fresh input, permuted — prediction must permute.
    let x = Tensor::random(n, 2, &mut rng);
    let g = equidiag::groups::sample(Group::Symmetric, n, &mut rng).unwrap();
    let a = net.forward(&equidiag::groups::rho(&g, &x)).unwrap();
    let b = equidiag::groups::rho(&g, &net.forward(&x).unwrap());
    assert!(a.allclose(&b, 1e-8));
}

/// A deep S_n net with ReLU fits an invariant polynomial target
/// (number-of-equal-neighbour-ish second moment).
#[test]
fn deep_net_fits_invariant_target() {
    let n = 3;
    let mut rng = Rng::new(902);
    let mut net = EquivariantNet::new(
        Group::Symmetric,
        n,
        &[2, 2, 0],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap();
    let data: Vec<(Tensor, Tensor)> = (0..64)
        .map(|_| {
            let x = Tensor::random(n, 2, &mut rng);
            // target: tr(A) + 0.5 * sum(A)
            let mut tr = 0.0;
            for i in 0..n {
                tr += x.get(&[i, i]);
            }
            let s: f64 = x.data.iter().sum();
            (x, Tensor::from_vec(n, 0, vec![tr + 0.5 * s]).unwrap())
        })
        .collect();
    let mut opt = Adam::new(0.02);
    let report = train(
        &mut net,
        &data,
        &mut opt,
        &TrainConfig {
            steps: 500,
            batch_size: 8,
            ..TrainConfig::default()
        },
    )
    .unwrap();
    let initial = report.losses[..10].iter().sum::<f64>() / 10.0;
    let fin = report.final_loss(20);
    assert!(fin < initial * 0.01, "initial {initial}, final {fin}");
}

/// O(n) layers trained with SGD on an invariant target (squared norm
/// projection onto the Brauer span).
#[test]
fn orthogonal_net_trains_with_sgd() {
    let n = 3;
    let mut rng = Rng::new(903);
    let mut net = EquivariantNet::new(
        Group::Orthogonal,
        n,
        &[2, 2],
        Activation::Identity,
        Init::Normal(0.1),
        &mut rng,
    )
    .unwrap();
    // Target: the Brauer-span map A ↦ 2·A + tr(A)·I.
    let data: Vec<(Tensor, Tensor)> = (0..32)
        .map(|_| {
            let x = Tensor::random(n, 2, &mut rng);
            let mut tr = 0.0;
            for i in 0..n {
                tr += x.get(&[i, i]);
            }
            let mut y = x.clone();
            y.scale(2.0);
            for i in 0..n {
                let v = y.get(&[i, i]) + tr;
                y.set(&[i, i], v);
            }
            (x, y)
        })
        .collect();
    let mut opt = Sgd::new(0.05, 0.9);
    let report = train(
        &mut net,
        &data,
        &mut opt,
        &TrainConfig {
            steps: 400,
            batch_size: 8,
            loss: Loss::Mse,
            ..TrainConfig::default()
        },
    )
    .unwrap();
    assert!(
        report.final_loss(20) < 1e-5,
        "final loss {}",
        report.final_loss(20)
    );
}

/// Loss curves are recorded at the configured cadence (the artifact the
/// e2e example logs into EXPERIMENTS.md).
#[test]
fn loss_curve_shape() {
    let mut rng = Rng::new(904);
    let mut net = EquivariantNet::new(
        Group::Symmetric,
        2,
        &[1, 0],
        Activation::Identity,
        Init::Normal(0.1),
        &mut rng,
    )
    .unwrap();
    let data = vec![(
        Tensor::from_vec(2, 1, vec![1.0, -1.0]).unwrap(),
        Tensor::from_vec(2, 0, vec![0.25]).unwrap(),
    )];
    let mut opt = Adam::new(0.05);
    let report = train(
        &mut net,
        &data,
        &mut opt,
        &TrainConfig {
            steps: 120,
            batch_size: 1,
            log_every: 0,
            ..TrainConfig::default()
        },
    )
    .unwrap();
    assert_eq!(report.losses.len(), 120);
    assert!(report.final_loss(10) < report.losses[0]);
}
