//! Launcher integration: drive the `equidiag` binary end to end — train
//! with a config file, save a checkpoint, serve with it loaded, inspect
//! basis counts — the full workflow a user runs.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_equidiag"))
}

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("equidiag-cli-{}-{name}", std::process::id()));
    p
}

#[test]
fn help_and_unknown_command() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let bad = bin().arg("frobnicate").output().unwrap();
    assert!(!bad.status.success());
}

#[test]
fn basis_prints_closed_forms() {
    let out = bin()
        .args(["basis", "--group", "sn", "--n", "2", "--k", "2", "--l", "2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("spanning-set size: 8"), "{text}");
    assert!(text.contains("B(l+k, n) = 8"), "{text}");
}

#[test]
fn bench_command_runs() {
    let out = bin()
        .args(["bench", "--group", "on", "--n", "4", "--k", "2", "--l", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fast (Algorithm 1)"), "{text}");
    assert!(text.contains("results agree"), "{text}");
}

#[test]
fn train_save_then_serve_load() {
    let cfg = tmp("train.toml");
    std::fs::write(
        &cfg,
        r#"
[network]
group = "sn"
n = 4
orders = [2, 0]
activation = "identity"
seed = 3

[training]
steps = 30
batch_size = 4
lr = 0.05
optimizer = "adam"
log_every = 0

[server]
workers = 2
max_batch = 4
batch_window_us = 100
queue_capacity = 64
"#,
    )
    .unwrap();
    let ckpt = tmp("model.ckpt");
    let out = bin()
        .args([
            "train",
            "--config",
            cfg.to_str().unwrap(),
            "--save",
            ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(ckpt.exists());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("final loss"), "{text}");

    let out = bin()
        .args([
            "serve",
            "--config",
            cfg.to_str().unwrap(),
            "--load",
            ckpt.to_str().unwrap(),
            "--requests",
            "20",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("loaded checkpoint"), "{text}");
    assert!(text.contains("completed 20"), "{text}");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn bad_config_fails_cleanly() {
    let cfg = tmp("bad.toml");
    std::fs::write(&cfg, "[network]\ngroup = \"u(1)\"\n").unwrap();
    let out = bin()
        .args(["train", "--config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown group"), "{err}");
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn repo_configs_parse() {
    // The shipped configs must stay loadable.
    for name in ["sn_graph.toml", "serve.toml", "on_covariance.toml"] {
        let path = format!("{}/configs/{name}", env!("CARGO_MANIFEST_DIR"));
        let text = std::fs::read_to_string(&path).unwrap();
        equidiag::config::AppConfig::from_text(&text)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}
