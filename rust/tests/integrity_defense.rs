//! Silent-failure defense integration suite
//! (`docs/serving_robustness.md`, "Integrity, watchdog & brownout"):
//! seeded bit-flips are caught by sampled shadow verification and the
//! suspect schedules quarantined + recompiled; the numeric canary turns a
//! NaN answer into a typed fault while its batch-mates survive; the
//! hung-batch watchdog frees a wedged slot (waiters resolve with
//! [`Error::BatchStuck`], the slot respawns); and the memory-pressure
//! brownout engages and recovers deterministically under a tiny arena
//! budget. Run by name in CI (`cargo test --test integrity_defense`).

use equidiag::config::ServerConfig;
use equidiag::coordinator::{ChaosPlan, Coordinator, CoordinatorHandle, MetricsSnapshot, ModelKind};
use equidiag::error::Error;
use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::{Precision, Tensor};
use equidiag::util::Rng;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// The defenses poke process-global state (the plan cache's quarantine
/// counters, arena watermarks, the executor); serialise every test in
/// this binary so each one's metric deltas are attributable.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn test_net(rng: &mut Rng, act: Activation) -> EquivariantNet {
    EquivariantNet::new(Group::Symmetric, 4, &[2, 2], act, Init::ScaledNormal, rng).unwrap()
}

/// Poll the coordinator's metrics until `pred` holds or `timeout`
/// passes (shadow verification and the supervisor sweeps are
/// asynchronous); returns the last snapshot either way.
fn wait_for(
    handle: &CoordinatorHandle,
    timeout: Duration,
    pred: impl Fn(&MetricsSnapshot) -> bool,
) -> MetricsSnapshot {
    let deadline = Instant::now() + timeout;
    loop {
        let snap = handle.metrics();
        if pred(&snap) || Instant::now() >= deadline {
            return snap;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Shadow verification on clean traffic never false-positives — at
/// either serving precision — and on fully bit-flipped traffic catches
/// every corrupted response, quarantining and recompiling the route's
/// schedules and flagging the model degraded.
#[test]
fn bit_flips_caught_clean_traffic_untouched() {
    let _g = lock();
    let mut rng = Rng::new(911);
    // Clean phase: every response is verified, none may mismatch.
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(100),
        queue_capacity: 64,
        verify_per_mille: 1000,
        ..ServerConfig::default()
    });
    coord.register("clean64", ModelKind::net(test_net(&mut rng, Activation::Relu)));
    coord.register(
        "clean32",
        ModelKind::net_with_precision(test_net(&mut rng, Activation::Relu), Precision::F32),
    );
    let handle = coord.start();
    for _ in 0..10 {
        handle.infer("clean64", Tensor::random(4, 2, &mut rng)).unwrap();
        handle.infer("clean32", Tensor::random(4, 2, &mut rng)).unwrap();
    }
    let snap = wait_for(&handle, Duration::from_secs(30), |s| {
        s.shadow_verifications >= 20
    });
    assert_eq!(snap.shadow_verifications, 20, "every response sampled");
    assert_eq!(snap.integrity_mismatches, 0, "clean traffic false positive");
    assert_eq!(snap.degraded_models, 0);
    handle.shutdown();

    // Corrupt phase: the chaos wrapper silently flips one output element
    // of every call; the serving path still answers Ok, so only the
    // shadow oracle can catch it.
    let plan = Arc::new(ChaosPlan::new(11).with_bit_flips(1000));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(100),
        queue_capacity: 64,
        verify_per_mille: 1000,
        ..ServerConfig::default()
    });
    coord.register(
        "corrupt",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng, Activation::Relu)), plan.clone()),
    );
    let handle = coord.start();
    const N: u64 = 10;
    for _ in 0..N {
        // Silent corruption: the request still resolves Ok.
        handle.infer("corrupt", Tensor::random(4, 2, &mut rng)).unwrap();
    }
    let snap = wait_for(&handle, Duration::from_secs(30), |s| {
        s.shadow_verifications >= N
    });
    let (flips, _) = plan.injected_silent();
    assert_eq!(flips, N, "one flip per single-item batch");
    assert_eq!(snap.shadow_verifications, N);
    assert_eq!(snap.integrity_mismatches, N, "every flip detected");
    assert!(snap.schedule_quarantines >= 1, "suspect schedules evicted");
    assert!(
        snap.schedule_recompiles >= 2,
        "both layers recompiled after quarantine"
    );
    assert_eq!(snap.degraded_models, 1);
    handle.shutdown();
}

/// The numeric canary converts a NaN answer into a typed
/// [`Error::NumericFault`] at the output boundary while the finite
/// batch-mates still get real responses.
#[test]
fn numeric_canary_trips_and_batch_mates_survive() {
    let _g = lock();
    let mut rng = Rng::new(912);
    // Identity activations so the poisoned input's NaN propagates to the
    // output instead of being absorbed by a max().
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 8,
        batch_window: Duration::from_millis(5),
        queue_capacity: 64,
        numeric_guard: true,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(test_net(&mut rng, Activation::Identity)));
    let handle = coord.start();
    let mut poisoned = Tensor::random(4, 2, &mut rng);
    poisoned.data[0] = f64::NAN;
    let rx_bad = handle.submit("m", poisoned).unwrap();
    let healthy: Vec<_> = (0..3)
        .map(|_| handle.submit("m", Tensor::random(4, 2, &mut rng)).unwrap())
        .collect();
    match rx_bad.recv_timeout(Duration::from_secs(5)).unwrap() {
        Err(Error::NumericFault(msg)) => assert!(msg.contains("'m'"), "{msg}"),
        other => panic!("expected NumericFault, got {other:?}"),
    }
    for rx in healthy {
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
    let snap = handle.metrics();
    assert_eq!(snap.numeric_faults, 1);
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.failed, 1);
    handle.shutdown();
}

/// A wedged batch (30s injected stall, far past the watchdog threshold)
/// is reaped: its waiter resolves with the typed [`Error::BatchStuck`]
/// instead of hanging, the slot is respawned, and the pool keeps serving
/// a healthy route. Shutdown stays prompt because the chaos sleep is
/// cancelled and sliced.
#[test]
fn watchdog_frees_wedged_slot_and_pool_keeps_serving() {
    let _g = lock();
    let mut rng = Rng::new(913);
    let plan = Arc::new(ChaosPlan::new(13).with_long_stalls(1000, Duration::from_secs(30)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        request_timeout: Some(Duration::from_millis(150)),
        watchdog_factor: 4.0,
        ..ServerConfig::default()
    });
    coord.register(
        "wedged",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng, Activation::Relu)), plan),
    );
    coord.register("ok", ModelKind::net(test_net(&mut rng, Activation::Relu)));
    let handle = coord.start();
    // No batch has executed yet, so the watchdog threshold floors at the
    // 150ms request timeout — far under the 30s stall.
    let rx = handle
        .submit("wedged", Tensor::random(4, 2, &mut rng))
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
        Err(Error::BatchStuck) => {}
        other => panic!("expected BatchStuck, got {other:?}"),
    }
    let snap = wait_for(&handle, Duration::from_secs(5), |s| {
        s.watchdog_kills >= 1 && s.worker_restarts >= 1
    });
    assert_eq!(snap.watchdog_kills, 1);
    assert!(
        snap.worker_restarts >= 1,
        "superseded slot must be respawned"
    );
    // The respawned pool still serves the healthy route while the zombie
    // sleeps out its stall.
    for _ in 0..5 {
        handle.infer("ok", Tensor::random(4, 2, &mut rng)).unwrap();
    }
    assert_eq!(handle.metrics().completed, 5);
    let t0 = Instant::now();
    handle.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown must cancel the injected stall, not wait it out"
    );
}

/// Under a 1-byte arena budget, sustained traffic engages the brownout
/// (every supervisor tick observes over-budget activity), served answers
/// stay correct to f32 rounding, and stopping the traffic recovers the
/// machine to Normal after its sustained under-budget window.
#[test]
fn brownout_engages_and_recovers_under_tiny_budget() {
    let _g = lock();
    let mut rng = Rng::new(914);
    let net = test_net(&mut rng, Activation::Relu);
    let reference = net.clone();
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 4,
        batch_window: Duration::from_micros(100),
        queue_capacity: 64,
        arena_budget_bytes: Some(1),
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(net));
    let handle = coord.start();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut engaged = None;
    while Instant::now() < deadline {
        let v = Tensor::random(4, 2, &mut rng);
        let got = handle.infer("m", v.clone()).unwrap();
        let want = reference.forward(&v).unwrap();
        // Browned-out answers are f32-rounded at worst.
        assert!(
            got.allclose(&want, 1e-3),
            "served answer drifted: {}",
            got.max_abs_diff(&want)
        );
        let snap = handle.metrics();
        if snap.brownout_state >= 1 {
            engaged = Some(snap);
            break;
        }
    }
    let snap = engaged.expect("brownout never engaged under sustained over-budget traffic");
    assert!(snap.brownout_engagements >= 1);
    assert!(snap.brownout_state >= 1);
    assert_ne!(snap.brownout_state_name(), "normal");
    // Traffic stopped: the arena footprint falls under budget and the
    // hysteresis recovers to Normal after its sustained window.
    let snap = wait_for(&handle, Duration::from_secs(30), |s| {
        s.brownout_state == 0 && s.brownout_recoveries >= 1
    });
    assert_eq!(snap.brownout_state, 0);
    assert_eq!(snap.brownout_state_name(), "normal");
    assert!(snap.brownout_recoveries >= 1);
    // Full-fidelity serving resumes.
    let v = Tensor::random(4, 2, &mut rng);
    let got = handle.infer("m", v.clone()).unwrap();
    assert!(got.allclose(&reference.forward(&v).unwrap(), 1e-12));
    handle.shutdown();
}
