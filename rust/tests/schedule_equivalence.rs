//! Property tests for the folded execution schedule: for all four groups
//! and random shapes up to the seed test sizes, schedule execution must be
//! (a) accumulation-order-stable — repeated runs are bitwise identical —
//! and (b) numerically equal (≤ 1e-12) to the per-term reference path, for
//! forward and backward, single and batched. (The folded class walk
//! reassociates per-term additions, so fused-vs-per-term is a 1e-12 bound,
//! not bitwise; the per-term tensors of the backward map walk stay
//! bitwise.) The forward paths run through the unified
//! [`EquivariantLinear::apply`] entry point; the full four-group
//! forward/backward matrix is additionally pinned under both scalar types
//! (`f64` bitwise against the legacy names, `f32` within the scaled
//! [`Scalar::TOLERANCE`]).

use equidiag::fastmult::{Group, PlanCache, ScratchArena};
use equidiag::layer::{transpose_sign, EquivariantLinear, Init};
use equidiag::tensor::{Scalar, Tensor, TensorOf};
use equidiag::util::prop::{check, Config};
use equidiag::util::Rng;

fn random_group(rng: &mut Rng) -> Group {
    match rng.below(4) {
        0 => Group::Symmetric,
        1 => Group::Orthogonal,
        2 => Group::SpecialOrthogonal,
        _ => Group::Symplectic,
    }
}

/// Random `(n, k, l)` within the seed test sizes (k + l bounded so S_n
/// spanning sets stay enumerable in a property loop).
fn random_shape(group: Group, rng: &mut Rng) -> (usize, usize, usize) {
    let n = if group == Group::Symplectic {
        2 * (1 + rng.below(2)) // 2 or 4
    } else {
        2 + rng.below(3) // 2..4
    };
    let k = 1 + rng.below(3); // 1..=3
    let max_l = 3usize.min(5 - k); // keep k + l <= 5
    let l = 1 + rng.below(max_l);
    (n, k, l)
}

/// Property: the folded forward equals the per-term reference to ≤ 1e-12
/// (class folding reassociates additions, nothing more), re-running it is
/// bitwise stable, and the compile-time stats never regress against the
/// prefix-sharing baseline.
#[test]
fn prop_folded_forward_is_stable_and_equal_to_per_term() {
    check(
        Config::default().cases(32).seed(0x5CED0),
        "schedule forward == per-term forward (1e-12, bitwise-stable)",
        |rng| {
            let group = random_group(rng);
            let (n, k, l) = random_shape(group, rng);
            let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng)
                .map_err(|e| e.to_string())?;
            let v = Tensor::random(n, k, rng);
            let fused = layer
                .apply(&v)
                .map_err(|e| e.to_string())?
                .into_single()
                .expect("single input yields single output");
            let reference = layer.forward_per_term(&v).map_err(|e| e.to_string())?;
            if !fused.allclose(&reference, 1e-12) {
                return Err(format!(
                    "group {group} n={n} ({k},{l}): folded differs from per-term by {}",
                    fused.max_abs_diff(&reference)
                ));
            }
            let again = layer
                .apply(&v)
                .map_err(|e| e.to_string())?
                .into_single()
                .expect("single input yields single output");
            if fused.max_abs_diff(&again) != 0.0 {
                return Err(format!(
                    "group {group} n={n} ({k},{l}): forward is not run-to-run stable"
                ));
            }
            let stats = layer.schedule_stats();
            if stats.nodes > stats.prefix_nodes {
                return Err(format!(
                    "group {group} n={n} ({k},{l}): global CSE produced more nodes \
                     than prefix sharing: {stats:?}"
                ));
            }
            if stats.classes > stats.terms {
                return Err(format!(
                    "group {group} n={n} ({k},{l}): more classes than terms: {stats:?}"
                ));
            }
            Ok(())
        },
    );
}

/// Property: the batched paths (both the multi-item fan-out and the
/// single-item subtree-parallel path) stay within 1e-12 of the per-term
/// reference — only the batch-shared bias and subtree partial sums may
/// reassociate.
#[test]
fn prop_batched_forward_within_1e12_of_per_term() {
    check(
        Config::default().cases(24).seed(0x5CED1),
        "forward_batch within 1e-12 of per-term forward",
        |rng| {
            let group = random_group(rng);
            let (n, k, l) = random_shape(group, rng);
            let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng)
                .map_err(|e| e.to_string())?;
            let batch = 1 + rng.below(5); // 1..5 — exercises both paths
            let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, rng)).collect();
            let batched = layer.apply(&inputs).map_err(|e| e.to_string())?.into_vec();
            for (i, (v, b)) in inputs.iter().zip(&batched).enumerate() {
                let want = layer.forward_per_term(v).map_err(|e| e.to_string())?;
                if !want.allclose(b, 1e-12) {
                    return Err(format!(
                        "group {group} n={n} ({k},{l}) batch={batch} item {i}: diff {}",
                        want.max_abs_diff(b)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Property: the schedule-driven backward matches a per-term reference
/// (plan-by-plan transposed application) to 1e-12 on both the coefficient
/// gradients and the input gradient, and is bitwise run-to-run stable.
#[test]
fn prop_backward_matches_per_term_reference() {
    check(
        Config::default().cases(24).seed(0x5CED2),
        "schedule backward == per-term backward",
        |rng| {
            let group = random_group(rng);
            let (n, k, l) = random_shape(group, rng);
            let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng)
                .map_err(|e| e.to_string())?;
            let v = Tensor::random(n, k, rng);
            let g = Tensor::random(n, l, rng);
            let mut grads = layer.zero_grads();
            let grad_v = layer.backward(&v, &g, &mut grads).map_err(|e| e.to_string())?;
            // Per-term reference over the transposed plans (the pre-fusion
            // path: one plan apply per term). The bias path is unchanged by
            // fusion, so the weight terms are what we verify here.
            let cache = PlanCache::global();
            let mut want_gv = Tensor::zeros(n, k);
            for (i, d) in layer.diagrams().enumerate() {
                let plan = cache
                    .get_or_build(group, &d.transpose(), n)
                    .map_err(|e| e.to_string())?;
                let bt = plan.apply(&g).map_err(|e| e.to_string())?;
                let sign = transpose_sign(group, d, n);
                let want_coeff = sign * bt.dot(&v);
                if (grads.coeffs[i] - want_coeff).abs() > 1e-12 {
                    return Err(format!(
                        "group {group} n={n} ({k},{l}) coeff {i}: {} vs {want_coeff}",
                        grads.coeffs[i]
                    ));
                }
                let lambda = layer.coeffs[i];
                if lambda != 0.0 {
                    want_gv.axpy(lambda * sign, &bt);
                }
            }
            if !grad_v.allclose(&want_gv, 1e-12) {
                return Err(format!(
                    "group {group} n={n} ({k},{l}): grad_v diff {}",
                    grad_v.max_abs_diff(&want_gv)
                ));
            }
            // Run-to-run stability (accumulation order is deterministic).
            let mut grads2 = layer.zero_grads();
            let grad_v2 = layer
                .backward(&v, &g, &mut grads2)
                .map_err(|e| e.to_string())?;
            if grad_v.max_abs_diff(&grad_v2) != 0.0 {
                return Err("backward is not run-to-run stable".into());
            }
            for (a, b) in grads.coeffs.iter().zip(&grads2.coeffs) {
                if a != b {
                    return Err("coeff grads are not run-to-run stable".into());
                }
            }
            Ok(())
        },
    );
}

/// Property: batched backward equals repeated single backward (summed
/// parameter gradients, ordered input gradients) to 1e-12.
#[test]
fn prop_backward_batch_matches_sequential() {
    check(
        Config::default().cases(16).seed(0x5CED3),
        "backward_batch == sequential backward",
        |rng| {
            let group = random_group(rng);
            let (n, k, l) = random_shape(group, rng);
            let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng)
                .map_err(|e| e.to_string())?;
            let batch = 1 + rng.below(4);
            let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, rng)).collect();
            let gs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, l, rng)).collect();
            let mut want = layer.zero_grads();
            let mut want_gv = Vec::new();
            for (v, g) in inputs.iter().zip(&gs) {
                want_gv.push(layer.backward(v, g, &mut want).map_err(|e| e.to_string())?);
            }
            let mut got = layer.zero_grads();
            let got_gv = layer
                .backward_batch(&inputs, &gs, &mut got)
                .map_err(|e| e.to_string())?;
            for (a, b) in want_gv.iter().zip(&got_gv) {
                if !a.allclose(b, 1e-12) {
                    return Err(format!("grad_v diff {}", a.max_abs_diff(b)));
                }
            }
            for (a, b) in want.coeffs.iter().zip(&got.coeffs) {
                if (a - b).abs() > 1e-12 {
                    return Err(format!("coeff grad {a} vs {b}"));
                }
            }
            for (a, b) in want.bias_coeffs.iter().zip(&got.bias_coeffs) {
                if (a - b).abs() > 1e-12 {
                    return Err(format!("bias grad {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// The acceptance-criterion invariant: once warmed, a forward pass through
/// the schedule performs zero heap allocations as measured by the arena
/// counters. Uses a dedicated arena (not the shared pool) so concurrent
/// tests cannot perturb the count.
#[test]
fn steady_state_forward_is_allocation_free() {
    let mut rng = Rng::new(0x5CED4);
    for group in Group::ALL {
        let n = if group == Group::Symplectic { 4 } else { 3 };
        let mut layer =
            EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        // Zero the bias so the schedule output alone is the full forward.
        for b in &mut layer.bias_coeffs {
            *b = 0.0;
        }
        let v = Tensor::random(n, 2, &mut rng);
        let mut arena = ScratchArena::new();
        let mut out = Tensor::zeros(n, 2);
        // Warm-up pass populates the arena buckets.
        layer
            .schedule()
            .execute(&v, &layer.coeffs, &mut out, &mut arena)
            .unwrap();
        let warm = arena.allocations();
        for _ in 0..5 {
            out.data.fill(0.0);
            layer
                .schedule()
                .execute(&v, &layer.coeffs, &mut out, &mut arena)
                .unwrap();
        }
        assert_eq!(
            arena.allocations(),
            warm,
            "group {group}: steady-state forward allocated"
        );
        // Per-term reference agrees (≤ 1e-12 — the folded walk
        // reassociates), so the allocation-free path is also the correct
        // one.
        let want = layer.forward_per_term(&v).unwrap();
        assert!(
            out.allclose(&want, 1e-12),
            "group {group}: diff {}",
            out.max_abs_diff(&want)
        );
    }
}

/// The full four-group forward/backward matrix under both scalar types:
/// the unified `apply`/`apply_grad` entry points are bitwise identical to
/// the legacy names at `f64` (they are the same code path), and the `f32`
/// instantiation tracks the `f64` reference within the scaled
/// [`Scalar::TOLERANCE`].
#[test]
#[allow(deprecated)] // the legacy names are the bitwise reference here
fn apply_matrix_all_groups_both_precisions() {
    let f32_tol = |reference: &Tensor| {
        let scale = reference.data.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
        64.0 * <f32 as Scalar>::TOLERANCE * scale
    };
    let mut rng = Rng::new(0x5CED6);
    for group in Group::ALL {
        let n = if group == Group::Symplectic { 4 } else { 3 };
        let layer = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        let v = Tensor::random(n, 2, &mut rng);
        let g = Tensor::random(n, 2, &mut rng);
        let inputs: Vec<Tensor> = (0..3).map(|_| Tensor::random(n, 2, &mut rng)).collect();
        let gs: Vec<Tensor> = (0..3).map(|_| Tensor::random(n, 2, &mut rng)).collect();

        // f64 forward: `apply` is bitwise the legacy path, single + batched.
        let want = layer.forward(&v).unwrap();
        let got = layer.apply(&v).unwrap().into_single().unwrap();
        assert!(got.allclose(&want, 0.0), "{group}: f64 apply not bitwise");
        let want_b = layer.forward_batch(&inputs).unwrap();
        let got_b = layer.apply(&inputs).unwrap().into_vec();
        for (a, b) in got_b.iter().zip(&want_b) {
            assert!(a.allclose(b, 0.0), "{group}: f64 batched apply not bitwise");
        }

        // f64 backward: `apply_grad` is bitwise the legacy path.
        let mut want_g1 = layer.zero_grads();
        let want_gv = layer.backward(&v, &g, &mut want_g1).unwrap();
        let mut got_g1 = layer.zero_grads();
        let got_gv = layer
            .apply_grad(&v, &g, &mut got_g1)
            .unwrap()
            .into_single()
            .unwrap();
        assert!(
            got_gv.allclose(&want_gv, 0.0),
            "{group}: f64 apply_grad not bitwise"
        );
        assert_eq!(want_g1.coeffs, got_g1.coeffs, "{group}: coeff grads differ");
        assert_eq!(want_g1.bias_coeffs, got_g1.bias_coeffs);

        let mut want_gb = layer.zero_grads();
        let want_gvs = layer.backward_batch(&inputs, &gs, &mut want_gb).unwrap();
        let mut got_gb = layer.zero_grads();
        let got_gvs = layer
            .apply_grad(&inputs, gs.as_slice(), &mut got_gb)
            .unwrap()
            .into_vec();
        for (a, b) in got_gvs.iter().zip(&want_gvs) {
            assert!(
                a.allclose(b, 0.0),
                "{group}: f64 batched apply_grad not bitwise"
            );
        }
        assert_eq!(want_gb.coeffs, got_gb.coeffs);
        assert_eq!(want_gb.bias_coeffs, got_gb.bias_coeffs);

        // f32: the same matrix within the scaled tolerance.
        let v32 = v.cast::<f32>();
        let g32 = g.cast::<f32>();
        let got32 = layer.apply(&v32).unwrap().into_single().unwrap();
        assert!(
            got32.cast::<f64>().allclose(&want, f32_tol(&want)),
            "{group}: f32 forward diverges by {}",
            got32.cast::<f64>().max_abs_diff(&want)
        );
        let inputs32: Vec<TensorOf<f32>> = inputs.iter().map(|t| t.cast()).collect();
        let got_b32 = layer.apply(&inputs32).unwrap().into_vec();
        for (a, b) in got_b32.iter().zip(&want_b) {
            assert!(
                a.cast::<f64>().allclose(b, f32_tol(b)),
                "{group}: f32 batched forward diverges by {}",
                a.cast::<f64>().max_abs_diff(b)
            );
        }
        let mut grads32 = layer.zero_grads();
        let gv32 = layer
            .apply_grad(&v32, &g32, &mut grads32)
            .unwrap()
            .into_single()
            .unwrap();
        assert!(
            gv32.cast::<f64>().allclose(&want_gv, f32_tol(&want_gv)),
            "{group}: f32 backward diverges by {}",
            gv32.cast::<f64>().max_abs_diff(&want_gv)
        );
        let coeff_scale = want_g1.coeffs.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
        let coeff_tol = 64.0 * <f32 as Scalar>::TOLERANCE * coeff_scale;
        for (i, (a, b)) in grads32.coeffs.iter().zip(&want_g1.coeffs).enumerate() {
            assert!(
                (a - b).abs() <= coeff_tol,
                "{group} coeff {i}: f32 grad {a} vs f64 {b}"
            );
        }
    }
}

/// Schedule compilation is cached: constructing many same-shape layers
/// compiles once and the schedule-cache hit counter climbs.
#[test]
fn schedule_cache_serves_repeat_layer_builds() {
    let mut rng = Rng::new(0x5CED5);
    let before = PlanCache::global().stats();
    let a = EquivariantLinear::new(Group::Orthogonal, 6, 2, 2, Init::Zeros, &mut rng).unwrap();
    let b = EquivariantLinear::new(Group::Orthogonal, 6, 2, 2, Init::Zeros, &mut rng).unwrap();
    let after = PlanCache::global().stats();
    // The second build must be served from the schedule cache (counters are
    // process-global and monotonic, so >= holds under concurrent tests).
    assert!(
        after.schedule_hits >= before.schedule_hits + 2,
        "second layer build should hit the schedule cache"
    );
    assert_eq!(a.schedule_stats(), b.schedule_stats());
}
