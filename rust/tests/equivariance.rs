//! The theorem-level property: every spanning matrix (hence every layer and
//! every network) satisfies eq. (3), `F(d) ρ_k(g) v = ρ_l(g) F(d) v`, for
//! random group elements — per group, via the *fast* path. This validates
//! simultaneously that the functors produce equivariant maps and that
//! Algorithm 1 implements the functors.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::diagram::Diagram;
use equidiag::fastmult::{matrix_mult, Group};
use equidiag::groups;
use equidiag::tensor::Tensor;
use equidiag::util::prop::{check, Config};
use equidiag::util::Rng;

fn equivariance_case(
    group: Group,
    n: usize,
    diagram: &Diagram,
    rng: &mut Rng,
    tol: f64,
) -> Result<(), String> {
    let v = Tensor::random(n, diagram.k, rng);
    let g = groups::sample(group, n, rng).map_err(|e| e.to_string())?;
    let lhs = matrix_mult(group, diagram, &groups::rho(&g, &v)).map_err(|e| e.to_string())?;
    let rhs = groups::rho(&g, &matrix_mult(group, diagram, &v).map_err(|e| e.to_string())?);
    if lhs.allclose(&rhs, tol) {
        Ok(())
    } else {
        Err(format!(
            "equivariance violated for {group} on {diagram}: diff {}",
            lhs.max_abs_diff(&rhs)
        ))
    }
}

#[test]
fn sn_spanning_matrices_are_equivariant() {
    check(Config::default().cases(100), "S_n equivariance", |rng| {
        let n = 2 + rng.below(3);
        let l = rng.below(4);
        let k = rng.below(4);
        let d = Diagram::random_partition(l, k, rng);
        equivariance_case(Group::Symmetric, n, &d, rng, 1e-8)
    });
}

#[test]
fn on_spanning_matrices_are_equivariant() {
    check(Config::default().cases(100), "O(n) equivariance", |rng| {
        let n = 2 + rng.below(3);
        let l = rng.below(4);
        let k = if (l + rng.below(4)) % 2 == 0 { l % 2 } else { 2 - l % 2 };
        let k = k + 2 * rng.below(2);
        if (l + k) % 2 != 0 {
            return Ok(());
        }
        let d = Diagram::random_brauer(l, k, rng).map_err(|e| e.to_string())?;
        equivariance_case(Group::Orthogonal, n, &d, rng, 1e-7)
    });
}

#[test]
fn sp_spanning_matrices_are_equivariant() {
    check(Config::default().cases(100), "Sp(n) equivariance", |rng| {
        let n = 2 + 2 * rng.below(2); // 2 or 4
        let l = rng.below(4);
        let k = (l % 2) + 2 * rng.below(2);
        if (l + k) % 2 != 0 {
            return Ok(());
        }
        let d = Diagram::random_brauer(l, k, rng).map_err(|e| e.to_string())?;
        // Symplectic sampling builds non-orthogonal matrices; tolerance
        // scales with the tensor order.
        equivariance_case(Group::Symplectic, n, &d, rng, 1e-5)
    });
}

#[test]
fn so_jellyfish_matrices_are_equivariant() {
    check(Config::default().cases(60), "SO(n) equivariance", |rng| {
        let n = 2 + rng.below(2); // 2 or 3
        let l = rng.below(4);
        let k = rng.below(4);
        if l + k < n || (l + k - n) % 2 != 0 {
            return Ok(());
        }
        let d = Diagram::random_jellyfish(l, k, n, rng).map_err(|e| e.to_string())?;
        equivariance_case(Group::SpecialOrthogonal, n, &d, rng, 1e-7)
    });
}

/// Negative control: H_α is SO(n)-equivariant but NOT O(n)-equivariant —
/// a reflection (det = -1) flips its sign. If this test ever passes with
/// equality, the determinant step has degenerated.
#[test]
fn so_jellyfish_breaks_under_reflection() {
    let n = 3;
    let mut rng = Rng::new(0xDEAD);
    // All-free diagram: the pure Levi-Civita map, l = 1, k = 2.
    let d = Diagram::from_blocks(1, 2, vec![vec![0], vec![1], vec![2]]).unwrap();
    let v = Tensor::random(n, 2, &mut rng);
    // A reflection: diag(-1, 1, 1).
    let mut refl = equidiag::linalg::Matrix::identity(n);
    refl.set(0, 0, -1.0);
    let lhs = matrix_mult(Group::SpecialOrthogonal, &d, &groups::rho(&refl, &v)).unwrap();
    let rhs = groups::rho(&refl, &matrix_mult(Group::SpecialOrthogonal, &d, &v).unwrap());
    // det(refl) = -1: lhs must equal -rhs (and be nonzero).
    let mut neg = rhs.clone();
    neg.scale(-1.0);
    assert!(lhs.allclose(&neg, 1e-9));
    assert!(lhs.norm() > 1e-6);
}

/// Equivariance survives linear combination: a whole layer is equivariant.
#[test]
fn random_layer_combination_is_equivariant() {
    use equidiag::layer::{EquivariantLinear, Init};
    let mut rng = Rng::new(0xBEEF);
    for group in [
        Group::Symmetric,
        Group::Orthogonal,
        Group::SpecialOrthogonal,
        Group::Symplectic,
    ] {
        let n = if group == Group::Symplectic { 4 } else { 3 };
        let layer = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.7), &mut rng).unwrap();
        for _ in 0..5 {
            let v = Tensor::random(n, 2, &mut rng);
            let g = groups::sample(group, n, &mut rng).unwrap();
            let lhs = layer.forward(&groups::rho(&g, &v)).unwrap();
            let rhs = groups::rho(&g, &layer.forward(&v).unwrap());
            assert!(
                lhs.allclose(&rhs, 1e-6),
                "{group}: diff {}",
                lhs.max_abs_diff(&rhs)
            );
        }
    }
}
