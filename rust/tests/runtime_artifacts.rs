//! Integration: the rust PJRT runtime executes the AOT jax/pallas
//! artifacts and reproduces jax's numerics exactly (within f32 tolerance).
//!
//! Requires `make artifacts`. Tests skip (pass vacuously, with a note on
//! stderr) when the artifacts are absent so `cargo test` works standalone.

use equidiag::coordinator::{Coordinator, ModelKind};
use equidiag::config::ServerConfig;
use equidiag::runtime::{HloService, PjrtRuntime};
use equidiag::tensor::Tensor;

const MODEL: &str = "artifacts/model.hlo.txt";
const PAIR_TRACE: &str = "artifacts/pair_trace.hlo.txt";
const CHECK: &str = "artifacts/model_check.txt";

fn artifacts_present() -> bool {
    let ok = std::path::Path::new(MODEL).exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn read_check() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let text = std::fs::read_to_string(CHECK).expect("model_check.txt");
    let mut lines = text.lines().map(|l| {
        l.split_whitespace()
            .map(|t| t.parse::<f32>().expect("float token"))
            .collect::<Vec<f32>>()
    });
    let params = lines.next().expect("params line");
    let input = lines.next().expect("input line");
    let output = lines.next().expect("output line");
    (params, input, output)
}

#[test]
fn model_artifact_matches_jax_numerics() {
    if !artifacts_present() {
        return;
    }
    let (params, input, expected) = read_check();
    let batch = 4usize;
    let n = 8usize;
    assert_eq!(input.len(), batch * n * n);
    let rt = PjrtRuntime::cpu().unwrap();
    let model = rt.load_hlo_text(MODEL).unwrap();
    let outs = model
        .run_f32(&[
            (params, vec![34]),
            (input, vec![batch, n, n]),
        ])
        .unwrap();
    assert_eq!(outs.len(), 1, "model returns a 1-tuple");
    assert_eq!(outs[0].len(), expected.len());
    let max_diff = outs[0]
        .iter()
        .zip(&expected)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // f32 with different accumulation order between xla_extension 0.5.1
    // and the jax-bundled XLA: allow ~1e-3 absolute on O(1)-magnitude
    // outputs.
    assert!(
        max_diff < 1e-3,
        "rust PJRT output deviates from jax by {max_diff}"
    );
}

#[test]
fn pair_trace_artifact_is_a_trace() {
    if !artifacts_present() {
        return;
    }
    let batch = 4usize;
    let n = 8usize;
    let rt = PjrtRuntime::cpu().unwrap();
    let model = rt.load_hlo_text(PAIR_TRACE).unwrap();
    // Deterministic input; expected = per-matrix trace.
    let mut data = vec![0f32; batch * n * n];
    for (i, x) in data.iter_mut().enumerate() {
        *x = (i % 13) as f32 - 6.0;
    }
    let mut expected = vec![0f32; batch];
    for b in 0..batch {
        for j in 0..n {
            expected[b] += data[b * n * n + j * n + j];
        }
    }
    let outs = model.run_f32(&[(data, vec![batch, n, n])]).unwrap();
    for (a, b) in outs[0].iter().zip(&expected) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn hlo_service_serves_from_coordinator() {
    if !artifacts_present() {
        return;
    }
    // The pallas pair-trace kernel as a coordinator route: order-2 input
    // over R^n with a leading batch axis is not the coordinator Tensor
    // shape, so serve the model artifact is also awkward; instead exercise
    // HloService directly under concurrency.
    let service = HloService::spawn(PAIR_TRACE).unwrap();
    assert_eq!(service.name(), "pair_trace.hlo");
    let mut joins = Vec::new();
    for t in 0..4 {
        let s = service.clone();
        joins.push(std::thread::spawn(move || {
            let batch = 4usize;
            let n = 8usize;
            let data = vec![t as f32; batch * n * n];
            let outs = s.run_f32(vec![(data, vec![batch, n, n])]).unwrap();
            for &v in &outs[0] {
                assert!((v - (t as f32) * n as f32).abs() < 1e-4);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // And through the coordinator with a native model alongside.
    let mut coord = Coordinator::new(ServerConfig::default());
    coord.register("kernel", ModelKind::hlo(service));
    let handle = coord.start();
    // The registry path expects cube tensors; the pair_trace artifact's
    // input is (4, 8, 8) which is not n^k for a single n — submitting a
    // mismatched tensor must fail cleanly, not crash the server.
    let bad = handle.infer("kernel", Tensor::zeros(8, 2));
    assert!(bad.is_err());
    assert_eq!(handle.metrics().failed, 1);
    handle.shutdown();
}
