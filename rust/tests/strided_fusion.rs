//! Strided fusion: gather-contract kernels and precompiled kernel plans.
//!
//! Three layers of guarantees, all **bitwise** (tolerance 0.0):
//!
//! 1. the fused tensor kernels (`contract_permuted_diagonal_into`,
//!    `trace_permuted_pair_eps_into`, `extract_permuted_group_diagonals_into`
//!    and their batched twins) equal the materialised permute-then-op
//!    composition for randomized axes (`util::prop`),
//! 2. a fused [`LayerSchedule`] equals its unfused compile on every
//!    execute path — forward (`execute`, `execute_batch`) and backward
//!    (`execute_map`, `execute_batch_map`) — for all four groups,
//! 3. the warm path performs zero heap allocations for *index scratch*
//!    (ref counts, activity masks, λ-weight gathers, node-slot tables) as
//!    well as tensor buffers.
//!
//! Plus the cost-model invariants: fusion never increases
//! `estimated_flops` and strictly decreases `estimated_bytes` whenever
//! `fused_nodes > 0`.
//!
//! The generic-scalar stack rides the same harness: the schedules
//! instantiated at `f32` must track the `f64` reference (the seed path)
//! within the scaled tolerance on every execute path, and the lane-chunked
//! vectorized kernels are pinned bitwise against plain scalar loops at
//! both precisions.

use equidiag::fastmult::{exec_stats, Group, LayerSchedule, ScratchArena, ScratchArenaOf};
use equidiag::layer::spanning_plans;
use equidiag::tensor::{BatchTensor, BatchTensorOf, Scalar, Tensor, TensorOf};
use equidiag::util::prop::{check, Config};
use equidiag::util::Rng;

/// Uniform random permutation of `0..order` (Fisher–Yates).
fn random_perm(order: usize, rng: &mut Rng) -> Vec<usize> {
    let mut p: Vec<usize> = (0..order).collect();
    for i in (1..order).rev() {
        let j = rng.below(i + 1);
        p.swap(i, j);
    }
    p
}

/// Shapes covering all four groups, k > l (contraction-heavy, so the
/// σ_k permutes feed contractions), k == l, and the SO(n) jellyfish path.
const CONFIGS: &[(Group, usize, usize, usize)] = &[
    (Group::Symmetric, 4, 3, 2),
    (Group::Symmetric, 3, 2, 3),
    (Group::Symmetric, 3, 3, 3),
    (Group::Orthogonal, 5, 4, 2),
    (Group::Orthogonal, 3, 3, 3),
    (Group::SpecialOrthogonal, 3, 3, 1),
    (Group::SpecialOrthogonal, 3, 3, 2), // jellyfish diagrams present
    (Group::Symplectic, 4, 2, 2),
    (Group::Symplectic, 4, 4, 2),
];

/// Fused gather kernels ≡ permute-then-op, randomized axes, single-item.
#[test]
fn fused_kernels_match_composition_randomized() {
    check(
        Config::default().cases(64).seed(0xF0_51),
        "fused gather kernels are bitwise",
        |rng| {
            let n = 2 + rng.below(3); // 2..=4
            let order = 2 + rng.below(3); // 2..=4
            let t = Tensor::random(n, order, rng);
            let axes = random_perm(order, rng);
            // Generalised diagonal contraction over permuted trailing axes.
            let m = 1 + rng.below(order);
            let want = t.permute_axes(&axes).contract_trailing_diagonal(m);
            let mut got = Tensor::zeros(n, order - m);
            got.data.fill(3.25); // stale scratch must be fully overwritten
            t.contract_permuted_diagonal_into(&axes, m, &mut got);
            if !got.allclose(&want, 0.0) {
                return Err(format!(
                    "contract n={n} order={order} m={m} axes={axes:?}: diff {}",
                    got.max_abs_diff(&want)
                ));
            }
            // Permuted group-diagonal extraction (random group split).
            let mut groups = Vec::new();
            let mut left = order;
            while left > 0 {
                let g = 1 + rng.below(left);
                groups.push(g);
                left -= g;
            }
            let want = t.permute_axes(&axes).extract_group_diagonals(&groups);
            let mut got = Tensor::zeros(n, groups.len());
            got.data.fill(-1.5);
            t.extract_permuted_group_diagonals_into(&axes, &groups, &mut got);
            if !got.allclose(&want, 0.0) {
                return Err(format!(
                    "extract n={n} order={order} axes={axes:?} groups={groups:?}: diff {}",
                    got.max_abs_diff(&want)
                ));
            }
            // Permuted ε-trace (even n).
            let t4 = Tensor::random(4, order, rng);
            let eaxes = random_perm(order, rng);
            let want = t4.permute_axes(&eaxes).trace_trailing_pair_eps();
            let mut got = Tensor::zeros(4, order - 2);
            got.data.fill(9.0);
            t4.trace_permuted_pair_eps_into(&eaxes, &mut got);
            if !got.allclose(&want, 0.0) {
                return Err(format!("eps order={order} axes={eaxes:?}"));
            }
            Ok(())
        },
    );
}

/// Batched fused kernels ≡ per-item fused kernels, randomized axes.
#[test]
fn batched_fused_kernels_match_per_item_randomized() {
    check(
        Config::default().cases(32).seed(0xF0_52),
        "batched fused gather kernels are bitwise per item",
        |rng| {
            let n = 2 + rng.below(3);
            let order = 2 + rng.below(3);
            let items: Vec<Tensor> = (0..3).map(|_| Tensor::random(n, order, rng)).collect();
            let packed = BatchTensor::pack(&items).unwrap();
            let axes = random_perm(order, rng);
            let m = 1 + rng.below(order);
            let mut got = BatchTensor::zeros(n, order - m, 3);
            packed.contract_permuted_diagonal_into(&axes, m, &mut got);
            for (b, t) in items.iter().enumerate() {
                let mut want = Tensor::zeros(n, order - m);
                t.contract_permuted_diagonal_into(&axes, m, &mut want);
                if got.item(b) != want.data.as_slice() {
                    return Err(format!("batched contract item {b} axes {axes:?}"));
                }
            }
            let groups = vec![order - 1, 1];
            let mut got = BatchTensor::zeros(n, groups.len(), 3);
            packed.extract_permuted_group_diagonals_into(&axes, &groups, &mut got);
            for (b, t) in items.iter().enumerate() {
                let mut want = Tensor::zeros(n, groups.len());
                t.extract_permuted_group_diagonals_into(&axes, &groups, &mut want);
                if got.item(b) != want.data.as_slice() {
                    return Err(format!("batched extract item {b} axes {axes:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Fused schedules equal unfused schedules **bitwise** on the forward
/// folded walk and on the per-term (backward) map walk, single + batched,
/// all four groups.
#[test]
fn fused_schedule_matches_unfused_everywhere() {
    let mut rng = Rng::new(0xF0_53);
    for &(group, n, k, l) in CONFIGS {
        let plans = spanning_plans(group, n, k, l).unwrap();
        if plans.is_empty() {
            continue;
        }
        let fused = LayerSchedule::compile(group, n, k, l, &plans).unwrap();
        let unfused = LayerSchedule::compile_unfused(group, n, k, l, &plans).unwrap();
        let coeffs: Vec<f64> = (0..plans.len()).map(|_| rng.gaussian()).collect();
        let v = Tensor::random(n, k, &mut rng);
        let mut arena = ScratchArena::new();
        // Forward, single item.
        let mut a = Tensor::zeros(n, l);
        let mut b = Tensor::zeros(n, l);
        fused.execute(&v, &coeffs, &mut a, &mut arena).unwrap();
        unfused.execute(&v, &coeffs, &mut b, &mut arena).unwrap();
        assert!(
            a.allclose(&b, 0.0),
            "{group} ({k},{l}): fused forward diverges by {}",
            a.max_abs_diff(&b)
        );
        // Backward map walk, single item: per-term tensors bitwise equal
        // between the two compiles AND to MultPlan::apply.
        let mut unfused_terms: Vec<Tensor> = Vec::new();
        unfused
            .execute_map(&v, &mut arena, |_, t| {
                unfused_terms.push(t.clone());
                Ok(())
            })
            .unwrap();
        fused
            .execute_map(&v, &mut arena, |i, t| {
                assert!(
                    t.allclose(&unfused_terms[i], 0.0),
                    "{group} ({k},{l}) term {i}: fused map walk diverges"
                );
                let want = plans[i].apply(&v).unwrap();
                assert!(
                    t.allclose(&want, 0.0),
                    "{group} ({k},{l}) term {i}: diverges from MultPlan::apply"
                );
                Ok(())
            })
            .unwrap();
        // Forward + backward, batched: bitwise per item against the
        // single-item fused walk and against the unfused batched walk.
        let items: Vec<Tensor> = (0..3).map(|_| Tensor::random(n, k, &mut rng)).collect();
        let vb = BatchTensor::pack(&items).unwrap();
        let mut ba = BatchTensor::zeros(n, l, 3);
        let mut bb = BatchTensor::zeros(n, l, 3);
        fused.execute_batch(&vb, &coeffs, &mut ba, &mut arena).unwrap();
        unfused
            .execute_batch(&vb, &coeffs, &mut bb, &mut arena)
            .unwrap();
        assert!(
            ba.max_abs_diff(&bb) == 0.0,
            "{group} ({k},{l}): batched fused forward diverges"
        );
        for (bi, item) in items.iter().enumerate() {
            let mut single = Tensor::zeros(n, l);
            fused.execute(item, &coeffs, &mut single, &mut arena).unwrap();
            assert!(
                ba.item_tensor(bi).allclose(&single, 0.0),
                "{group} ({k},{l}) item {bi}: batch/single divergence"
            );
        }
        fused
            .execute_batch_map(&vb, &mut arena, |i, tb| {
                for (bi, item) in items.iter().enumerate() {
                    let want = plans[i].apply(item).unwrap();
                    assert!(
                        tb.item_tensor(bi).allclose(&want, 0.0),
                        "{group} ({k},{l}) term {i} item {bi}: batched map walk diverges"
                    );
                }
                Ok(())
            })
            .unwrap();
    }
}

/// The fused schedule instantiated at `f32`: every execute path (single,
/// batched, per-term map walk) across the four-group configs tracks the
/// `f64` reference within the scaled [`Scalar::TOLERANCE`]. The `f64`
/// instantiation is the seed path itself, so this is the whole
/// two-precision schedule matrix.
#[test]
fn f32_schedule_tracks_f64_all_groups() {
    let f32_tol = |reference: &Tensor| {
        let scale = reference.data.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
        64.0 * <f32 as Scalar>::TOLERANCE * scale
    };
    let mut rng = Rng::new(0xF0_55);
    for &(group, n, k, l) in CONFIGS {
        let plans = spanning_plans(group, n, k, l).unwrap();
        if plans.is_empty() {
            continue;
        }
        let schedule = LayerSchedule::compile(group, n, k, l, &plans).unwrap();
        let coeffs: Vec<f64> = (0..plans.len()).map(|_| rng.gaussian()).collect();
        let v = Tensor::random(n, k, &mut rng);
        let v32 = v.cast::<f32>();
        let mut arena = ScratchArena::new();
        let mut arena32 = ScratchArenaOf::<f32>::new();
        // Forward, single item.
        let mut want = Tensor::zeros(n, l);
        let mut got = TensorOf::<f32>::zeros(n, l);
        schedule.execute(&v, &coeffs, &mut want, &mut arena).unwrap();
        schedule
            .execute(&v32, &coeffs, &mut got, &mut arena32)
            .unwrap();
        assert!(
            got.cast::<f64>().allclose(&want, f32_tol(&want)),
            "{group} ({k},{l}): f32 forward diverges by {}",
            got.cast::<f64>().max_abs_diff(&want)
        );
        // Backward map walk: per-term tensors track per term.
        let mut terms: Vec<Tensor> = Vec::new();
        schedule
            .execute_map(&v, &mut arena, |_, t| {
                terms.push(t.clone());
                Ok(())
            })
            .unwrap();
        schedule
            .execute_map(&v32, &mut arena32, |i, t| {
                assert!(
                    t.cast::<f64>().allclose(&terms[i], f32_tol(&terms[i])),
                    "{group} ({k},{l}) term {i}: f32 map walk diverges by {}",
                    t.cast::<f64>().max_abs_diff(&terms[i])
                );
                Ok(())
            })
            .unwrap();
        // Forward, batched.
        let items: Vec<Tensor> = (0..3).map(|_| Tensor::random(n, k, &mut rng)).collect();
        let items32: Vec<TensorOf<f32>> = items.iter().map(|t| t.cast()).collect();
        let vb = BatchTensor::pack(&items).unwrap();
        let vb32 = BatchTensorOf::<f32>::pack(&items32).unwrap();
        let mut bwant = BatchTensor::zeros(n, l, 3);
        let mut bgot = BatchTensorOf::<f32>::zeros(n, l, 3);
        schedule
            .execute_batch(&vb, &coeffs, &mut bwant, &mut arena)
            .unwrap();
        schedule
            .execute_batch(&vb32, &coeffs, &mut bgot, &mut arena32)
            .unwrap();
        for bi in 0..3 {
            let want_item = bwant.item_tensor(bi);
            assert!(
                bgot.item_tensor(bi)
                    .cast::<f64>()
                    .allclose(&want_item, f32_tol(&want_item)),
                "{group} ({k},{l}) item {bi}: f32 batched forward diverges"
            );
        }
    }
}

/// Property: the lane-chunked vectorized kernels behind [`TensorOf::axpy`]
/// and [`TensorOf::scale`] are bitwise equal to their plain scalar twins at
/// both precisions — `chunks_exact` changes the instruction schedule, never
/// the per-element arithmetic (no FMA contraction, no reassociation).
#[test]
fn prop_vectorized_kernels_match_scalar_twins() {
    check(
        Config::default().cases(64).seed(0xF0_56),
        "vectorized axpy/scale are bitwise vs scalar loops",
        |rng| {
            let n = 2 + rng.below(3); // 2..=4
            let order = 1 + rng.below(3); // 1..=3
            let alpha = rng.gaussian();
            // f64 twins.
            let x = Tensor::random(n, order, rng);
            let mut out = Tensor::random(n, order, rng);
            let mut want = out.data.clone();
            for (w, &xv) in want.iter_mut().zip(&x.data) {
                *w += alpha * xv;
            }
            out.axpy(alpha, &x);
            if out.data != want {
                return Err(format!("f64 axpy diverges from the scalar loop (n={n})"));
            }
            let want: Vec<f64> = out.data.iter().map(|&v| v * alpha).collect();
            out.scale(alpha);
            if out.data != want {
                return Err(format!("f64 scale diverges from the scalar loop (n={n})"));
            }
            // f32 twins: the kernel narrows alpha once, then runs the same
            // per-element expression.
            let a32 = <f32 as Scalar>::from_f64(alpha);
            let x32 = x.cast::<f32>();
            let mut out32 = Tensor::random(n, order, rng).cast::<f32>();
            let mut want = out32.data.clone();
            for (w, &xv) in want.iter_mut().zip(&x32.data) {
                *w += a32 * xv;
            }
            out32.axpy(alpha, &x32);
            if out32.data != want {
                return Err(format!("f32 axpy diverges from the scalar loop (n={n})"));
            }
            let want: Vec<f32> = out32.data.iter().map(|&v| v * a32).collect();
            out32.scale(alpha);
            if out32.data != want {
                return Err(format!("f32 scale diverges from the scalar loop (n={n})"));
            }
            Ok(())
        },
    );
}

/// Fusion's cost-model invariants: flops unchanged, bytes strictly lower
/// whenever anything fused, node accounting exact — and the
/// contraction-heavy shapes must actually fuse.
#[test]
fn fusion_cost_invariants() {
    let mut any_fused = false;
    for &(group, n, k, l) in CONFIGS {
        let plans = spanning_plans(group, n, k, l).unwrap();
        if plans.is_empty() {
            continue;
        }
        let fused = LayerSchedule::compile(group, n, k, l, &plans).unwrap().stats();
        let unfused = LayerSchedule::compile_unfused(group, n, k, l, &plans)
            .unwrap()
            .stats();
        assert_eq!(
            fused.estimated_flops, unfused.estimated_flops,
            "{group} ({k},{l}): fusion must never change estimated flops"
        );
        assert_eq!(fused.nodes + fused.fused_nodes, unfused.nodes, "{group} ({k},{l})");
        assert_eq!(
            unfused.estimated_bytes - fused.estimated_bytes,
            fused.bytes_saved_estimate,
            "{group} ({k},{l}): bytes-saved bookkeeping"
        );
        if fused.fused_nodes > 0 {
            any_fused = true;
            assert!(
                fused.estimated_bytes < unfused.estimated_bytes,
                "{group} ({k},{l}): fusion must strictly decrease estimated bytes"
            );
        }
        assert_eq!(unfused.fused_nodes, 0);
    }
    assert!(any_fused, "no config fused anything — the pass is dead");
    // The k > l shapes specifically must fuse (non-identity σ_k permutes
    // feeding contractions, single consumer after CSE).
    for &(group, n, k, l) in &[
        (Group::Orthogonal, 5usize, 4usize, 2usize),
        (Group::Symplectic, 4, 4, 2),
    ] {
        let plans = spanning_plans(group, n, k, l).unwrap();
        let stats = LayerSchedule::compile(group, n, k, l, &plans).unwrap().stats();
        assert!(
            stats.fused_nodes > 0,
            "{group} ({k},{l}): expected fusion to fire: {stats:?}"
        );
    }
}

/// Warm-path zero-allocation now covers index scratch on every execute
/// variant (single, batched, map), and the measured bytes counter moves.
#[test]
fn warm_path_zero_alloc_covers_index_scratch() {
    let mut rng = Rng::new(0xF0_54);
    let (group, n, k, l) = (Group::Symmetric, 3, 3, 2);
    let plans = spanning_plans(group, n, k, l).unwrap();
    let schedule = LayerSchedule::compile(group, n, k, l, &plans).unwrap();
    let coeffs: Vec<f64> = (0..plans.len()).map(|_| rng.gaussian()).collect();
    let v = Tensor::random(n, k, &mut rng);
    let items: Vec<Tensor> = (0..4).map(|_| Tensor::random(n, k, &mut rng)).collect();
    let vb = BatchTensor::pack(&items).unwrap();
    let mut out = Tensor::zeros(n, l);
    let mut bout = BatchTensor::zeros(n, l, 4);
    let mut arena = ScratchArena::new();
    let bytes_before = exec_stats().bytes_moved;
    // Warm every path once.
    schedule.execute(&v, &coeffs, &mut out, &mut arena).unwrap();
    schedule
        .execute_batch(&vb, &coeffs, &mut bout, &mut arena)
        .unwrap();
    schedule.execute_map(&v, &mut arena, |_, _| Ok(())).unwrap();
    schedule
        .execute_batch_map(&vb, &mut arena, |_, _| Ok(()))
        .unwrap();
    assert!(
        exec_stats().bytes_moved > bytes_before,
        "measured bytes-moved counter must accumulate"
    );
    let warm_tensor = arena.allocations();
    let warm_index = arena.index_allocations();
    assert!(warm_index > 0, "cold passes must allocate index scratch");
    for _ in 0..3 {
        out.data.fill(0.0);
        bout.data_mut().fill(0.0);
        schedule.execute(&v, &coeffs, &mut out, &mut arena).unwrap();
        schedule
            .execute_batch(&vb, &coeffs, &mut bout, &mut arena)
            .unwrap();
        schedule.execute_map(&v, &mut arena, |_, _| Ok(())).unwrap();
        schedule
            .execute_batch_map(&vb, &mut arena, |_, _| Ok(()))
            .unwrap();
    }
    assert_eq!(
        arena.allocations(),
        warm_tensor,
        "warm tensor scratch must not allocate"
    );
    assert_eq!(
        arena.index_allocations(),
        warm_index,
        "warm index scratch must not allocate"
    );
    assert!(arena.index_reuses() > 0);
}
