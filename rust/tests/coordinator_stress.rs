//! Coordinator failure-mode and stress tests: backpressure, mixed
//! success/failure traffic, saturation, shutdown under load.

use equidiag::config::ServerConfig;
use equidiag::coordinator::{ChaosPlan, Coordinator, ModelKind};
use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn slow_net(rng: &mut Rng) -> EquivariantNet {
    // A deeper net so each inference takes a non-trivial time.
    EquivariantNet::new(
        Group::Symmetric,
        6,
        &[2, 2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        rng,
    )
    .unwrap()
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let mut rng = Rng::new(701);
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_window: Duration::from_millis(50), // slow drain
        queue_capacity: 2,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(slow_net(&mut rng)));
    let handle = coord.start();
    // Fire-and-forget submissions until the bounded queue overflows.
    let mut receivers = Vec::new();
    let mut rejected = 0;
    for _ in 0..50 {
        match handle.submit("m", Tensor::random(6, 2, &mut rng)) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    assert_eq!(handle.metrics().rejected, rejected as u64);
    // Everything accepted must still complete.
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    handle.shutdown();
}

#[test]
fn mixed_traffic_failures_do_not_poison_the_pool() {
    let mut rng = Rng::new(702);
    let mut coord = Coordinator::new(ServerConfig::default());
    coord.register("good", ModelKind::net(slow_net(&mut rng)));
    let handle = Arc::new(coord.start());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(800 + t);
            let mut ok = 0;
            let mut err = 0;
            for i in 0..50 {
                let route = if i % 5 == 0 { "missing" } else { "good" };
                match h.infer(route, Tensor::random(6, 2, &mut rng)) {
                    Ok(_) => ok += 1,
                    Err(_) => err += 1,
                }
            }
            (ok, err)
        }));
    }
    let mut total_ok = 0;
    let mut total_err = 0;
    for j in joins {
        let (ok, err) = j.join().unwrap();
        total_ok += ok;
        total_err += err;
    }
    assert_eq!(total_ok, 160);
    assert_eq!(total_err, 40);
    let snap = handle.metrics();
    assert_eq!(snap.completed, 160);
    assert_eq!(snap.failed, 40);
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
}

#[test]
fn shutdown_under_load_completes_accepted_requests() {
    let mut rng = Rng::new(703);
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 8,
        batch_window: Duration::from_micros(100),
        queue_capacity: 256,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(slow_net(&mut rng)));
    let handle = coord.start();
    let mut receivers = Vec::new();
    for _ in 0..64 {
        receivers.push(handle.submit("m", Tensor::random(6, 2, &mut rng)).unwrap());
    }
    handle.shutdown(); // drains the queue before joining
    let mut completed = 0;
    for rx in receivers {
        if let Ok(Ok(_)) = rx.recv() {
            completed += 1;
        }
    }
    assert_eq!(completed, 64, "accepted requests must complete on shutdown");
}

/// Shutdown race: dropping the handle (instead of calling `shutdown`)
/// with requests still in flight must deliver a terminal outcome to every
/// accepted waiter — a response, a typed error, or at worst a
/// disconnected channel; never a receiver stuck forever.
#[test]
fn drop_with_inflight_delivers_terminal_outcomes() {
    let mut rng = Rng::new(704);
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        queue_capacity: 256,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(slow_net(&mut rng)));
    let handle = coord.start();
    let mut receivers = Vec::new();
    for _ in 0..32 {
        receivers.push(handle.submit("m", Tensor::random(6, 2, &mut rng)).unwrap());
    }
    drop(handle); // implicit shutdown: close the queue, join everything
    for (i, rx) in receivers.into_iter().enumerate() {
        // After drop has joined the pool, the outcome is already in the
        // channel (or the channel is provably disconnected) — the bounded
        // recv is a backstop, not a wait.
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(_) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                panic!("waiter {i} got no terminal outcome after drop")
            }
        }
    }
}

/// Shutdown arriving mid-batch: workers are stalled inside model
/// execution when the handle shuts down; every waiter (executing and
/// still-queued alike) must still resolve.
#[test]
fn mid_batch_shutdown_resolves_every_waiter() {
    let mut rng = Rng::new(705);
    let plan = Arc::new(ChaosPlan::new(9).with_stalls(1000, Duration::from_millis(50)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 2,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        ..ServerConfig::default()
    });
    coord.register(
        "stall",
        ModelKind::chaos(ModelKind::net(slow_net(&mut rng)), plan),
    );
    let handle = coord.start();
    let mut receivers = Vec::new();
    for _ in 0..16 {
        receivers.push(
            handle
                .submit("stall", Tensor::random(6, 2, &mut rng))
                .unwrap(),
        );
    }
    // Let the workers get pinned inside a stalled batch, then shut down.
    std::thread::sleep(Duration::from_millis(10));
    handle.shutdown();
    for (i, rx) in receivers.into_iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(_) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                panic!("waiter {i} got no terminal outcome across mid-batch shutdown")
            }
        }
    }
}
