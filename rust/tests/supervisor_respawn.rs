//! Supervisor respawn regression suite (run by name in CI:
//! `cargo test --test supervisor_respawn`).
//!
//! The old supervisor slept out a panicked slot's backoff **inline** in
//! its event loop, so while slot A waited out its (up to 200ms) delay,
//! slot B's exit event sat unread and B's respawn was serialised behind
//! A's. The rewritten supervisor tracks a per-slot respawn *due time* and
//! keeps draining exit events while backoffs pend. These tests pin the
//! observable consequences: two crash-looping routes both keep getting
//! respawns (neither starves behind the other's backoff), and a shutdown
//! arriving mid-backoff is honoured promptly.

use equidiag::config::ServerConfig;
use equidiag::coordinator::{ChaosPlan, Coordinator, ModelKind, CHAOS_PANIC_PREFIX};
use equidiag::error::Error;
use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;
use std::sync::{Arc, Once};
use std::time::{Duration, Instant};

fn test_net(rng: &mut Rng) -> EquivariantNet {
    EquivariantNet::new(
        Group::Symmetric,
        4,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        rng,
    )
    .unwrap()
}

/// Keep expected chaos-injected panics off stderr; real panics (test
/// failures included) still print through the previous hook.
fn quiet_chaos_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let old = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.starts_with(CHAOS_PANIC_PREFIX) {
                old(info);
            }
        }));
    });
}

/// Two always-panicking models hammered concurrently on a two-slot pool:
/// every request on **both** routes resolves to the typed
/// [`Error::WorkerPanic`] — with the old inline backoff, one slot's
/// crash-loop delay starved the other route's respawns and stalled its
/// requests. Afterwards the respawned pool still serves a healthy route.
#[test]
fn two_crash_looping_models_respawn_independently() {
    quiet_chaos_panics();
    let mut rng = Rng::new(911);
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 2,
        batch_window: Duration::from_micros(100),
        queue_capacity: 64,
        ..ServerConfig::default()
    });
    for (route, seed) in [("boom-a", 11u64), ("boom-b", 12)] {
        let plan = Arc::new(ChaosPlan::new(seed).with_panics(1000));
        coord.register(
            route,
            ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
        );
    }
    coord.register("ok", ModelKind::net(test_net(&mut rng)));
    let handle = Arc::new(coord.start());
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for (t, route) in [(0u64, "boom-a"), (1, "boom-b")] {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(920 + t);
            for i in 0..8 {
                let err = h.infer(route, Tensor::random(4, 2, &mut rng)).unwrap_err();
                match err {
                    Error::WorkerPanic(msg) => {
                        assert!(msg.starts_with(CHAOS_PANIC_PREFIX), "{route} #{i}: {msg}")
                    }
                    other => panic!("{route} #{i}: expected WorkerPanic, got {other:?}"),
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // Both routes crash-looped through 16 requests; even at the 200ms
    // backoff cap a non-serialising supervisor clears this with a wide
    // margin (the bound mostly guards against a respawn deadlock).
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "respawns took {:?} — serialised or deadlocked supervisor",
        t0.elapsed()
    );
    let snap = handle.metrics();
    assert_eq!(snap.failed, 16);
    assert!(
        snap.worker_restarts >= 2,
        "both crash-looping slots must respawn (saw {})",
        snap.worker_restarts
    );
    assert!(snap.batch_panics >= 2);
    // Recovery: the pool serves the healthy route after the panic storm.
    for _ in 0..4 {
        handle.infer("ok", Tensor::random(4, 2, &mut rng)).unwrap();
    }
    assert_eq!(handle.metrics().completed, 4);
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
}

/// A shutdown arriving while a respawn backoff pends must be honoured:
/// pending respawns are cancelled against the drained queue and the
/// supervisor exits instead of spawning into a closed pool.
#[test]
fn shutdown_during_pending_backoff_is_prompt() {
    quiet_chaos_panics();
    let mut rng = Rng::new(912);
    let plan = Arc::new(ChaosPlan::new(13).with_panics(1000));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        ..ServerConfig::default()
    });
    coord.register(
        "boom",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    let handle = coord.start();
    // Drive the single slot into a crash loop so its backoff grows.
    for _ in 0..6 {
        let err = handle
            .infer("boom", Tensor::random(4, 2, &mut rng))
            .unwrap_err();
        assert!(matches!(err, Error::WorkerPanic(_)), "got {err:?}");
    }
    // Shut down immediately after a panic: a respawn is likely pending.
    let t0 = Instant::now();
    handle.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown stalled {:?} behind a pending respawn",
        t0.elapsed()
    );
}
