//! The headline correctness property: Algorithm 1 (`matrix_mult` /
//! `MultPlan`) agrees with the naïve `O(n^{l+k})` functor application for
//! random diagrams, shapes and dimensions — all four groups, including the
//! degenerate shapes (k = 0, l = 0, order-0 scalars).

use equidiag::diagram::Diagram;
use equidiag::fastmult::{matrix_mult, Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::tensor::Tensor;
use equidiag::util::prop::{check, Config};

#[test]
fn sn_random_diagrams() {
    check(Config::default().cases(200), "S_n fast == naive", |rng| {
        let n = 2 + rng.below(3);
        let l = rng.below(5);
        let k = rng.below(5);
        let d = Diagram::random_partition(l, k, rng);
        let v = Tensor::random(n, k, rng);
        let fast = matrix_mult(Group::Symmetric, &d, &v).map_err(|e| e.to_string())?;
        let slow = naive_apply(Group::Symmetric, &d, &v).map_err(|e| e.to_string())?;
        if fast.allclose(&slow, 1e-8) {
            Ok(())
        } else {
            Err(format!("{d}: diff {}", fast.max_abs_diff(&slow)))
        }
    });
}

#[test]
fn on_random_diagrams() {
    check(Config::default().cases(200), "O(n) fast == naive", |rng| {
        let n = 2 + rng.below(3);
        let l = rng.below(5);
        let k = l % 2 + 2 * rng.below(3);
        let d = match Diagram::random_brauer(l, k, rng) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let v = Tensor::random(n, k, rng);
        let fast = matrix_mult(Group::Orthogonal, &d, &v).map_err(|e| e.to_string())?;
        let slow = naive_apply(Group::Orthogonal, &d, &v).map_err(|e| e.to_string())?;
        if fast.allclose(&slow, 1e-8) {
            Ok(())
        } else {
            Err(format!("{d}: diff {}", fast.max_abs_diff(&slow)))
        }
    });
}

#[test]
fn sp_random_diagrams() {
    check(Config::default().cases(200), "Sp(n) fast == naive", |rng| {
        let n = 2 + 2 * rng.below(2);
        let l = rng.below(5);
        let k = l % 2 + 2 * rng.below(3);
        let d = match Diagram::random_brauer(l, k, rng) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let v = Tensor::random(n, k, rng);
        let fast = matrix_mult(Group::Symplectic, &d, &v).map_err(|e| e.to_string())?;
        let slow = naive_apply(Group::Symplectic, &d, &v).map_err(|e| e.to_string())?;
        if fast.allclose(&slow, 1e-8) {
            Ok(())
        } else {
            Err(format!("{d}: diff {}", fast.max_abs_diff(&slow)))
        }
    });
}

#[test]
fn so_random_diagrams_brauer_and_jellyfish() {
    check(Config::default().cases(150), "SO(n) fast == naive", |rng| {
        let n = 2 + rng.below(2);
        let l = rng.below(4);
        let k = rng.below(5);
        // Alternate between Brauer and jellyfish depending on parity.
        let d = if (l + k) % 2 == 0 && rng.below(2) == 0 {
            match Diagram::random_brauer(l, k, rng) {
                Ok(d) => d,
                Err(_) => return Ok(()),
            }
        } else if l + k >= n && (l + k - n) % 2 == 0 {
            Diagram::random_jellyfish(l, k, n, rng).map_err(|e| e.to_string())?
        } else {
            return Ok(());
        };
        let v = Tensor::random(n, k, rng);
        let fast =
            matrix_mult(Group::SpecialOrthogonal, &d, &v).map_err(|e| e.to_string())?;
        let slow =
            naive_apply(Group::SpecialOrthogonal, &d, &v).map_err(|e| e.to_string())?;
        if fast.allclose(&slow, 1e-7) {
            Ok(())
        } else {
            Err(format!("{d}: diff {}", fast.max_abs_diff(&slow)))
        }
    });
}

#[test]
fn plans_are_linear() {
    // F(d)(a v + b w) == a F(d) v + b F(d) w — the property §5 uses to
    // extend the per-diagram algorithm to whole weight matrices.
    check(Config::default().cases(100), "linearity", |rng| {
        let n = 3;
        let d = Diagram::random_partition(rng.below(4), rng.below(4), rng);
        let plan = MultPlan::new(Group::Symmetric, &d, n).map_err(|e| e.to_string())?;
        let v = Tensor::random(n, d.k, rng);
        let w = Tensor::random(n, d.k, rng);
        let (a, b) = (rng.uniform_in(-2.0, 2.0), rng.uniform_in(-2.0, 2.0));
        let mut lin = v.clone();
        lin.scale(a);
        lin.axpy(b, &w);
        let lhs = plan.apply(&lin).map_err(|e| e.to_string())?;
        let mut rhs = plan.apply(&v).map_err(|e| e.to_string())?;
        rhs.scale(a);
        rhs.axpy(b, &plan.apply(&w).map_err(|e| e.to_string())?);
        if lhs.allclose(&rhs, 1e-8) {
            Ok(())
        } else {
            Err(format!("not linear on {d}"))
        }
    });
}

#[test]
fn larger_shapes_spot_checks() {
    // A few big-shape cases that the exhaustive unit tests cannot cover.
    let mut rng = equidiag::util::Rng::new(0xFEED);
    for (group, n, l, k) in [
        (Group::Symmetric, 4usize, 3usize, 4usize),
        (Group::Symmetric, 2, 5, 4),
        (Group::Orthogonal, 5, 3, 5),
        (Group::Symplectic, 4, 4, 4),
        (Group::SpecialOrthogonal, 3, 4, 3),
    ] {
        let d = match group {
            Group::Symmetric => Diagram::random_partition(l, k, &mut rng),
            Group::SpecialOrthogonal => Diagram::random_jellyfish(l, k, n, &mut rng).unwrap(),
            _ => Diagram::random_brauer(l, k, &mut rng).unwrap(),
        };
        let v = Tensor::random(n, k, &mut rng);
        let fast = matrix_mult(group, &d, &v).unwrap();
        let slow = naive_apply(group, &d, &v).unwrap();
        assert!(
            fast.allclose(&slow, 1e-7),
            "{group} {d}: diff {}",
            fast.max_abs_diff(&slow)
        );
    }
}
