//! Fault-injection ("chaos") integration suite: certifies the coordinator's
//! fault-tolerance invariants under seeded panics, stalls, and errors —
//! no client hang, exactly one terminal outcome per request, typed errors
//! end to end, supervisor respawn, deadline shedding at every shed point,
//! and per-model admission control. Run by name in CI
//! (`cargo test --test coordinator_chaos`).

use equidiag::config::ServerConfig;
use equidiag::coordinator::{ChaosPlan, Coordinator, ModelKind, CHAOS_PANIC_PREFIX};
use equidiag::error::Error;
use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;
use std::sync::{Arc, Once};
use std::time::Duration;

fn test_net(rng: &mut Rng) -> EquivariantNet {
    EquivariantNet::new(
        Group::Symmetric,
        4,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        rng,
    )
    .unwrap()
}

/// Keep expected chaos-injected panics off stderr; real panics (test
/// failures included) still print through the previous hook.
fn quiet_chaos_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let old = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.starts_with(CHAOS_PANIC_PREFIX) {
                old(info);
            }
        }));
    });
}

/// An always-panicking model: every request still resolves — to the typed
/// [`Error::WorkerPanic`] — no client hangs, the supervisor respawns the
/// recycled workers, and a healthy route on the same pool keeps serving
/// afterwards (recovery).
#[test]
fn panicking_model_yields_typed_errors_and_pool_recovers() {
    quiet_chaos_panics();
    let mut rng = Rng::new(901);
    let plan = Arc::new(ChaosPlan::new(1).with_panics(1000));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(100),
        queue_capacity: 64,
        ..ServerConfig::default()
    });
    coord.register(
        "boom",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    coord.register("ok", ModelKind::net(test_net(&mut rng)));
    let handle = coord.start();
    for i in 0..12 {
        let err = handle
            .infer("boom", Tensor::random(4, 2, &mut rng))
            .unwrap_err();
        // Batch-level panic, then the per-item fallback panics again →
        // the typed WorkerPanic carries the chaos payload.
        match err {
            Error::WorkerPanic(msg) => {
                assert!(msg.starts_with(CHAOS_PANIC_PREFIX), "request {i}: {msg}")
            }
            other => panic!("request {i}: expected WorkerPanic, got {other:?}"),
        }
    }
    let snap = handle.metrics();
    assert!(snap.batch_panics >= 1, "no batch panic was caught");
    assert!(
        snap.worker_restarts >= 1,
        "supervisor never respawned a recycled worker"
    );
    assert_eq!(snap.failed, 12);
    // Recovery: the respawned pool serves the healthy route.
    for _ in 0..5 {
        handle.infer("ok", Tensor::random(4, 2, &mut rng)).unwrap();
    }
    assert_eq!(handle.metrics().completed, 5);
    handle.shutdown();
}

/// Mixed batch under a batch-level panic: the per-item fallback isolates
/// the fault per input — with a panic rate under 1000 the retried items
/// split into real responses and typed panics, and their sum accounts for
/// every submitted request.
#[test]
fn partial_panics_keep_batch_mates_alive() {
    quiet_chaos_panics();
    let mut rng = Rng::new(902);
    let plan = Arc::new(ChaosPlan::new(2).with_panics(400));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        queue_capacity: 256,
        ..ServerConfig::default()
    });
    coord.register(
        "flaky",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    let handle = Arc::new(coord.start());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(950 + t);
            let mut ok = 0u64;
            let mut typed_err = 0u64;
            for _ in 0..25 {
                match h.infer("flaky", Tensor::random(4, 2, &mut rng)) {
                    Ok(_) => ok += 1,
                    Err(Error::WorkerPanic(_)) | Err(Error::Coordinator(_)) => typed_err += 1,
                    Err(other) => panic!("unexpected error kind: {other:?}"),
                }
            }
            (ok, typed_err)
        }));
    }
    let mut ok = 0u64;
    let mut typed_err = 0u64;
    for j in joins {
        let (o, e) = j.join().unwrap();
        ok += o;
        typed_err += e;
    }
    // Exactly one terminal outcome per request.
    assert_eq!(ok + typed_err, 100);
    assert!(ok > 0, "a 40% panic rate must let some requests through");
    let snap = handle.metrics();
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.failed, typed_err);
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
}

/// Deadline enforcement around a stalled model: the client's bounded wait
/// returns the typed [`Error::DeadlineExceeded`] instead of hanging, and
/// requests queued behind the stall are shed server-side
/// (`shed_expired`).
#[test]
fn stalled_model_sheds_on_deadline() {
    quiet_chaos_panics();
    let mut rng = Rng::new(903);
    let plan = Arc::new(ChaosPlan::new(3).with_stalls(1000, Duration::from_millis(200)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        request_timeout: Some(Duration::from_millis(20)),
        ..ServerConfig::default()
    });
    coord.register(
        "stuck",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    let handle = coord.start();
    // Bounded wait: 20ms deadline + grace ≪ the 200ms stall.
    let err = handle
        .infer("stuck", Tensor::random(4, 2, &mut rng))
        .unwrap_err();
    assert!(matches!(err, Error::DeadlineExceeded), "got {err:?}");
    // A burst behind the stalled worker: the queued tail expires before
    // execution and is shed with the same typed error.
    let mut receivers = Vec::new();
    for _ in 0..4 {
        receivers.push(
            handle
                .submit("stuck", Tensor::random(4, 2, &mut rng))
                .unwrap(),
        );
    }
    let mut sheds = 0;
    for rx in receivers {
        if let Err(Error::DeadlineExceeded) = rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            sheds += 1;
        }
    }
    assert!(sheds >= 1, "queued requests behind the stall must shed");
    let snap = handle.metrics();
    assert!(snap.shed_expired >= 1, "shed counter not recorded");
    // Tail-latency histograms are live under this traffic.
    assert!(snap.p50_latency_s <= snap.p95_latency_s);
    assert!(snap.p95_latency_s <= snap.p99_latency_s);
    handle.shutdown();
}

/// Per-model admission control: with an inflight cap of 2 and a stalled
/// worker, extra submissions shed with the typed [`Error::Overloaded`] and
/// the slots release once the admitted requests resolve.
#[test]
fn admission_cap_sheds_and_releases_slots() {
    quiet_chaos_panics();
    let mut rng = Rng::new(904);
    let plan = Arc::new(ChaosPlan::new(4).with_stalls(1000, Duration::from_millis(100)));
    let mut coord = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_window: Duration::from_micros(0),
        queue_capacity: 64,
        max_inflight_per_model: Some(2),
        ..ServerConfig::default()
    });
    coord.register(
        "capped",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    let handle = coord.start();
    let mut admitted = Vec::new();
    let mut overloaded = 0u64;
    for _ in 0..5 {
        match handle.submit("capped", Tensor::random(4, 2, &mut rng)) {
            Ok(rx) => admitted.push(rx),
            Err(Error::Overloaded { model }) => {
                assert_eq!(model, "capped");
                overloaded += 1;
            }
            Err(other) => panic!("unexpected error: {other:?}"),
        }
    }
    assert_eq!(admitted.len(), 2, "cap is 2");
    assert_eq!(overloaded, 3);
    assert_eq!(handle.metrics().shed_admission, 3);
    // The admitted pair resolves (stall then respond) and frees its slots…
    for rx in admitted {
        rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    }
    // …so the route admits again.
    let rx = handle
        .submit("capped", Tensor::random(4, 2, &mut rng))
        .expect("slot must free after terminal outcomes");
    rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    handle.shutdown();
}

/// Injected typed errors pass through the serving path intact (no
/// flattening into opaque strings en route).
#[test]
fn injected_errors_arrive_typed() {
    quiet_chaos_panics();
    let mut rng = Rng::new(905);
    let plan = Arc::new(ChaosPlan::new(5).with_errors(1000));
    let mut coord = Coordinator::new(ServerConfig::default());
    coord.register(
        "erroring",
        ModelKind::chaos(ModelKind::net(test_net(&mut rng)), plan),
    );
    let handle = coord.start();
    let err = handle
        .infer("erroring", Tensor::random(4, 2, &mut rng))
        .unwrap_err();
    assert!(
        err.to_string().contains("chaos: injected error"),
        "error lost its payload: {err}"
    );
    handle.shutdown();
}
