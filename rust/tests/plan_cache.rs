//! PlanCache under concurrency: hit/miss accounting, bounded eviction and
//! plan correctness while many threads hammer one cache.

use equidiag::diagram::{all_partition_diagrams, Diagram};
use equidiag::fastmult::{matrix_mult, Group, PlanCache};
use equidiag::tensor::Tensor;
use equidiag::util::Rng;
use std::sync::Arc;

#[test]
fn concurrent_lookups_account_every_hit_and_miss() {
    let cache = Arc::new(PlanCache::with_capacity(0)); // unbounded: no evictions
    let diagrams: Vec<Diagram> = all_partition_diagrams(2, 2, None);
    assert!(diagrams.len() >= 10);
    let threads = 8;
    let rounds = 40;
    let mut joins = Vec::new();
    for t in 0..threads {
        let cache = cache.clone();
        let diagrams = diagrams.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + t as u64);
            for r in 0..rounds {
                let d = &diagrams[(t + r) % diagrams.len()];
                let plan = cache.get_or_build(Group::Symmetric, d, 3).unwrap();
                // Every returned plan must be correct, cached or fresh.
                let v = Tensor::random(3, 2, &mut rng);
                let fast = plan.apply(&v).unwrap();
                let want = matrix_mult(Group::Symmetric, d, &v).unwrap();
                assert!(fast.allclose(&want, 1e-12));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let s = cache.stats();
    // Builds race outside the lock, so a key can be factored more than
    // once, but every lookup is either a hit or a miss and the population
    // is exactly the distinct keys.
    assert_eq!(s.hits + s.misses, (threads * rounds) as u64);
    assert_eq!(s.entries, diagrams.len());
    assert!(s.misses >= diagrams.len() as u64);
    assert_eq!(s.evictions, 0);
    assert!(s.hit_rate() > 0.5, "hit rate {:.3}", s.hit_rate());
}

#[test]
fn concurrent_contention_on_a_tiny_cache_stays_bounded() {
    // Capacity far below the working set: constant eviction churn must
    // never break correctness or the size bound.
    let capacity = 3;
    let cache = Arc::new(PlanCache::with_capacity(capacity));
    let diagrams: Vec<Diagram> = all_partition_diagrams(2, 2, None);
    let threads = 8;
    let rounds = 30;
    let mut joins = Vec::new();
    for t in 0..threads {
        let cache = cache.clone();
        let diagrams = diagrams.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(950 + t as u64);
            for r in 0..rounds {
                let d = &diagrams[(3 * t + r) % diagrams.len()];
                let plan = cache.get_or_build(Group::Symmetric, d, 3).unwrap();
                let v = Tensor::random(3, 2, &mut rng);
                let fast = plan.apply(&v).unwrap();
                let want = matrix_mult(Group::Symmetric, d, &v).unwrap();
                assert!(fast.allclose(&want, 1e-12));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let s = cache.stats();
    assert!(s.entries <= capacity, "{} entries > capacity", s.entries);
    assert!(s.evictions > 0, "tiny cache must have evicted");
    assert_eq!(s.hits + s.misses, (threads * rounds) as u64);
}

#[test]
fn distinct_groups_and_dimensions_do_not_collide() {
    let cache = PlanCache::with_capacity(0);
    let d = Diagram::random_brauer(2, 2, &mut Rng::new(1)).unwrap();
    let sn = cache.get_or_build(Group::Symmetric, &d, 3).unwrap();
    let on = cache.get_or_build(Group::Orthogonal, &d, 3).unwrap();
    let on4 = cache.get_or_build(Group::Orthogonal, &d, 4).unwrap();
    assert!(!Arc::ptr_eq(&sn, &on));
    assert!(!Arc::ptr_eq(&on, &on4));
    assert_eq!(cache.stats().entries, 3);
    // The cached plans carry their own (group, n).
    assert_eq!(on4.n(), 4);
    assert_eq!(on.group(), Group::Orthogonal);
}

#[test]
fn global_cache_is_shared_and_survives_capacity_changes() {
    let g = PlanCache::global();
    let d = Diagram::identity(2);
    let a = g.get_or_build(Group::Symmetric, &d, 7).unwrap();
    let b = g.get_or_build(Group::Symmetric, &d, 7).unwrap();
    assert!(Arc::ptr_eq(&a, &b));
    // Capacity changes keep the cache usable (other tests share it, so no
    // assertions on counters — just behaviour).
    let before = g.capacity();
    g.set_capacity(before);
    let c = g.get_or_build(Group::Symmetric, &d, 7).unwrap();
    assert!(c.apply(&Tensor::linspace(7, 2)).is_ok());
}
