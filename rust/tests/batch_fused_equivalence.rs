//! Batch-axis fused execution end to end: one schedule walk per batch must
//! be numerically indistinguishable (≤ 1e-12) from the per-item reference
//! path — for both layer types, all four groups, ragged (B = 1) batches,
//! the network plumbing, and the training loop — and must stay
//! zero-allocation once the scratch arena is warm.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::fastmult::{Group, LayerSchedule, ScratchArena};
use equidiag::layer::{ChannelEquivariantLinear, EquivariantLinear, Init};
use equidiag::nn::{
    train, Activation, EquivariantNet, Loss, NetGrads, Optimizer, Sgd, TrainConfig,
};
use equidiag::tensor::{BatchTensor, Tensor};
use equidiag::util::Rng;

const GROUPS: [Group; 4] = [
    Group::Symmetric,
    Group::Orthogonal,
    Group::SpecialOrthogonal,
    Group::Symplectic,
];

fn dim_for(group: Group) -> usize {
    if group == Group::Symplectic {
        4
    } else {
        3
    }
}

#[test]
fn layer_forward_batch_matches_per_item_all_groups() {
    let mut rng = Rng::new(0xFB01);
    for group in GROUPS {
        let n = dim_for(group);
        let layer = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        // Full batch and the ragged single-item tail.
        for batch in [5usize, 1] {
            let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, 2, &mut rng)).collect();
            let batched = layer.forward_batch(&inputs).unwrap();
            assert_eq!(batched.len(), batch);
            for (v, b) in inputs.iter().zip(&batched) {
                let want = layer.forward(v).unwrap();
                assert!(
                    want.allclose(b, 1e-12),
                    "{group} B={batch}: fused batch diverges by {}",
                    want.max_abs_diff(b)
                );
            }
        }
    }
}

#[test]
fn layer_backward_batch_matches_per_item_all_groups() {
    let mut rng = Rng::new(0xFB02);
    for group in GROUPS {
        let n = dim_for(group);
        let layer = EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
        for batch in [5usize, 1] {
            let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, 2, &mut rng)).collect();
            let gouts: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, 2, &mut rng)).collect();
            // Sequential per-item reference.
            let mut want_grads = layer.zero_grads();
            let mut want_gv = Vec::new();
            for (v, g) in inputs.iter().zip(&gouts) {
                want_gv.push(layer.backward(v, g, &mut want_grads).unwrap());
            }
            // Fused batched walk.
            let mut got_grads = layer.zero_grads();
            let got_gv = layer.backward_batch(&inputs, &gouts, &mut got_grads).unwrap();
            for (a, b) in want_gv.iter().zip(&got_gv) {
                assert!(
                    a.allclose(b, 1e-12),
                    "{group} B={batch}: input grad diverges by {}",
                    a.max_abs_diff(b)
                );
            }
            for (a, b) in want_grads.coeffs.iter().zip(&got_grads.coeffs) {
                assert!((a - b).abs() <= 1e-12, "{group} B={batch}: λ grad {a} vs {b}");
            }
            for (a, b) in want_grads.bias_coeffs.iter().zip(&got_grads.bias_coeffs) {
                assert!(
                    (a - b).abs() <= 1e-12,
                    "{group} B={batch}: bias grad {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn channel_layer_batch_matches_per_item() {
    let mut rng = Rng::new(0xFB03);
    for group in [Group::Symmetric, Group::Orthogonal, Group::Symplectic] {
        let n = dim_for(group);
        let (c_in, c_out) = (2usize, 3usize);
        let layer = ChannelEquivariantLinear::new(group, n, 2, 2, c_in, c_out, &mut rng).unwrap();
        for batch in [4usize, 1] {
            let items: Vec<Vec<Tensor>> = (0..batch)
                .map(|_| (0..c_in).map(|_| Tensor::random(n, 2, &mut rng)).collect())
                .collect();
            // Forward.
            let batched = layer.forward_batch(&items).unwrap();
            assert_eq!(batched.len(), batch);
            for (x, outs) in items.iter().zip(&batched) {
                let want = layer.forward(x).unwrap();
                assert_eq!(outs.len(), c_out);
                for (a, b) in want.iter().zip(outs) {
                    assert!(
                        a.allclose(b, 1e-12),
                        "{group} B={batch}: channel forward diverges by {}",
                        a.max_abs_diff(b)
                    );
                }
            }
            // Backward.
            let gouts: Vec<Vec<Tensor>> = (0..batch)
                .map(|_| (0..c_out).map(|_| Tensor::random(n, 2, &mut rng)).collect())
                .collect();
            let mut want_grads = layer.zero_grads();
            let mut want_gx = Vec::new();
            for (x, g) in items.iter().zip(&gouts) {
                want_gx.push(layer.backward(x, g, &mut want_grads).unwrap());
            }
            let mut got_grads = layer.zero_grads();
            let got_gx = layer.backward_batch(&items, &gouts, &mut got_grads).unwrap();
            for (wi, gi) in want_gx.iter().zip(&got_gx) {
                for (a, b) in wi.iter().zip(gi) {
                    assert!(a.allclose(b, 1e-12), "{group} B={batch}: ∂x diverges");
                }
            }
            for (wt, gt) in want_grads.terms.iter().zip(&got_grads.terms) {
                for (a, b) in wt.iter().zip(gt) {
                    assert!((a - b).abs() <= 1e-12, "{group} B={batch}: λ grad {a} vs {b}");
                }
            }
            for (wb, gb) in want_grads.bias.iter().zip(&got_grads.bias) {
                for (a, b) in wb.iter().zip(gb) {
                    assert!((a - b).abs() <= 1e-12, "{group} B={batch}: bias grad");
                }
            }
        }
    }
}

#[test]
fn net_batched_plumbing_matches_per_item() {
    let mut rng = Rng::new(0xFB04);
    let net = EquivariantNet::new(
        Group::Symmetric,
        3,
        &[2, 2, 1],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap();
    let inputs: Vec<Tensor> = (0..6).map(|_| Tensor::random(3, 2, &mut rng)).collect();
    // forward_batch keeps activations batched between layers.
    let batched = net.forward_batch(&inputs).unwrap();
    for (v, b) in inputs.iter().zip(&batched) {
        let want = net.forward(v).unwrap();
        assert!(want.allclose(b, 1e-12), "diff {}", want.max_abs_diff(b));
    }
    // The traced/backward pair against the per-item reference.
    let vb = BatchTensor::pack(&inputs).unwrap();
    let (trace, out) = net.forward_trace_batched(&vb).unwrap();
    let gout = out.clone(); // dL/dout = out for L = ||out||²/2
    let (got_grads, got_gv) = net.backward_batched(&trace, &gout).unwrap();
    let mut want_grads = NetGrads {
        layers: net.layers.iter().map(|l| l.zero_grads()).collect(),
    };
    for (b, v) in inputs.iter().enumerate() {
        let (trace_i, out_i) = net.forward_trace(v).unwrap();
        assert!(out.item_tensor(b).allclose(&out_i, 1e-12));
        let (grads_i, gv_i) = net.backward(&trace_i, &out_i).unwrap();
        want_grads.add(&grads_i);
        assert!(
            got_gv.item_tensor(b).allclose(&gv_i, 1e-12),
            "input grad item {b} diverges by {}",
            got_gv.item_tensor(b).max_abs_diff(&gv_i)
        );
    }
    for (lw, lg) in want_grads.layers.iter().zip(&got_grads.layers) {
        for (a, b) in lw.coeffs.iter().zip(&lg.coeffs) {
            assert!((a - b).abs() <= 1e-11, "{a} vs {b}");
        }
        for (a, b) in lw.bias_coeffs.iter().zip(&lg.bias_coeffs) {
            assert!((a - b).abs() <= 1e-11, "{a} vs {b}");
        }
    }
}

/// The warmed scratch arena serves every batched intermediate by
/// recycling: steady-state `execute_batch` performs zero heap allocations.
#[test]
fn batched_path_is_zero_alloc_when_warm() {
    let mut rng = Rng::new(0xFB05);
    let layer =
        EquivariantLinear::new(Group::Symmetric, 3, 3, 2, Init::Normal(0.5), &mut rng).unwrap();
    let inputs: Vec<Tensor> = (0..6).map(|_| Tensor::random(3, 3, &mut rng)).collect();
    let vb = BatchTensor::pack(&inputs).unwrap();
    let schedule: &LayerSchedule = layer.schedule();
    let mut arena = ScratchArena::new();
    let mut out = BatchTensor::zeros(3, 2, 6);
    schedule
        .execute_batch(&vb, &layer.coeffs, &mut out, &mut arena)
        .unwrap();
    let warm = arena.allocations();
    assert!(warm > 0, "cold batched pass must allocate");
    for _ in 0..5 {
        out.data_mut().fill(0.0);
        schedule
            .execute_batch(&vb, &layer.coeffs, &mut out, &mut arena)
            .unwrap();
    }
    assert_eq!(
        arena.allocations(),
        warm,
        "steady-state batched execution must not heap-allocate"
    );
    assert!(arena.reuses() > 0);
}

/// Historical per-sample training loop, reproduced verbatim as the
/// reference: same RNG stream, per-sample forward/backward, per-sample
/// gradient accumulation.
fn train_per_sample_reference(
    net: &mut EquivariantNet,
    data: &[(Tensor, Tensor)],
    opt: &mut dyn Optimizer,
    cfg: &TrainConfig,
) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed);
    let mut losses = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let mut batch_loss = 0.0;
        let mut acc: Option<NetGrads> = None;
        for _ in 0..cfg.batch_size {
            let (x, y) = &data[rng.below(data.len())];
            let (trace, out) = net.forward_trace(x).unwrap();
            batch_loss += cfg.loss.value(&out, y);
            let gout = cfg.loss.grad(&out, y);
            let (grads, _) = net.backward(&trace, &gout).unwrap();
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => a.add(&grads),
            }
        }
        let mut grads = acc.expect("batch_size >= 1");
        grads.scale(1.0 / cfg.batch_size as f64);
        batch_loss /= cfg.batch_size as f64;
        let mut params = net.params_flat();
        let flat = net.grads_flat(&grads);
        opt.step(&mut params, &flat);
        net.set_params_flat(&params);
        losses.push(batch_loss);
    }
    losses
}

/// `train()` (one fused batched walk per step, single gradient reduction)
/// must reproduce the per-sample loop's loss trajectory for a fixed seed.
#[test]
fn train_matches_per_sample_loss_trajectory() {
    let n = 3;
    let mut rng = Rng::new(0xFB06);
    let net = EquivariantNet::new(
        Group::Symmetric,
        n,
        &[2, 0],
        Activation::Tanh,
        Init::Normal(0.2),
        &mut rng,
    )
    .unwrap();
    let data: Vec<(Tensor, Tensor)> = (0..24)
        .map(|_| {
            let x = Tensor::random(n, 2, &mut rng);
            let mut tr = 0.0;
            for i in 0..n {
                tr += x.get(&[i, i]);
            }
            (x, Tensor::from_vec(n, 0, vec![tr]).unwrap())
        })
        .collect();
    let cfg = TrainConfig {
        steps: 40,
        batch_size: 4,
        loss: Loss::Mse,
        log_every: 10,
        seed: 0x5EED,
        ..TrainConfig::default()
    };
    let mut net_fused = net.clone();
    let mut opt_fused = Sgd::new(0.05, 0.9);
    let report = train(&mut net_fused, &data, &mut opt_fused, &cfg).unwrap();
    let mut net_ref = net.clone();
    let mut opt_ref = Sgd::new(0.05, 0.9);
    let want = train_per_sample_reference(&mut net_ref, &data, &mut opt_ref, &cfg);
    assert_eq!(report.losses.len(), want.len());
    for (step, (a, b)) in report.losses.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-8 * (1.0 + b.abs()),
            "step {step}: fused loss {a} vs per-sample {b}"
        );
    }
    // The logged rows follow log_every and never print from the library.
    assert!(!report.logged.is_empty());
    assert_eq!(report.logged.first().unwrap().0, 0);
    assert_eq!(report.logged.last().unwrap().0, cfg.steps - 1);
}
