//! The batched engine end to end: `forward_batch` must be indistinguishable
//! (to 1e-9) from per-item `forward` for every group, at the layer, the
//! network and the coordinator level.

// The legacy forward names stay exercised until their removal.
#![allow(deprecated)]

use equidiag::config::ServerConfig;
use equidiag::coordinator::{Coordinator, ModelKind};
use equidiag::fastmult::Group;
use equidiag::layer::{EquivariantLinear, Init};
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::Tensor;
use equidiag::util::prop::{check, Config};
use equidiag::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Property: for a random layer over a random group and a random batch,
/// `forward_batch` matches per-item `forward` to 1e-9.
#[test]
fn prop_forward_batch_matches_forward_all_groups() {
    check(
        Config::default().cases(24).seed(0xBA7C4),
        "forward_batch == per-item forward",
        |rng| {
            let group = match rng.below(4) {
                0 => Group::Symmetric,
                1 => Group::Orthogonal,
                2 => Group::SpecialOrthogonal,
                _ => Group::Symplectic,
            };
            let n = if group == Group::Symplectic {
                2 * (1 + rng.below(2)) // 2 or 4
            } else {
                2 + rng.below(3) // 2..4
            };
            let k = 1 + rng.below(2); // 1..2
            let l = 1 + rng.below(2);
            let layer = EquivariantLinear::new(group, n, k, l, Init::Normal(0.5), rng)
                .map_err(|e| e.to_string())?;
            let batch = 1 + rng.below(9); // 1..9 — exercises both parallel paths
            let inputs: Vec<Tensor> = (0..batch).map(|_| Tensor::random(n, k, rng)).collect();
            let batched = layer.forward_batch(&inputs).map_err(|e| e.to_string())?;
            if batched.len() != inputs.len() {
                return Err(format!(
                    "{} outputs for {} inputs",
                    batched.len(),
                    inputs.len()
                ));
            }
            for (i, (v, b)) in inputs.iter().zip(&batched).enumerate() {
                let want = layer.forward(v).map_err(|e| e.to_string())?;
                if !want.allclose(b, 1e-9) {
                    return Err(format!(
                        "group {group} n={n} k={k} l={l} item {i}: diff {}",
                        want.max_abs_diff(b)
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn net_batch_matches_forward_for_every_group() {
    let mut rng = Rng::new(0xBEEF);
    for group in Group::ALL {
        let n = if group == Group::Symplectic { 4 } else { 3 };
        let net = EquivariantNet::new(
            group,
            n,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..16).map(|_| Tensor::random(n, 2, &mut rng)).collect();
        let batched = net.forward_batch(&inputs).unwrap();
        for (v, b) in inputs.iter().zip(&batched) {
            let want = net.forward(v).unwrap();
            assert!(
                want.allclose(b, 1e-9),
                "group {group}: diff {}",
                want.max_abs_diff(b)
            );
        }
    }
}

#[test]
fn coordinator_batched_path_serves_exact_results() {
    let mut rng = Rng::new(0xC0DE);
    let net = EquivariantNet::new(
        Group::Symmetric,
        4,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap();
    let reference = net.clone();
    // A wide window and deep batches so requests actually ride the batched
    // worker path together.
    let mut coord = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 32,
        batch_window: Duration::from_millis(2),
        queue_capacity: 512,
        ..ServerConfig::default()
    });
    coord.register("m", ModelKind::net(net));
    let handle = Arc::new(coord.start());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xD00D + t);
            let mut pairs = Vec::new();
            for _ in 0..25 {
                let v = Tensor::random(4, 2, &mut rng);
                let out = h.infer("m", v.clone()).unwrap();
                pairs.push((v, out));
            }
            pairs
        }));
    }
    for j in joins {
        for (v, got) in j.join().unwrap() {
            let want = reference.forward(&v).unwrap();
            assert!(
                want.allclose(&got, 1e-9),
                "served result diverges by {}",
                want.max_abs_diff(&got)
            );
        }
    }
    let snap = handle.metrics();
    assert_eq!(snap.completed, 100);
    assert_eq!(snap.failed, 0);
    assert!(snap.batch_execs >= 1);
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!(),
    }
}

#[test]
fn coordinator_batch_isolates_per_item_shape_errors() {
    let mut rng = Rng::new(0xF00D);
    let net = EquivariantNet::new(
        Group::Symmetric,
        3,
        &[2, 2],
        Activation::Relu,
        Init::ScaledNormal,
        &mut rng,
    )
    .unwrap();
    let reference = net.clone();
    let kind = ModelKind::net(net);
    let good = Tensor::random(3, 2, &mut rng);
    let wrong_n = Tensor::zeros(4, 2);
    let wrong_order = Tensor::zeros(3, 1);
    let results = kind.infer_batch(&[&good, &wrong_n, &good, &wrong_order]);
    assert_eq!(results.len(), 4);
    assert!(results[0].is_ok());
    assert!(results[1].is_err(), "wrong n must fail");
    assert!(results[2].is_ok());
    assert!(results[3].is_err(), "wrong order must fail");
    // And the good items still computed the right thing.
    let want = reference.forward(&good).unwrap();
    for i in [0usize, 2] {
        let got = results[i].as_ref().unwrap();
        assert!(
            want.allclose(got, 1e-9),
            "item {i} diverges by {}",
            want.max_abs_diff(got)
        );
    }
}
