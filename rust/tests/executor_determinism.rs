//! Determinism-under-stealing stress suite: the work-stealing executor
//! must never change bits. Results are fixed by the chunk partition
//! (a function of `(len, threads)` only), never by which worker runs or
//! steals a chunk — so any fixed thread budget must reproduce itself
//! across repeated runs (different steal interleavings), and explicit
//! pools of different sizes must agree bitwise for the same budget.
//! Run by name in CI (`cargo test --test executor_determinism`).

use equidiag::fastmult::Group;
use equidiag::layer::Init;
use equidiag::nn::{Activation, EquivariantNet};
use equidiag::tensor::Tensor;
use equidiag::util::executor::hw_threads;
use equidiag::util::{
    parallel_map, parallel_map_on, set_thread_budget, thread_budget, Executor, Rng,
};

/// A small net per group (Sp(n) needs even n).
fn net_for(group: Group, seed: u64) -> EquivariantNet {
    let n = match group {
        Group::Symplectic => 4,
        _ => 3,
    };
    let mut rng = Rng::new(seed);
    EquivariantNet::new(group, n, &[2, 2], Activation::Relu, Init::ScaledNormal, &mut rng)
        .unwrap()
}

fn inputs_for(net_n: usize, count: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| Tensor::random(net_n, 2, &mut rng))
        .collect()
}

/// One full forward + backward through `net`, returning every output bit:
/// per-item outputs, summed parameter gradients, per-item input gradients.
fn fwd_bwd(net: &EquivariantNet, inputs: &[Tensor]) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let outputs = net.forward_batch(inputs).unwrap();
    let traced: Vec<_> = net.forward_trace_batch(inputs).unwrap();
    let traces: Vec<_> = traced.iter().map(|(t, _)| t.clone()).collect();
    // Use the outputs themselves as output gradients: deterministic and
    // shape-correct without dragging in a loss.
    let grad_outs: Vec<Tensor> = traced.into_iter().map(|(_, out)| out).collect();
    let (grads, grad_inputs) = net.backward_batch(&traces, &grad_outs).unwrap();
    (
        outputs.into_iter().map(|t| t.data).collect(),
        net.grads_flat(&grads),
        grad_inputs.into_iter().map(|t| t.data).collect(),
    )
}

/// The tentpole equivalence: for all four groups, full model forward and
/// backward passes are **bitwise** identical across thread budgets 1, 2,
/// and the hardware count, and across repeated runs at each budget (each
/// run sees a different steal interleaving on the shared pool).
///
/// Single test on purpose: the thread budget is process-global, so the
/// sweep must not interleave with itself.
#[test]
fn model_fwd_bwd_bitwise_identical_across_thread_budgets() {
    let prior = thread_budget();
    let budgets = [1usize, 2, hw_threads()];
    for (gi, group) in Group::ALL.into_iter().enumerate() {
        let net = net_for(group, 4200 + gi as u64);
        let n = match group {
            Group::Symplectic => 4,
            _ => 3,
        };
        let inputs = inputs_for(n, 12, 4300 + gi as u64);
        set_thread_budget(1);
        let reference = fwd_bwd(&net, &inputs);
        for &budget in &budgets {
            set_thread_budget(budget);
            for run in 0..3 {
                let got = fwd_bwd(&net, &inputs);
                assert_eq!(
                    got, reference,
                    "group {group}: budget {budget} run {run} changed bits"
                );
            }
        }
    }
    set_thread_budget(prior);
}

/// Explicit pools of size 1, 2 and hw agree bitwise with each other and
/// with the global pool, for the same requested thread count — the chunk
/// partition depends on the thread argument, never the pool size.
#[test]
fn explicit_pool_sizes_bitwise_identical() {
    let items: Vec<usize> = (0..257).collect();
    let f = |&i: &usize| {
        // Non-associative float accumulation: any ordering change between
        // runs would move bits.
        let mut acc = 0.0f64;
        for j in 0..100 {
            acc += ((i * 31 + j) as f64).sin() * 1e-3;
        }
        acc
    };
    for threads in [1usize, 2, 4] {
        let reference = parallel_map(&items, threads, f);
        for workers in [1usize, 2, hw_threads()] {
            let pool = Executor::new(workers);
            let got = parallel_map_on(&pool, &items, threads, f);
            assert_eq!(
                got, reference,
                "pool size {workers} at {threads} threads changed bits"
            );
        }
    }
}

/// Stealing stress: many repeated fan-outs on one hardware-sized pool,
/// with uneven task costs to force steals, stay bitwise stable.
#[test]
fn repeated_runs_under_stealing_are_stable() {
    let pool = Executor::new(hw_threads());
    let items: Vec<usize> = (0..512).collect();
    let f = |&i: &usize| {
        // Skewed cost: early items are ~64x the work of late ones, so
        // whichever worker draws the head gets robbed by the others.
        let iters = 16 + (512 - i) / 8;
        let mut acc = 0.0f64;
        for j in 0..iters {
            acc += ((i + j) as f64).cos() * 1e-4;
        }
        acc
    };
    let threads = hw_threads().max(2);
    let reference = parallel_map_on(&pool, &items, threads, f);
    for run in 0..20 {
        let got = parallel_map_on(&pool, &items, threads, f);
        assert_eq!(got, reference, "run {run} changed bits under stealing");
    }
}
