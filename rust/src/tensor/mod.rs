//! Dense tensor substrate for `(R^n)^{⊗k}`.
//!
//! Every layer space in the paper is a tensor power of `R^n`, so a tensor
//! here is a cube: `order` axes, each of extent `n`, stored row-major. The
//! module provides exactly the primitives Algorithm 1 needs:
//!
//! - axis permutation ([`TensorOf::permute_axes`]) — the `Permute`
//!   procedure,
//! - trailing diagonal contraction
//!   ([`TensorOf::contract_trailing_diagonal`]) — S_n Step 1 (eq. 98),
//! - trailing pair trace ([`TensorOf::trace_trailing_pair`]) — O(n)/SO(n)
//!   Step 1 (eq. 122),
//! - ε-weighted pair trace ([`TensorOf::trace_trailing_pair_eps`]) — Sp(n)
//!   Step 1 (eq. 138),
//! - Levi-Civita contraction
//!   ([`TensorOf::levi_civita_contract_trailing`]) — SO(n) free-vertex
//!   Step 1 (eq. 157),
//! - group-diagonal extraction ([`TensorOf::extract_group_diagonals`]) —
//!   S_n Step 2 transfer (eq. 101),
//! - mode product ([`Tensor::mode_apply`]) — the group action `ρ_k(g)` used
//!   by the equivariance tests,
//! - the contiguous `[B, n^k]` batch layout ([`BatchTensor`]) with batched
//!   variants of every kernel above, sharing one precomputed index map
//!   across all `B` items (see `docs/batched_execution.md`).
//!
//! The whole stack is generic over the sealed [`Scalar`] trait (`f64` and
//! `f32`, see `docs/scalar_precision.md`): [`TensorOf<S>`] is the generic
//! struct, and the [`Tensor`] / [`BatchTensor`] aliases pin `S = f64` so
//! existing call sites read unchanged. Weights and coefficients stay `f64`
//! masters everywhere; kernels convert them once per invocation via
//! [`Scalar::from_f64`], which for `S = f64` is the identity — the `f64`
//! instantiation is bitwise identical to the historical hard-coded path.

mod batch;
mod index;
mod ops;
mod scalar;

pub use batch::{BatchTensor, BatchTensorOf};
pub use index::{flat_index, tile_spans, unflat_index, MultiIndexIter};
pub use scalar::{Precision, Scalar};
// Lane-chunked elementwise helpers and the ramp detector, shared with the
// schedule executor's scatter fast paths.
pub(crate) use scalar::{axpy_slice, ramp_base, scale_slice};
// Index-map builders shared with the schedule compiler's kernel plans
// (`fastmult::schedule` precomputes every table once per compiled schedule
// and replays it on the warm path).
pub(crate) use ops::{
    axis_strides, group_diag_offsets, levi_civita_entries, permute_block_map, permute_dst_map,
    permuted_gather_base, permuted_group_diag_offsets, scatter_diag_dsts,
};
// Tile-windowed kernel slabs for the cache-blocked streaming walk (see
// `docs/tiled_execution.md`): each replays the exact per-element loop body
// of its full kernel over one `[lo, hi)` output window.
pub(crate) use ops::{
    contract_diag_window, gather_contract_window, gather_eps_trace_window, gather_window,
    permute_blocks_window, trace_eps_window,
};

use crate::error::{Error, Result};
use crate::util::Rng;

/// A dense element of `(R^n)^{⊗order}` over scalar type `S`, stored
/// row-major (axis 0 is the slowest-varying index).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorOf<S: Scalar> {
    /// Extent of every axis.
    pub n: usize,
    /// Number of axes `k` (the tensor power order). `order == 0` is the
    /// scalar space `R`.
    pub order: usize,
    /// Row-major coefficients, `len == n.pow(order)`.
    pub data: Vec<S>,
}

/// The training-precision tensor — the alias the rest of the crate (and
/// every pre-existing call site) uses.
pub type Tensor = TensorOf<f64>;

impl<S: Scalar> TensorOf<S> {
    /// All-zeros tensor.
    pub fn zeros(n: usize, order: usize) -> Self {
        TensorOf {
            n,
            order,
            data: vec![S::ZERO; n.pow(order as u32)],
        }
    }

    /// Tensor filled with `0, 1, 2, ...` scaled to `[0, 1]` — deterministic
    /// test data with all-distinct entries.
    pub fn linspace(n: usize, order: usize) -> Self {
        let len = n.pow(order as u32);
        let denom = (len.max(2) - 1) as f64;
        TensorOf {
            n,
            order,
            data: (0..len).map(|i| S::from_f64(i as f64 / denom)).collect(),
        }
    }

    /// Tensor with iid standard-normal entries (drawn in `f64`, then
    /// narrowed — so an `f32` tensor holds the rounded values of the `f64`
    /// tensor the same seed produces).
    pub fn random(n: usize, order: usize, rng: &mut Rng) -> Self {
        let len = n.pow(order as u32);
        TensorOf {
            n,
            order,
            data: rng.gaussian_vec(len).into_iter().map(S::from_f64).collect(),
        }
    }

    /// Wrap an existing buffer.
    pub fn from_vec(n: usize, order: usize, data: Vec<S>) -> Result<Self> {
        let expect = n.pow(order as u32);
        if data.len() != expect {
            return Err(Error::ShapeMismatch {
                expected: format!("n^order = {expect}"),
                got: format!("{}", data.len()),
            });
        }
        Ok(TensorOf { n, order, data })
    }

    /// Elementwise narrowing/widening conversion to another scalar type
    /// (via `f64`, so `f32 → f64` is exact and `f64 → f32` rounds once).
    pub fn cast<T: Scalar>(&self) -> TensorOf<T> {
        TensorOf {
            n: self.n,
            order: self.order,
            data: self
                .data
                .iter()
                .map(|&x| T::from_f64(x.to_f64()))
                .collect(),
        }
    }

    /// Number of coefficients, `n^order`.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when `order == 0` would still hold one scalar; tensors are
    /// never empty unless `n == 0`.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Coefficient at a multi-index.
    #[inline]
    pub fn get(&self, idx: &[usize]) -> S {
        self.data[flat_index(self.n, idx)]
    }

    /// Assign the coefficient at a multi-index.
    #[inline]
    pub fn set(&mut self, idx: &[usize], v: S) {
        let f = flat_index(self.n, idx);
        self.data[f] = v;
    }

    /// Iterator over all multi-indices of this tensor.
    pub fn indices(&self) -> MultiIndexIter {
        MultiIndexIter::new(self.n, self.order)
    }

    /// Max absolute difference against another tensor of the same shape
    /// (computed in `S`, reported in `f64`).
    pub fn max_abs_diff(&self, other: &TensorOf<S>) -> f64 {
        assert_eq!(self.n, other.n);
        assert_eq!(self.order, other.order);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(S::ZERO, S::max)
            .to_f64()
    }

    /// Approximate equality within `tol` (absolute, entrywise).
    pub fn allclose(&self, other: &TensorOf<S>, tol: f64) -> bool {
        self.n == other.n && self.order == other.order && self.max_abs_diff(other) <= tol
    }

    /// Euclidean norm of the coefficient vector (accumulated in `S`, root
    /// taken in `f64`).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum::<S>().to_f64().sqrt()
    }

    /// Scale in place (lane-chunked; bitwise equal to the scalar loop).
    pub fn scale(&mut self, s: f64) {
        scale_slice(S::from_f64(s), &mut self.data);
    }

    /// `self += alpha * other` (shapes must match; lane-chunked, bitwise
    /// equal to the scalar loop).
    pub fn axpy(&mut self, alpha: f64, other: &TensorOf<S>) {
        assert_eq!(self.n, other.n);
        assert_eq!(self.order, other.order);
        axpy_slice(S::from_f64(alpha), &other.data, &mut self.data);
    }

    /// Inner product of coefficient vectors (accumulated in `S` in element
    /// order, reported in `f64`).
    pub fn dot(&self, other: &TensorOf<S>) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a * b)
            .sum::<S>()
            .to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_len() {
        let t = Tensor::zeros(3, 4);
        assert_eq!(t.len(), 81);
        assert_eq!(t.order, 4);
    }

    #[test]
    fn order_zero_is_scalar() {
        let t = Tensor::zeros(5, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = Tensor::zeros(3, 3);
        t.set(&[1, 2, 0], 7.5);
        assert_eq!(t.get(&[1, 2, 0]), 7.5);
        assert_eq!(t.get(&[0, 2, 1]), 0.0);
    }

    #[test]
    fn linspace_distinct() {
        let t = Tensor::linspace(2, 3);
        let mut sorted = t.data.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(2, 2, vec![0.0; 4]).is_ok());
        assert!(Tensor::from_vec(2, 2, vec![0.0; 5]).is_err());
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = Tensor::zeros(2, 1);
        let b = Tensor::from_vec(2, 1, vec![3.0, 4.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![6.0, 8.0]);
        assert!((a.norm() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn f32_tensor_roundtrips_through_cast() {
        let a = Tensor::linspace(3, 2);
        let b: TensorOf<f32> = a.cast();
        let c: Tensor = b.cast();
        assert_eq!(b.n, 3);
        assert_eq!(b.order, 2);
        // f64 → f32 → f64 keeps every linspace value within f32 tolerance.
        assert!(a.allclose(&c, f32::TOLERANCE));
    }

    #[test]
    fn generic_reductions_match_f64_reference() {
        let a32: TensorOf<f32> = Tensor::linspace(2, 3).cast();
        let b32: TensorOf<f32> = {
            let mut b = Tensor::linspace(2, 3);
            b.scale(-0.5);
            b.cast()
        };
        let dot = a32.dot(&b32);
        let mut want = 0.0f32;
        for (&x, &y) in a32.data.iter().zip(&b32.data) {
            want += x * y;
        }
        assert_eq!(dot, want as f64);
    }
}
