//! Dense tensor substrate for `(R^n)^{⊗k}`.
//!
//! Every layer space in the paper is a tensor power of `R^n`, so a tensor
//! here is a cube: `order` axes, each of extent `n`, stored row-major. The
//! module provides exactly the primitives Algorithm 1 needs:
//!
//! - axis permutation ([`Tensor::permute_axes`]) — the `Permute` procedure,
//! - trailing diagonal contraction ([`Tensor::contract_trailing_diagonal`])
//!   — S_n Step 1 (eq. 98),
//! - trailing pair trace ([`Tensor::trace_trailing_pair`]) — O(n)/SO(n)
//!   Step 1 (eq. 122),
//! - ε-weighted pair trace ([`Tensor::trace_trailing_pair_eps`]) — Sp(n)
//!   Step 1 (eq. 138),
//! - Levi-Civita contraction ([`Tensor::levi_civita_contract_trailing`]) —
//!   SO(n) free-vertex Step 1 (eq. 157),
//! - group-diagonal extraction ([`Tensor::extract_group_diagonals`]) — S_n
//!   Step 2 transfer (eq. 101),
//! - mode product ([`Tensor::mode_apply`]) — the group action `ρ_k(g)` used
//!   by the equivariance tests,
//! - the contiguous `[B, n^k]` batch layout ([`BatchTensor`]) with batched
//!   variants of every kernel above, sharing one precomputed index map
//!   across all `B` items (see `docs/batched_execution.md`).

mod batch;
mod index;
mod ops;

pub use batch::BatchTensor;
pub use index::{flat_index, unflat_index, MultiIndexIter};
// Index-map builders shared with the schedule compiler's kernel plans
// (`fastmult::schedule` precomputes every table once per compiled schedule
// and replays it on the warm path).
pub(crate) use ops::{
    axis_strides, group_diag_offsets, levi_civita_entries, permute_block_map, permute_dst_map,
    permuted_gather_base, permuted_group_diag_offsets, scatter_diag_dsts,
};

use crate::error::{Error, Result};
use crate::util::Rng;

/// A dense element of `(R^n)^{⊗order}` stored row-major
/// (axis 0 is the slowest-varying index).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Extent of every axis.
    pub n: usize,
    /// Number of axes `k` (the tensor power order). `order == 0` is the
    /// scalar space `R`.
    pub order: usize,
    /// Row-major coefficients, `len == n.pow(order)`.
    pub data: Vec<f64>,
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(n: usize, order: usize) -> Self {
        Tensor {
            n,
            order,
            data: vec![0.0; n.pow(order as u32)],
        }
    }

    /// Tensor filled with `0, 1, 2, ...` scaled to `[0, 1]` — deterministic
    /// test data with all-distinct entries.
    pub fn linspace(n: usize, order: usize) -> Self {
        let len = n.pow(order as u32);
        let denom = (len.max(2) - 1) as f64;
        Tensor {
            n,
            order,
            data: (0..len).map(|i| i as f64 / denom).collect(),
        }
    }

    /// Tensor with iid standard-normal entries.
    pub fn random(n: usize, order: usize, rng: &mut Rng) -> Self {
        let len = n.pow(order as u32);
        Tensor {
            n,
            order,
            data: rng.gaussian_vec(len),
        }
    }

    /// Wrap an existing buffer.
    pub fn from_vec(n: usize, order: usize, data: Vec<f64>) -> Result<Self> {
        let expect = n.pow(order as u32);
        if data.len() != expect {
            return Err(Error::ShapeMismatch {
                expected: format!("n^order = {expect}"),
                got: format!("{}", data.len()),
            });
        }
        Ok(Tensor { n, order, data })
    }

    /// Number of coefficients, `n^order`.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when `order == 0` would still hold one scalar; tensors are
    /// never empty unless `n == 0`.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Coefficient at a multi-index.
    #[inline]
    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[flat_index(self.n, idx)]
    }

    /// Assign the coefficient at a multi-index.
    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f64) {
        let f = flat_index(self.n, idx);
        self.data[f] = v;
    }

    /// Iterator over all multi-indices of this tensor.
    pub fn indices(&self) -> MultiIndexIter {
        MultiIndexIter::new(self.n, self.order)
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.n, other.n);
        assert_eq!(self.order, other.order);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Approximate equality within `tol` (absolute, entrywise).
    pub fn allclose(&self, other: &Tensor, tol: f64) -> bool {
        self.n == other.n && self.order == other.order && self.max_abs_diff(other) <= tol
    }

    /// Euclidean norm of the coefficient vector.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += alpha * other` (shapes must match).
    pub fn axpy(&mut self, alpha: f64, other: &Tensor) {
        assert_eq!(self.n, other.n);
        assert_eq!(self.order, other.order);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Inner product of coefficient vectors.
    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_len() {
        let t = Tensor::zeros(3, 4);
        assert_eq!(t.len(), 81);
        assert_eq!(t.order, 4);
    }

    #[test]
    fn order_zero_is_scalar() {
        let t = Tensor::zeros(5, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = Tensor::zeros(3, 3);
        t.set(&[1, 2, 0], 7.5);
        assert_eq!(t.get(&[1, 2, 0]), 7.5);
        assert_eq!(t.get(&[0, 2, 1]), 0.0);
    }

    #[test]
    fn linspace_distinct() {
        let t = Tensor::linspace(2, 3);
        let mut sorted = t.data.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(2, 2, vec![0.0; 4]).is_ok());
        assert!(Tensor::from_vec(2, 2, vec![0.0; 5]).is_err());
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = Tensor::zeros(2, 1);
        let b = Tensor::from_vec(2, 1, vec![3.0, 4.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![6.0, 8.0]);
        assert!((a.norm() - 10.0).abs() < 1e-12);
    }
}
