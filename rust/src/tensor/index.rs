//! Multi-index ↔ flat offset conversion and iteration for cube tensors.

/// Row-major flat offset of `idx` in a cube of side `n`
/// (axis 0 slowest-varying).
#[inline]
pub fn flat_index(n: usize, idx: &[usize]) -> usize {
    let mut f = 0usize;
    for &i in idx {
        debug_assert!(i < n);
        f = f * n + i;
    }
    f
}

/// Inverse of [`flat_index`]: decode `flat` into `order` digits base `n`.
pub fn unflat_index(n: usize, order: usize, mut flat: usize) -> Vec<usize> {
    let mut idx = vec![0usize; order];
    for a in (0..order).rev() {
        idx[a] = flat % n;
        flat /= n;
    }
    idx
}

/// Iterator over all multi-indices of a cube tensor, in row-major order.
pub struct MultiIndexIter {
    n: usize,
    idx: Vec<usize>,
    started: bool,
    done: bool,
}

impl MultiIndexIter {
    /// All indices of an `order`-dimensional cube of side `n`.
    pub fn new(n: usize, order: usize) -> Self {
        MultiIndexIter {
            n,
            idx: vec![0; order],
            started: false,
            done: n == 0 && order > 0,
        }
    }

    /// Advance and return the next multi-index (borrowed).
    pub fn next_index(&mut self) -> Option<&[usize]> {
        if self.done {
            return None;
        }
        if !self.started {
            self.started = true;
            return Some(&self.idx);
        }
        // Odometer increment from the last axis.
        let order = self.idx.len();
        let mut a = order;
        loop {
            if a == 0 {
                self.done = true;
                return None;
            }
            a -= 1;
            self.idx[a] += 1;
            if self.idx[a] < self.n {
                break;
            }
            self.idx[a] = 0;
        }
        Some(&self.idx)
    }
}

/// Disjoint `[lo, hi)` slabs of width `span` covering `0..len` in order
/// (the last slab is ragged when `span ∤ len`). The tiled schedule walk
/// iterates these per chain; pulling the arithmetic into one helper
/// keeps the walk, its tests and the benches counting identical slabs.
pub fn tile_spans(len: usize, span: usize) -> impl Iterator<Item = (usize, usize)> {
    debug_assert!(span >= 1);
    (0..len).step_by(span.max(1)).map(move |lo| (lo, (lo + span).min(len)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_spans_cover_disjointly() {
        for (len, span) in [(10usize, 3usize), (9, 3), (1, 4), (8, 8), (7, 1)] {
            let spans: Vec<_> = tile_spans(len, span).collect();
            let mut expect_lo = 0usize;
            for &(lo, hi) in &spans {
                assert_eq!(lo, expect_lo);
                assert!(hi > lo && hi <= len);
                assert!(hi - lo <= span);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, len, "slabs must cover 0..{len}");
        }
        assert_eq!(tile_spans(0, 4).count(), 0);
    }

    #[test]
    fn flat_roundtrip() {
        let n: usize = 3;
        let order = 4;
        for f in 0..n.pow(order as u32) {
            let idx = unflat_index(n, order, f);
            assert_eq!(flat_index(n, &idx), f);
        }
    }

    #[test]
    fn iter_covers_all_in_order() {
        let mut it = MultiIndexIter::new(2, 3);
        let mut count = 0usize;
        while let Some(idx) = it.next_index() {
            assert_eq!(flat_index(2, idx), count);
            count += 1;
        }
        assert_eq!(count, 8);
    }

    #[test]
    fn iter_order_zero_yields_one_empty_index() {
        let mut it = MultiIndexIter::new(4, 0);
        assert_eq!(it.next_index(), Some(&[][..]));
        assert!(it.next_index().is_none());
    }
}
