//! The sealed [`Scalar`] trait: the element types the tensor stack is
//! generic over (`f64` and `f32`).
//!
//! Everything numeric in the execution stack — tensors, batches, arena
//! buffers, kernel inner loops — is parameterised by a `Scalar`. The trait
//! is **sealed**: exactly two implementations exist, so downstream code can
//! rely on every `Scalar` being an IEEE-754 float with the usual semantics,
//! and the crate can add methods without a semver break.
//!
//! Design constraints the trait encodes (see `docs/scalar_precision.md`):
//!
//! - **Master coefficients stay `f64`.** Layer weights, diagram
//!   coefficients and signs are stored in `f64` everywhere; generic kernels
//!   accept `f64` scalars and convert once per kernel invocation via
//!   [`Scalar::from_f64`]. For `S = f64` that conversion is the identity,
//!   which is what makes the `f64` instantiation bitwise identical to the
//!   historical hard-coded-`f64` code path.
//! - **No FMA in kernels.** [`Scalar::mul_add`] exists for callers that
//!   want it, but the schedule kernels never use it: contracting `a*b + c`
//!   into one fused operation changes results at the ULP level and would
//!   break the bitwise run-to-run and seed-compatibility guarantees.
//! - **Lane width is a hint, not a SIMD binding.** [`Scalar::LANES`] sizes
//!   the `chunks_exact` blocks the elementwise kernels use so LLVM's
//!   autovectorizer sees fixed-width, branch-free inner loops (no `unsafe`,
//!   no intrinsics). 4×f64 / 8×f32 matches one 256-bit vector register.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

mod sealed {
    /// Prevents downstream `Scalar` impls (the kernels assume IEEE floats).
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// Element type of the tensor stack: `f64` (training default) or `f32`
/// (halved memory traffic for inference). Sealed — see the module docs.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Default
    + Debug
    + Display
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + Sum<Self>
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Comparison tolerance natural to this precision: the scale factor
    /// equivalence tests multiply into their `f64`-derived bounds. Chosen
    /// as ~2³ ULP at magnitude 1 (`f64`: 1e-15, `f32`: 1e-6).
    const TOLERANCE: f64;
    /// Elementwise-kernel chunk width: how many elements fill one 256-bit
    /// vector register (4 for `f64`, 8 for `f32`).
    const LANES: usize;
    /// `size_of::<Self>()` as a const, for measured-bytes accounting.
    const BYTES: usize;
    /// `"f64"` / `"f32"` — used by the precision config and bench rows.
    const NAME: &'static str;

    /// Narrowing (or identity) conversion from an `f64` master value.
    fn from_f64(x: f64) -> Self;
    /// Widening (or identity) conversion to `f64`.
    fn to_f64(self) -> f64;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Square root.
    fn sqrt(self) -> Self;
    /// Hyperbolic tangent (the `Tanh` activation's elementwise op).
    fn tanh(self) -> Self;
    /// Integer power.
    fn powi(self, e: i32) -> Self;
    /// IEEE maximum of two values.
    fn max(self, other: Self) -> Self;
    /// Fused multiply-add `self * a + b`. **Not used by the schedule
    /// kernels** (it would break bitwise reproducibility); provided for
    /// callers that explicitly opt into fused rounding.
    fn mul_add(self, a: Self, b: Self) -> Self;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TOLERANCE: f64 = 1e-15;
    const LANES: usize = 4;
    const BYTES: usize = 8;
    const NAME: &'static str = "f64";

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline(always)]
    fn tanh(self) -> Self {
        f64::tanh(self)
    }
    #[inline(always)]
    fn powi(self, e: i32) -> Self {
        f64::powi(self, e)
    }
    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f64::mul_add(self, a, b)
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TOLERANCE: f64 = 1e-6;
    const LANES: usize = 8;
    const BYTES: usize = 4;
    const NAME: &'static str = "f32";

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline(always)]
    fn tanh(self) -> Self {
        f32::tanh(self)
    }
    #[inline(always)]
    fn powi(self, e: i32) -> Self {
        f32::powi(self, e)
    }
    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f32::mul_add(self, a, b)
    }
}

/// Runtime selector between the two [`Scalar`] instantiations — the value
/// form of the type parameter, used where the scalar type is chosen by
/// configuration (`[model] precision`) rather than at the call site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Execute in `f64` (the training default; bitwise-reference path).
    #[default]
    F64,
    /// Execute in `f32` (halved memory traffic on the bandwidth-bound
    /// schedule walks; results within the scaled `f32` tolerance).
    F32,
}

impl Precision {
    /// Canonical config spelling (`"f64"` / `"f32"`).
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => f64::NAME,
            Precision::F32 => f32::NAME,
        }
    }

    /// Parse a config string (case-insensitive). Accepts `f64`/`float64`/
    /// `double` and `f32`/`float32`/`single`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "float64" | "double" => Some(Precision::F64),
            "f32" | "float32" | "single" => Some(Precision::F32),
            _ => None,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Lane-chunked `y[i] += alpha · x[i]` over equal-length slices — the one
/// elementwise axpy every vectorized kernel funnels through. Each element
/// is updated by exactly one multiply and one add in the same order as the
/// plain scalar loop (no reassociation, no FMA), so results are **bitwise
/// identical** to the naive form; the fixed-width body only lets LLVM emit
/// vector instructions for it.
#[inline]
pub(crate) fn axpy_slice<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    let mut xs = x.chunks_exact(S::LANES);
    let mut ys = y.chunks_exact_mut(S::LANES);
    for (yc, xc) in (&mut ys).zip(&mut xs) {
        for j in 0..S::LANES {
            yc[j] += alpha * xc[j];
        }
    }
    for (yv, xv) in ys.into_remainder().iter_mut().zip(xs.remainder()) {
        *yv += alpha * *xv;
    }
}

/// Lane-chunked `y[i] *= alpha` (see [`axpy_slice`] for the bitwise
/// argument).
#[inline]
pub(crate) fn scale_slice<S: Scalar>(alpha: S, y: &mut [S]) {
    let mut ys = y.chunks_exact_mut(S::LANES);
    for yc in &mut ys {
        for v in yc.iter_mut() {
            *v *= alpha;
        }
    }
    for v in ys.into_remainder() {
        *v *= alpha;
    }
}

/// Is `rep` the contiguous ramp `base, base+1, …`? Returns the base when
/// it is — the scatter-axpy kernels use this to route identity-layout
/// destination maps through the lane-chunked [`axpy_slice`] instead of the
/// scalar indirect scatter. Early-exits on the first mismatch, so
/// non-trivial maps pay O(1).
#[inline]
pub(crate) fn ramp_base(rep: &[usize]) -> Option<usize> {
    let &base = rep.first()?;
    for (j, &d) in rep.iter().enumerate() {
        if d != base + j {
            return None;
        }
    }
    Some(base)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_consistent() {
        assert_eq!(<f64 as Scalar>::BYTES, std::mem::size_of::<f64>());
        assert_eq!(<f32 as Scalar>::BYTES, std::mem::size_of::<f32>());
        assert_eq!(<f64 as Scalar>::LANES * 8, <f32 as Scalar>::LANES * 4);
        assert_eq!(<f64 as Scalar>::NAME, "f64");
        assert_eq!(<f32 as Scalar>::NAME, "f32");
    }

    #[test]
    fn from_f64_is_identity_for_f64() {
        for x in [0.0, -1.5, std::f64::consts::PI, 1e-300, f64::MAX] {
            assert_eq!(<f64 as Scalar>::from_f64(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn axpy_slice_matches_scalar_loop_bitwise() {
        fn run<S: Scalar>() {
            let n = 4 * S::LANES + 3; // exercises the remainder
            let x: Vec<S> = (0..n).map(|i| S::from_f64(0.37 * i as f64 - 1.0)).collect();
            let mut y: Vec<S> = (0..n).map(|i| S::from_f64(1.0 / (i + 1) as f64)).collect();
            let mut want = y.clone();
            let alpha = S::from_f64(-0.625);
            for (w, &xv) in want.iter_mut().zip(&x) {
                *w += alpha * xv;
            }
            axpy_slice(alpha, &x, &mut y);
            assert_eq!(y, want);
        }
        run::<f64>();
        run::<f32>();
    }

    #[test]
    fn scale_slice_matches_scalar_loop_bitwise() {
        fn run<S: Scalar>() {
            let n = 2 * S::LANES + 1;
            let mut y: Vec<S> = (0..n).map(|i| S::from_f64(0.11 * i as f64)).collect();
            let mut want = y.clone();
            let alpha = S::from_f64(3.5);
            for w in &mut want {
                *w *= alpha;
            }
            scale_slice(alpha, &mut y);
            assert_eq!(y, want);
        }
        run::<f64>();
        run::<f32>();
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!(Precision::parse("F64"), Some(Precision::F64));
        assert_eq!(Precision::parse("float32"), Some(Precision::F32));
        assert_eq!(Precision::parse("half"), None);
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn ramp_base_detects_ramps_only() {
        assert_eq!(ramp_base(&[5, 6, 7, 8]), Some(5));
        assert_eq!(ramp_base(&[0]), Some(0));
        assert_eq!(ramp_base(&[]), None);
        assert_eq!(ramp_base(&[5, 7, 8]), None);
        assert_eq!(ramp_base(&[3, 2, 1]), None);
    }
}
