//! Tensor operations backing Algorithm 1 and the equivariance tests.
//!
//! The contraction primitives all act on *trailing* axes: `Factor` already
//! permutes the input so the axes to be consumed sit at the end, which makes
//! every inner loop here a contiguous or constant-stride sweep — this is the
//! optimisation the paper's "algorithmically planar" layout buys.

use super::index::flat_index;
use super::scalar::{axpy_slice, ramp_base};
use super::{Scalar, TensorOf};

impl<S: Scalar> TensorOf<S> {
    /// Axis permutation (the paper's `Permute`, eq. 90, as a memory move).
    ///
    /// numpy `transpose` semantics: output axis `q` carries input axis
    /// `axes[q]`, i.e. `out[I] = self[J]` where `J[axes[q]] = I[q]`.
    ///
    /// Write-once: the output buffer is filled in destination order with no
    /// zero-fill pass, and any unmoved trailing axes are copied as whole
    /// contiguous blocks (the blocked kernel — one `memcpy` per leading
    /// multi-index instead of an elementwise odometer).
    pub fn permute_axes(&self, axes: &[usize]) -> TensorOf<S> {
        self.check_axes(axes);
        // Identity fast path — common when Factor finds the diagram already
        // planar (e.g. every cross-only Brauer diagram).
        if axes.iter().enumerate().all(|(i, &a)| i == a) {
            return self.clone();
        }
        let mut data = Vec::with_capacity(self.data.len());
        self.permute_scan(axes, |block| data.extend_from_slice(block));
        TensorOf {
            n: self.n,
            order: self.order,
            data,
        }
    }

    /// [`Tensor::permute_axes`] into a caller-provided buffer (typically a
    /// recycled [`crate::fastmult::ScratchArena`] tensor). Every element of
    /// `out` is overwritten, so stale contents are fine.
    pub fn permute_axes_into(&self, axes: &[usize], out: &mut TensorOf<S>) {
        self.check_axes(axes);
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, self.order);
        if axes.iter().enumerate().all(|(i, &a)| i == a) {
            out.data.copy_from_slice(&self.data);
            return;
        }
        let mut dst = 0usize;
        self.permute_scan(axes, |block| {
            out.data[dst..dst + block.len()].copy_from_slice(block);
            dst += block.len();
        });
    }

    fn check_axes(&self, axes: &[usize]) {
        assert_eq!(axes.len(), self.order, "axes arity must match order");
        debug_assert!({
            let mut seen = vec![false; self.order];
            axes.iter().all(|&a| {
                let fresh = !seen[a];
                seen[a] = true;
                fresh
            })
        });
    }

    /// Core of the permute kernel: visit the permuted data in destination
    /// order, emitting maximal contiguous source blocks. The longest suffix
    /// of unmoved axes (`axes[q] == q`) forms a contiguous block in both
    /// layouts, so only the leading axes need the odometer.
    fn permute_scan(&self, axes: &[usize], mut emit: impl FnMut(&[S])) {
        let n = self.n;
        let order = self.order;
        let mut tail = 0usize;
        while tail < order && axes[order - 1 - tail] == order - 1 - tail {
            tail += 1;
        }
        let lead = order - tail;
        if lead == 0 {
            emit(&self.data);
            return;
        }
        // Strides of the input axes as seen from the output's odometer:
        // moving output axis a by 1 moves input axis axes[a] by its stride.
        let mut strides = vec![0usize; order];
        {
            let mut s = 1usize;
            for a in (0..order).rev() {
                strides[a] = s;
                s *= n;
            }
        }
        let lead_strides: Vec<usize> = axes[..lead].iter().map(|&a| strides[a]).collect();
        let block = n.pow(tail as u32);
        let blocks = n.pow(lead as u32);
        let mut idx = vec![0usize; lead];
        let mut src = 0usize;
        for _ in 0..blocks {
            emit(&self.data[src..src + block]);
            // odometer increment with incremental source offset update
            let mut a = lead;
            loop {
                if a == 0 {
                    break;
                }
                a -= 1;
                idx[a] += 1;
                src += lead_strides[a];
                if idx[a] < n {
                    break;
                }
                idx[a] = 0;
                src -= n * lead_strides[a];
            }
        }
    }

    /// S_n Step-1 contraction (eq. 98): sum the generalised diagonal of the
    /// trailing `m` axes. `out[M] = Σ_j self[M, j, j, …, j]`.
    ///
    /// Cost: `n^{order-m} · n` multiplications-equivalents — the paper's
    /// eq. (115) term for one bottom-row block of size `m`.
    pub fn contract_trailing_diagonal(&self, m: usize) -> TensorOf<S> {
        let keep = self.order.checked_sub(m).expect("m must be <= order");
        let mut data = Vec::with_capacity(self.n.pow(keep as u32));
        self.contract_diagonal_scan(m, |s| data.push(s));
        TensorOf {
            n: self.n,
            order: keep,
            data,
        }
    }

    /// [`Tensor::contract_trailing_diagonal`] into a caller-provided buffer
    /// (write-once: every element of `out` is overwritten).
    pub fn contract_trailing_diagonal_into(&self, m: usize, out: &mut TensorOf<S>) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, self.order - m);
        let mut slots = out.data.iter_mut();
        self.contract_diagonal_scan(m, |s| {
            *slots.next().expect("output sized n^(order-m)") = s;
        });
    }

    fn contract_diagonal_scan(&self, m: usize, mut emit: impl FnMut(S)) {
        assert!(m >= 1 && m <= self.order);
        let n = self.n;
        let keep = self.order - m;
        let block = n.pow(m as u32);
        // Diagonal stride within the trailing block: 1 + n + … + n^{m-1}.
        let dstride: usize = (0..m).map(|a| n.pow(a as u32)).sum();
        for o in 0..n.pow(keep as u32) {
            let mut s = S::ZERO;
            let mut off = o * block;
            for _ in 0..n {
                s += self.data[off];
                off += dstride;
            }
            emit(s);
        }
    }

    /// O(n)/SO(n) Step-1 pair contraction (eq. 122): trace over the two
    /// trailing axes. `out[M] = Σ_j self[M, j, j]`.
    pub fn trace_trailing_pair(&self) -> TensorOf<S> {
        self.contract_trailing_diagonal(2)
    }

    /// [`Tensor::trace_trailing_pair`] into a caller-provided buffer.
    pub fn trace_trailing_pair_into(&self, out: &mut TensorOf<S>) {
        self.contract_trailing_diagonal_into(2, out)
    }

    /// Sp(n) Step-1 pair contraction (eq. 138): ε-weighted trace over the
    /// two trailing axes, `out[M] = Σ_{j1 j2} ε_{j1 j2} self[M, j1, j2]`,
    /// with the symplectic form in the interleaved basis
    /// `1, 1', 2, 2', …, m, m'`: `ε_{2i, 2i+1} = +1`, `ε_{2i+1, 2i} = -1`.
    pub fn trace_trailing_pair_eps(&self) -> TensorOf<S> {
        let keep = self.order.checked_sub(2).expect("order must be >= 2");
        let mut data = Vec::with_capacity(self.n.pow(keep as u32));
        self.trace_eps_scan(|s| data.push(s));
        TensorOf {
            n: self.n,
            order: keep,
            data,
        }
    }

    /// [`Tensor::trace_trailing_pair_eps`] into a caller-provided buffer
    /// (write-once: every element of `out` is overwritten).
    pub fn trace_trailing_pair_eps_into(&self, out: &mut TensorOf<S>) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, self.order - 2);
        let mut slots = out.data.iter_mut();
        self.trace_eps_scan(|s| {
            *slots.next().expect("output sized n^(order-2)") = s;
        });
    }

    fn trace_eps_scan(&self, mut emit: impl FnMut(S)) {
        assert!(self.order >= 2);
        let n = self.n;
        assert_eq!(n % 2, 0, "Sp(n) requires even n");
        let keep = self.order - 2;
        let block = n * n;
        for o in 0..n.pow(keep as u32) {
            let base = o * block;
            let mut s = S::ZERO;
            for i in 0..n / 2 {
                let a = 2 * i;
                let b = 2 * i + 1;
                s += self.data[base + a * n + b] - self.data[base + b * n + a];
            }
            emit(s);
        }
    }

    /// SO(n) free-vertex Step-1 (eq. 157): contract the trailing `n - s`
    /// axes against the Levi-Civita symbol, producing `s` new trailing axes:
    ///
    /// `out[M, t_1…t_s] = Σ_{b_1…b_{n-s}} ε_{t_1…t_s b_1…b_{n-s}}
    ///                     self[M, b_1…b_{n-s}]`
    ///
    /// Implemented by iterating the `n!` permutations of `[n]` with their
    /// signs — exactly the `n!/(n-s)!` valid `T`-tuples × `(n-s)!` terms the
    /// paper counts in eq. (168).
    pub fn levi_civita_contract_trailing(&self, s: usize) -> TensorOf<S> {
        let n = self.n;
        assert!(s <= n);
        let nb = n - s;
        assert!(nb <= self.order);
        let mut out = TensorOf::zeros(n, self.order - nb + s);
        self.levi_civita_accumulate(s, &mut out);
        out
    }

    /// [`Tensor::levi_civita_contract_trailing`] into a caller-provided
    /// buffer. Unlike the write-once primitives this op scatters (`+=`)
    /// into its output, so the buffer is zeroed first.
    pub fn levi_civita_contract_trailing_into(&self, s: usize, out: &mut TensorOf<S>) {
        let n = self.n;
        assert!(s <= n);
        let nb = n - s;
        assert!(nb <= self.order);
        assert_eq!(out.n, n);
        assert_eq!(out.order, self.order - nb + s);
        out.data.fill(S::ZERO);
        self.levi_civita_accumulate(s, out);
    }

    fn levi_civita_accumulate(&self, s: usize, out: &mut TensorOf<S>) {
        let n = self.n;
        let nb = n - s; // bottom free axes consumed
        let keep = self.order - nb;
        let in_block = n.pow(nb as u32);
        let out_block = n.pow(s as u32);
        let perms = signed_permutations(n);
        for o in 0..n.pow(keep as u32) {
            let in_base = o * in_block;
            let out_base = o * out_block;
            for (perm, sign) in &perms {
                // T = perm[0..s] indexes the new trailing axes,
                // B = perm[s..n] indexes the consumed input axes.
                let t_off = flat_index(n, &perm[..s]);
                let b_off = flat_index(n, &perm[s..]);
                out.data[out_base + t_off] += S::from_f64(*sign) * self.data[in_base + b_off];
            }
        }
    }

    /// S_n Step-2 transfer, compact form (eq. 101): given trailing axis
    /// groups of sizes `groups[0], …, groups[d-1]` (summing to `order`),
    /// read the per-group diagonals: `out[j_1…j_d] = self[j_1 rep g_1, …]`.
    /// Write-once: the output is filled in destination order, no zero-fill.
    pub fn extract_group_diagonals(&self, groups: &[usize]) -> TensorOf<S> {
        let mut data = Vec::with_capacity(self.n.pow(groups.len() as u32));
        self.extract_diagonals_scan(groups, |x| data.push(x));
        TensorOf {
            n: self.n,
            order: groups.len(),
            data,
        }
    }

    /// [`Tensor::extract_group_diagonals`] into a caller-provided buffer
    /// (write-once: every element of `out` is overwritten).
    pub fn extract_group_diagonals_into(&self, groups: &[usize], out: &mut TensorOf<S>) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, groups.len());
        let mut slots = out.data.iter_mut();
        self.extract_diagonals_scan(groups, |x| {
            *slots.next().expect("output sized n^groups") = x;
        });
    }

    fn extract_diagonals_scan(&self, groups: &[usize], mut emit: impl FnMut(S)) {
        let total: usize = groups.iter().sum();
        assert_eq!(total, self.order, "groups must cover all axes");
        let n = self.n;
        let d = groups.len();
        // Stride of group g's repeated index in the input flat offset.
        let mut gstride = vec![0usize; d];
        {
            let mut axis_stride = vec![0usize; self.order];
            let mut s = 1usize;
            for a in (0..self.order).rev() {
                axis_stride[a] = s;
                s *= n;
            }
            let mut a = 0usize;
            for (g, &size) in groups.iter().enumerate() {
                for _ in 0..size {
                    gstride[g] += axis_stride[a];
                    a += 1;
                }
            }
        }
        let mut idx = vec![0usize; d];
        let mut src = 0usize;
        for _ in 0..n.pow(d as u32) {
            emit(self.data[src]);
            let mut g = d;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                idx[g] += 1;
                src += gstride[g];
                if idx[g] < n {
                    break;
                }
                idx[g] = 0;
                src -= n * gstride[g];
            }
        }
    }

    // -----------------------------------------------------------------
    // Strided-fusion gather kernels
    //
    // A `Permute` feeding a diagonal contraction, pair trace or group-
    // diagonal extraction is pure index relabelling: the downstream op can
    // read the *unpermuted* source through remapped per-axis strides and
    // never touch the materialised permuted intermediate. Each kernel below
    // visits its output in exactly the order of the permute-then-op
    // composition and performs the identical floating-point reduction, so
    // the results are **bitwise** equal to the two-step path — which is what
    // lets `fastmult::schedule` fuse freely without perturbing the
    // schedule-vs-per-term bitwise guarantees.
    // -----------------------------------------------------------------

    /// Fused `permute_axes(self, axes).contract_trailing_diagonal(m)`
    /// without materialising the permuted tensor: the generalised diagonal
    /// of the permuted trailing `m`-block is the set of source axes
    /// `axes[order-m..]`, so its stride in `self` is the sum of those axes'
    /// strides and the outer walk reads `self` through the remaining
    /// remapped strides. Bitwise identical to the composition.
    pub fn contract_permuted_diagonal_into(&self, axes: &[usize], m: usize, out: &mut TensorOf<S>) {
        self.check_axes(axes);
        assert!(m >= 1 && m <= self.order);
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, self.order - m);
        let strides = axis_strides(self.n, self.order);
        let dstride: usize = axes[self.order - m..].iter().map(|&a| strides[a]).sum();
        let base = permuted_gather_base(self.n, self.order, axes, m);
        self.gather_contract_with(&base, dstride, out);
    }

    /// Replay of [`Tensor::contract_permuted_diagonal_into`] off a
    /// precomputed outer-offset table (`fastmult::schedule` builds it once
    /// per kernel plan): `out[o] = Σ_j self[base[o] + j·dstride]`.
    pub(crate) fn gather_contract_with(&self, base: &[usize], dstride: usize, out: &mut TensorOf<S>) {
        let n = self.n;
        debug_assert_eq!(base.len(), out.data.len());
        for (slot, &b) in out.data.iter_mut().zip(base) {
            let mut s = S::ZERO;
            let mut off = b;
            for _ in 0..n {
                s += self.data[off];
                off += dstride;
            }
            *slot = s;
        }
    }

    /// Fused `permute_axes(self, axes).trace_trailing_pair_eps()`: the two
    /// ε-traced axes are the source axes `axes[order-2..]`, read through
    /// their own strides. Bitwise identical to the composition.
    pub fn trace_permuted_pair_eps_into(&self, axes: &[usize], out: &mut TensorOf<S>) {
        self.check_axes(axes);
        assert!(self.order >= 2);
        assert_eq!(self.n % 2, 0, "Sp(n) requires even n");
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, self.order - 2);
        let strides = axis_strides(self.n, self.order);
        let sa = strides[axes[self.order - 2]];
        let sb = strides[axes[self.order - 1]];
        let base = permuted_gather_base(self.n, self.order, axes, 2);
        self.gather_eps_trace_with(&base, sa, sb, out);
    }

    /// Replay of [`Tensor::trace_permuted_pair_eps_into`] off a precomputed
    /// outer-offset table plus the two traced axes' strides.
    pub(crate) fn gather_eps_trace_with(
        &self,
        base: &[usize],
        sa: usize,
        sb: usize,
        out: &mut TensorOf<S>,
    ) {
        let n = self.n;
        debug_assert_eq!(base.len(), out.data.len());
        for (slot, &b) in out.data.iter_mut().zip(base) {
            let mut s = S::ZERO;
            for i in 0..n / 2 {
                let p = 2 * i;
                let q = 2 * i + 1;
                s += self.data[b + p * sa + q * sb] - self.data[b + q * sa + p * sb];
            }
            *slot = s;
        }
    }

    /// Fused `permute_axes(self, axes).extract_group_diagonals(groups)`:
    /// group `g`'s repeated index steps `self` by the summed strides of the
    /// source axes feeding that group — a pure gather, bitwise identical to
    /// the composition.
    pub fn extract_permuted_group_diagonals_into(
        &self,
        axes: &[usize],
        groups: &[usize],
        out: &mut TensorOf<S>,
    ) {
        self.check_axes(axes);
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, groups.len());
        let offs = permuted_group_diag_offsets(self.n, self.order, axes, groups);
        self.gather_with(&offs, out);
    }

    /// Pure gather replay: `out[i] = self[offs[i]]` (group-diagonal
    /// extraction, permuted or not, off a precomputed offset table).
    pub(crate) fn gather_with(&self, offs: &[usize], out: &mut TensorOf<S>) {
        debug_assert_eq!(offs.len(), out.data.len());
        for (slot, &s) in out.data.iter_mut().zip(offs) {
            *slot = self.data[s];
        }
    }

    /// Blocked-permute replay off a precomputed block map (see
    /// [`permute_block_map`]): destination is filled sequentially with the
    /// maximal contiguous source blocks. Bitwise identical to
    /// [`Tensor::permute_axes_into`].
    pub(crate) fn permute_blocks_into(&self, map: &[usize], block: usize, out: &mut TensorOf<S>) {
        debug_assert_eq!(map.len() * block, out.data.len());
        let mut d = 0usize;
        for &s in map {
            out.data[d..d + block].copy_from_slice(&self.data[s..s + block]);
            d += block;
        }
    }

    /// [`Tensor::levi_civita_contract_trailing_into`] replayed off a
    /// precomputed signed-permutation offset table (see
    /// [`levi_civita_entries`]); scatters, so the output is zeroed first.
    pub(crate) fn levi_civita_entries_into(
        &self,
        s: usize,
        entries: &[(usize, usize, f64)],
        out: &mut TensorOf<S>,
    ) {
        let n = self.n;
        let nb = n - s;
        let keep = self.order - nb;
        let in_block = n.pow(nb as u32);
        let out_block = n.pow(s as u32);
        debug_assert_eq!(out.order, keep + s);
        out.data.fill(S::ZERO);
        for o in 0..n.pow(keep as u32) {
            let in_base = o * in_block;
            let out_base = o * out_block;
            for &(t_off, b_off, sign) in entries {
                out.data[out_base + t_off] += S::from_f64(sign) * self.data[in_base + b_off];
            }
        }
    }

    /// Single-pattern sink replay off a precomputed destination map:
    /// `out[dsts[c·len + s]] += alpha · self[s]` over every chunk of
    /// `self.len()` destinations — one chunk for a permuted axpy, one chunk
    /// per broadcast rep for the diagonal-support scatter. Each destination
    /// receives exactly one contribution, so the result is bitwise equal to
    /// the odometer kernels.
    pub(crate) fn axpy_dsts_into(&self, dsts: &[usize], alpha: f64, out: &mut TensorOf<S>) {
        debug_assert_eq!(dsts.len() % self.data.len(), 0);
        let a = S::from_f64(alpha);
        let len = self.data.len();
        for rep in dsts.chunks(len) {
            // Identity-layout destination runs take the lane-chunked axpy
            // (bitwise equal to the scalar scatter — each destination still
            // receives its one contribution in the same order).
            if let Some(d0) = ramp_base(rep) {
                axpy_slice(a, &self.data, &mut out.data[d0..d0 + len]);
            } else {
                for (&d, &x) in rep.iter().zip(&self.data) {
                    out.data[d] += a * x;
                }
            }
        }
    }

    /// Inverse of [`Tensor::extract_group_diagonals`]: embed a compact
    /// order-`d` tensor onto the per-group diagonals of an order-`total`
    /// tensor (zero elsewhere). This is the S_n Step-2/3 expand used when a
    /// caller needs the *materialised* output (eq. 100/104).
    pub fn embed_group_diagonals(&self, groups: &[usize]) -> TensorOf<S> {
        assert_eq!(groups.len(), self.order, "one group per compact axis");
        let n = self.n;
        let total: usize = groups.iter().sum();
        let mut out = TensorOf::zeros(n, total);
        let d = self.order;
        let mut gstride = vec![0usize; d];
        {
            let mut axis_stride = vec![0usize; total];
            let mut s = 1usize;
            for a in (0..total).rev() {
                axis_stride[a] = s;
                s *= n;
            }
            let mut a = 0usize;
            for (g, &size) in groups.iter().enumerate() {
                for _ in 0..size {
                    gstride[g] += axis_stride[a];
                    a += 1;
                }
            }
        }
        let mut idx = vec![0usize; d];
        let mut dst = 0usize;
        for src in 0..self.data.len() {
            out.data[dst] = self.data[src];
            let mut g = d;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                idx[g] += 1;
                dst += gstride[g];
                if idx[g] < n {
                    break;
                }
                idx[g] = 0;
                dst -= n * gstride[g];
            }
        }
        out
    }

    /// `out += alpha · permute_axes(self, axes)` without materialising the
    /// permuted tensor — the fused final step of a spanning-term apply
    /// (Algorithm 1's closing `Permute` + the layer's λ-weighted sum).
    pub fn axpy_permuted_into(&self, alpha: f64, axes: &[usize], out: &mut TensorOf<S>) {
        assert_eq!(axes.len(), self.order);
        assert_eq!(out.order, self.order);
        assert_eq!(out.n, self.n);
        let n = self.n;
        let order = self.order;
        let alpha = S::from_f64(alpha);
        if order == 0 {
            out.data[0] += alpha * self.data[0];
            return;
        }
        // Identity fast path (lane-chunked).
        if axes.iter().enumerate().all(|(i, &a)| i == a) {
            axpy_slice(alpha, &self.data, &mut out.data);
            return;
        }
        let mut in_stride = vec![0usize; order];
        {
            let mut strides = vec![0usize; order];
            let mut s = 1usize;
            for a in (0..order).rev() {
                strides[a] = s;
                s *= n;
            }
            for a in 0..order {
                in_stride[a] = strides[axes[a]];
            }
        }
        let mut idx = vec![0usize; order];
        let mut src = 0usize;
        for dst in 0..out.data.len() {
            out.data[dst] += alpha * self.data[src];
            let mut a = order;
            loop {
                if a == 0 {
                    break;
                }
                a -= 1;
                idx[a] += 1;
                src += in_stride[a];
                if idx[a] < n {
                    break;
                }
                idx[a] = 0;
                src -= n * in_stride[a];
            }
        }
    }

    /// Multi-pattern [`Tensor::axpy_permuted_into`]: one pass over the
    /// source applying every `(axes, alpha)` pattern at once —
    /// `out += Σ_p alpha_p · permute_axes(self, axes_p)`. The folded-class
    /// closing kernel for pure-permutation spanning terms: the source is
    /// read once and the odometer digits are shared across the patterns
    /// (each pattern only carries its own per-axis destination strides), so
    /// a class of `P` patterns costs one scatter pass, not `P`.
    ///
    /// Per destination element the contributions arrive in source order
    /// (not pattern-major), so a multi-pattern pass may round differently
    /// from `P` sequential single-pattern passes — equal to ≤ 1e-12, not
    /// bitwise. A class with exactly **one** pattern delegates to
    /// [`Tensor::axpy_permuted_into`] (each destination receives a single
    /// contribution either way, so the delegation is bitwise exact): P=1
    /// classes keep the plain kernel's accumulation and skip the
    /// per-pattern stride indirection entirely.
    ///
    /// The schedule's folded walk replays precompiled destination maps in
    /// this exact visit order (`fastmult::schedule`); this standalone form
    /// is the reference its equivalence tests assert against.
    pub fn axpy_permuted_multi_into(&self, pats: &[(&[usize], f64)], out: &mut TensorOf<S>) {
        assert_eq!(out.order, self.order);
        assert_eq!(out.n, self.n);
        if pats.is_empty() {
            return;
        }
        if let [(axes, alpha)] = pats {
            return self.axpy_permuted_into(*alpha, axes, out);
        }
        let n = self.n;
        let order = self.order;
        // Per-pattern weights, narrowed once per invocation.
        let ws: Vec<S> = pats.iter().map(|&(_, alpha)| S::from_f64(alpha)).collect();
        if order == 0 {
            for &w in &ws {
                out.data[0] += w * self.data[0];
            }
            return;
        }
        // Per pattern: destination stride of each *source* axis. Walking the
        // source row-major, incrementing source digit `a` moves pattern p's
        // destination by `pstride[p][a]`.
        let mut out_stride = vec![0usize; order];
        {
            let mut s = 1usize;
            for q in (0..order).rev() {
                out_stride[q] = s;
                s *= n;
            }
        }
        let pstrides: Vec<Vec<usize>> = pats
            .iter()
            .map(|(axes, _)| {
                assert_eq!(axes.len(), order);
                let mut ps = vec![0usize; order];
                for (q, &a) in axes.iter().enumerate() {
                    ps[a] = out_stride[q];
                }
                ps
            })
            .collect();
        let mut idx = vec![0usize; order];
        let mut dsts = vec![0usize; pats.len()];
        for src in 0..self.data.len() {
            let x = self.data[src];
            for (p, &w) in ws.iter().enumerate() {
                out.data[dsts[p]] += w * x;
            }
            let mut a = order;
            loop {
                if a == 0 {
                    break;
                }
                a -= 1;
                idx[a] += 1;
                for (d, ps) in dsts.iter_mut().zip(&pstrides) {
                    *d += ps[a];
                }
                if idx[a] < n {
                    break;
                }
                idx[a] = 0;
                for (d, ps) in dsts.iter_mut().zip(&pstrides) {
                    *d -= n * ps[a];
                }
            }
        }
    }

    /// Fused S_n/O(n)/SO(n) Step-3: broadcast `lead_groups.len()` free
    /// leading block indices AND embed the compact tensor on the per-group
    /// diagonals, in one allocation and one scatter:
    ///
    /// `out[diag(i_1,g_1), …, diag(i_t,g_t), diag(j_1,h_1), …] = self[j_1…]`
    ///
    /// where `lead_groups = [g_1…g_t]` are the broadcast block sizes (the
    /// `i` indices are free) and `tail_groups = [h_1…h_d]` are the diagonal
    /// embeddings of `self`'s axes. Replaces
    /// `self.broadcast_leading(t).embed_group_diagonals(groups)` without
    /// the `n^t·|self|` intermediate.
    pub fn scatter_broadcast_diagonals(
        &self,
        lead_groups: &[usize],
        tail_groups: &[usize],
    ) -> TensorOf<S> {
        assert_eq!(tail_groups.len(), self.order);
        let n = self.n;
        let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
        let mut out = TensorOf::zeros(n, total);
        let t = lead_groups.len();
        let d = tail_groups.len();
        // Per-compact-axis strides in the output (diagonal strides).
        let mut gstride = vec![0usize; t + d];
        {
            let mut axis_stride = vec![0usize; total];
            let mut s = 1usize;
            for a in (0..total).rev() {
                axis_stride[a] = s;
                s *= n;
            }
            let mut a = 0usize;
            for (g, &size) in lead_groups.iter().chain(tail_groups.iter()).enumerate() {
                for _ in 0..size {
                    gstride[g] += axis_stride[a];
                    a += 1;
                }
            }
        }
        // Odometer over (lead indices, compact indices): the source offset
        // advances only with the tail digits.
        let reps = n.pow(t as u32);
        let tail_len = self.data.len();
        let mut lead_idx = vec![0usize; t];
        let mut lead_off = 0usize;
        for _ in 0..reps {
            // inner: walk the compact tensor
            let mut tail_idx = vec![0usize; d];
            let mut dst = lead_off;
            for src in 0..tail_len {
                out.data[dst] = self.data[src];
                let mut g = d;
                loop {
                    if g == 0 {
                        break;
                    }
                    g -= 1;
                    tail_idx[g] += 1;
                    dst += gstride[t + g];
                    if tail_idx[g] < n {
                        break;
                    }
                    tail_idx[g] = 0;
                    dst -= n * gstride[t + g];
                }
            }
            // advance lead odometer
            let mut g = t;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                lead_idx[g] += 1;
                lead_off += gstride[g];
                if lead_idx[g] < n {
                    break;
                }
                lead_idx[g] = 0;
                lead_off -= n * gstride[g];
            }
        }
        out
    }

    /// Deep-fused spanning-term tail: equivalent to
    /// `out += alpha · permute_axes(self.scatter_broadcast_diagonals(lead,
    /// tail), axes)` but touching only the `n^{t+d}` diagonal-support
    /// entries of `out` — skipping the `O(n^l)` zero-fill, write-back and
    /// re-read of the materialised Step-3 output entirely. The layer
    /// hot path (`MultPlan::apply_accumulate`) lives on this.
    pub fn scatter_broadcast_diagonals_axpy(
        &self,
        lead_groups: &[usize],
        tail_groups: &[usize],
        axes: &[usize],
        alpha: f64,
        out: &mut TensorOf<S>,
    ) {
        assert_eq!(tail_groups.len(), self.order);
        let alpha = S::from_f64(alpha);
        let n = self.n;
        let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
        assert_eq!(axes.len(), total);
        assert_eq!(out.order, total);
        assert_eq!(out.n, n);
        let t = lead_groups.len();
        let d = tail_groups.len();
        // Planar axis a feeds output axis p where axes[p] == a; its stride
        // in `out` is the output stride of axis p.
        let mut planar_out_stride = vec![0usize; total];
        {
            let mut out_stride = vec![0usize; total];
            let mut s = 1usize;
            for p in (0..total).rev() {
                out_stride[p] = s;
                s *= n;
            }
            for (p, &a) in axes.iter().enumerate() {
                planar_out_stride[a] = out_stride[p];
            }
        }
        // Per-compact-axis strides: sum the (permuted) strides of the
        // planar axes in each group.
        let mut gstride = vec![0usize; t + d];
        {
            let mut a = 0usize;
            for (g, &size) in lead_groups.iter().chain(tail_groups.iter()).enumerate() {
                for _ in 0..size {
                    gstride[g] += planar_out_stride[a];
                    a += 1;
                }
            }
        }
        let reps = n.pow(t as u32);
        let tail_len = self.data.len();
        let mut lead_idx = vec![0usize; t];
        let mut lead_off = 0usize;
        for _ in 0..reps {
            let mut tail_idx = vec![0usize; d];
            let mut dst = lead_off;
            for src in 0..tail_len {
                out.data[dst] += alpha * self.data[src];
                let mut g = d;
                loop {
                    if g == 0 {
                        break;
                    }
                    g -= 1;
                    tail_idx[g] += 1;
                    dst += gstride[t + g];
                    if tail_idx[g] < n {
                        break;
                    }
                    tail_idx[g] = 0;
                    dst -= n * gstride[t + g];
                }
            }
            let mut g = t;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                lead_idx[g] += 1;
                lead_off += gstride[g];
                if lead_idx[g] < n {
                    break;
                }
                lead_idx[g] = 0;
                lead_off -= n * gstride[g];
            }
        }
    }

    /// Multi-pattern [`Tensor::scatter_broadcast_diagonals_axpy`]: apply a
    /// whole *class* of diagonal-support scatter patterns — same
    /// `(lead_groups, tail_groups)` shape, different output permutations
    /// `axes_p` and weights `alpha_p` — in **one** pass over the compact
    /// source. The shared `(lead, tail)` odometer is walked once; each
    /// pattern carries only its own per-group destination strides. This is
    /// the folded-class hot path: `P` spanning terms that differ only in
    /// `σ_l` cost one scatter pass instead of `P`.
    ///
    /// Per destination element the contributions arrive in source order,
    /// so a class pass may round differently from `P` sequential
    /// single-pattern passes (≤ 1e-12, not bitwise). As with the multi
    /// axpy, the schedule replays precompiled maps in this visit order;
    /// this standalone form is the asserted reference.
    pub fn scatter_broadcast_diagonals_multi_axpy(
        &self,
        lead_groups: &[usize],
        tail_groups: &[usize],
        pats: &[(&[usize], f64)],
        out: &mut TensorOf<S>,
    ) {
        assert_eq!(tail_groups.len(), self.order);
        if pats.is_empty() {
            return;
        }
        let ws: Vec<S> = pats.iter().map(|&(_, alpha)| S::from_f64(alpha)).collect();
        let n = self.n;
        let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
        assert_eq!(out.order, total);
        assert_eq!(out.n, n);
        let t = lead_groups.len();
        let d = tail_groups.len();
        let mut out_stride = vec![0usize; total];
        {
            let mut s = 1usize;
            for p in (0..total).rev() {
                out_stride[p] = s;
                s *= n;
            }
        }
        // Per pattern: per-compact-axis destination strides (sum of the
        // permuted output strides of the planar axes in each group).
        let gstrides: Vec<Vec<usize>> = pats
            .iter()
            .map(|(axes, _)| {
                assert_eq!(axes.len(), total);
                let mut planar = vec![0usize; total];
                for (p, &a) in axes.iter().enumerate() {
                    planar[a] = out_stride[p];
                }
                let mut gs = vec![0usize; t + d];
                let mut a = 0usize;
                for (g, &size) in lead_groups.iter().chain(tail_groups.iter()).enumerate() {
                    for _ in 0..size {
                        gs[g] += planar[a];
                        a += 1;
                    }
                }
                gs
            })
            .collect();
        let reps = n.pow(t as u32);
        let tail_len = self.data.len();
        let np = pats.len();
        let mut lead_idx = vec![0usize; t];
        let mut lead_offs = vec![0usize; np];
        let mut tail_idx = vec![0usize; d];
        let mut dsts = vec![0usize; np];
        for _ in 0..reps {
            tail_idx.fill(0);
            dsts.copy_from_slice(&lead_offs);
            for src in 0..tail_len {
                let x = self.data[src];
                for (p, &w) in ws.iter().enumerate() {
                    out.data[dsts[p]] += w * x;
                }
                let mut g = d;
                loop {
                    if g == 0 {
                        break;
                    }
                    g -= 1;
                    tail_idx[g] += 1;
                    for (dst, gs) in dsts.iter_mut().zip(&gstrides) {
                        *dst += gs[t + g];
                    }
                    if tail_idx[g] < n {
                        break;
                    }
                    tail_idx[g] = 0;
                    for (dst, gs) in dsts.iter_mut().zip(&gstrides) {
                        *dst -= n * gs[t + g];
                    }
                }
            }
            let mut g = t;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                lead_idx[g] += 1;
                for (off, gs) in lead_offs.iter_mut().zip(&gstrides) {
                    *off += gs[g];
                }
                if lead_idx[g] < n {
                    break;
                }
                lead_idx[g] = 0;
                for (off, gs) in lead_offs.iter_mut().zip(&gstrides) {
                    *off -= n * gs[g];
                }
            }
        }
    }

    /// Prepend `m` broadcast axes: `out[i_1…i_m, J] = self[J]` for every
    /// choice of the leading indices — the "copy" half of S_n Step 3
    /// (eq. 103) before the diagonal embedding.
    pub fn broadcast_leading(&self, m: usize) -> TensorOf<S> {
        let n = self.n;
        let reps = n.pow(m as u32);
        let mut data = Vec::with_capacity(reps * self.data.len());
        for _ in 0..reps {
            data.extend_from_slice(&self.data);
        }
        TensorOf {
            n,
            order: self.order + m,
            data,
        }
    }

    /// Mode product: apply an `n×n` matrix `g` along one axis,
    /// `out[…, i, …] = Σ_j g[i,j] self[…, j, …]`. Composed over all axes it
    /// realises the diagonal action `ρ_k(g)` of eq. (2).
    pub fn mode_apply(&self, g: &[f64], axis: usize) -> TensorOf<S> {
        let n = self.n;
        assert_eq!(g.len(), n * n);
        assert!(axis < self.order);
        let mut out = TensorOf::zeros(n, self.order);
        // Split flat index as (outer, axis, inner).
        let inner: usize = n.pow((self.order - 1 - axis) as u32);
        let outer: usize = n.pow(axis as u32);
        for o in 0..outer {
            for i in 0..n {
                let obase = (o * n + i) * inner;
                for j in 0..n {
                    let gij = g[i * n + j];
                    if gij == 0.0 {
                        continue;
                    }
                    let gs = S::from_f64(gij);
                    let ibase = (o * n + j) * inner;
                    axpy_slice(
                        gs,
                        &self.data[ibase..ibase + inner],
                        &mut out.data[obase..obase + inner],
                    );
                }
            }
        }
        out
    }

    /// The full tensor-power action `ρ_k(g)` (eq. 2): `g` applied along
    /// every axis.
    pub fn rho_apply(&self, g: &[f64]) -> TensorOf<S> {
        let mut t = self.clone();
        for a in 0..self.order {
            t = t.mode_apply(g, a);
        }
        t
    }
}

/// All permutations of `0..n` with their signs, generated by Heap's
/// algorithm (each successive permutation differs by one transposition, so
/// the sign alternates).
pub fn signed_permutations(n: usize) -> Vec<(Vec<usize>, f64)> {
    let mut out = Vec::with_capacity((1..=n).product::<usize>());
    let mut a: Vec<usize> = (0..n).collect();
    let mut c = vec![0usize; n];
    let mut sign = 1.0;
    out.push((a.clone(), sign));
    let mut i = 0usize;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                a.swap(0, i);
            } else {
                a.swap(c[i], i);
            }
            sign = -sign;
            out.push((a.clone(), sign));
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shared index maps for the batched kernels
// ---------------------------------------------------------------------------
//
// Each function below runs the same odometer as its per-item scan above,
// exactly once, and returns the visit order as flat offsets. The batched
// kernels in `super::batch` replay the map over every item of a
// [`super::BatchTensor`], so the index arithmetic is paid once per schedule
// node instead of once per batch item. Keeping the odometers here, next to
// the per-item scans they mirror, is what the bitwise-equivalence unit
// tests in `batch.rs` lean on.

/// The blocked-permute visit order: source offsets of each maximal
/// contiguous block, in destination order, plus the block length.
/// An identity permutation (or `order == 0`) is the single whole-tensor
/// block `([0], n^order)`.
pub(crate) fn permute_block_map(n: usize, order: usize, axes: &[usize]) -> (Vec<usize>, usize) {
    assert_eq!(axes.len(), order, "axes arity must match order");
    let mut tail = 0usize;
    while tail < order && axes[order - 1 - tail] == order - 1 - tail {
        tail += 1;
    }
    let lead = order - tail;
    if lead == 0 {
        return (vec![0], n.pow(order as u32));
    }
    let mut strides = vec![0usize; order];
    {
        let mut s = 1usize;
        for a in (0..order).rev() {
            strides[a] = s;
            s *= n;
        }
    }
    let lead_strides: Vec<usize> = axes[..lead].iter().map(|&a| strides[a]).collect();
    let block = n.pow(tail as u32);
    let blocks = n.pow(lead as u32);
    let mut map = Vec::with_capacity(blocks);
    let mut idx = vec![0usize; lead];
    let mut src = 0usize;
    for _ in 0..blocks {
        map.push(src);
        let mut a = lead;
        loop {
            if a == 0 {
                break;
            }
            a -= 1;
            idx[a] += 1;
            src += lead_strides[a];
            if idx[a] < n {
                break;
            }
            idx[a] = 0;
            src -= n * lead_strides[a];
        }
    }
    (map, block)
}

/// The group-diagonal gather order: source offsets visited by
/// `extract_diagonals_scan`, in destination order (`n^groups.len()`
/// entries). The identity-permutation case of
/// [`permuted_group_diag_offsets`].
pub(crate) fn group_diag_offsets(n: usize, order: usize, groups: &[usize]) -> Vec<usize> {
    let ident: Vec<usize> = (0..order).collect();
    permuted_group_diag_offsets(n, order, &ident, groups)
}

/// Row-major axis strides of an order-`order` tensor over `R^n`
/// (`strides[a] = n^(order-1-a)`).
pub(crate) fn axis_strides(n: usize, order: usize) -> Vec<usize> {
    let mut strides = vec![0usize; order];
    let mut s = 1usize;
    for a in (0..order).rev() {
        strides[a] = s;
        s *= n;
    }
    strides
}

/// Outer-offset table of a fused permute-gather: entry `o` is the flat
/// offset in the *unpermuted* source of the permuted element at row-major
/// outer index `o` (the leading `order - m` permuted axes) with the
/// trailing `m` permuted axes at 0 — walking permuted axis `q` steps the
/// source by `strides[axes[q]]`. `n^(order-m)` entries, in the exact visit
/// order of the trailing-axis scans.
pub(crate) fn permuted_gather_base(
    n: usize,
    order: usize,
    axes: &[usize],
    m: usize,
) -> Vec<usize> {
    assert_eq!(axes.len(), order);
    assert!(m <= order);
    let strides = axis_strides(n, order);
    let keep = order - m;
    let lead_strides: Vec<usize> = axes[..keep].iter().map(|&a| strides[a]).collect();
    let count = n.pow(keep as u32);
    let mut base = Vec::with_capacity(count);
    let mut idx = vec![0usize; keep];
    let mut off = 0usize;
    for _ in 0..count {
        base.push(off);
        let mut a = keep;
        loop {
            if a == 0 {
                break;
            }
            a -= 1;
            idx[a] += 1;
            off += lead_strides[a];
            if idx[a] < n {
                break;
            }
            idx[a] = 0;
            off -= n * lead_strides[a];
        }
    }
    base
}

/// The permuted-extract gather order: source offsets of
/// `permute_axes(x, axes).extract_group_diagonals(groups)` in destination
/// order — group `g`'s repeated index steps the source by the summed
/// strides of the source axes `axes[q]` feeding that group.
pub(crate) fn permuted_group_diag_offsets(
    n: usize,
    order: usize,
    axes: &[usize],
    groups: &[usize],
) -> Vec<usize> {
    let total: usize = groups.iter().sum();
    assert_eq!(total, order, "groups must cover all axes");
    assert_eq!(axes.len(), order);
    let strides = axis_strides(n, order);
    let d = groups.len();
    let mut gstride = vec![0usize; d];
    {
        let mut q = 0usize;
        for (g, &size) in groups.iter().enumerate() {
            for _ in 0..size {
                gstride[g] += strides[axes[q]];
                q += 1;
            }
        }
    }
    let count = n.pow(d as u32);
    let mut offs = Vec::with_capacity(count);
    let mut idx = vec![0usize; d];
    let mut src = 0usize;
    for _ in 0..count {
        offs.push(src);
        let mut g = d;
        loop {
            if g == 0 {
                break;
            }
            g -= 1;
            idx[g] += 1;
            src += gstride[g];
            if idx[g] < n {
                break;
            }
            idx[g] = 0;
            src -= n * gstride[g];
        }
    }
    offs
}

/// The Levi-Civita contraction's signed-permutation offsets, in
/// [`signed_permutations`] order: `(top offset, bottom offset, sign)` per
/// permutation of `0..n` split at `s`. Built once per kernel plan instead
/// of once per call (`n!` tuples).
pub(crate) fn levi_civita_entries(n: usize, s: usize) -> Vec<(usize, usize, f64)> {
    signed_permutations(n)
        .iter()
        .map(|(perm, sign)| (flat_index(n, &perm[..s]), flat_index(n, &perm[s..]), *sign))
        .collect()
}

/// The destination offsets of a permuted axpy in **source** order:
/// `map[s]` is where source element `s` lands in the output under
/// `axes` (numpy-transpose semantics, as in [`Tensor::permute_axes`]).
/// The batched multi-pattern axpy replays this map over every item of a
/// batch, one map per pattern.
pub(crate) fn permute_dst_map(n: usize, order: usize, axes: &[usize]) -> Vec<usize> {
    assert_eq!(axes.len(), order);
    let len = n.pow(order as u32);
    if order == 0 {
        return vec![0];
    }
    let mut out_stride = vec![0usize; order];
    {
        let mut s = 1usize;
        for q in (0..order).rev() {
            out_stride[q] = s;
            s *= n;
        }
    }
    let mut pstride = vec![0usize; order];
    for (q, &a) in axes.iter().enumerate() {
        pstride[a] = out_stride[q];
    }
    let mut map = Vec::with_capacity(len);
    let mut idx = vec![0usize; order];
    let mut dst = 0usize;
    for _ in 0..len {
        map.push(dst);
        let mut a = order;
        loop {
            if a == 0 {
                break;
            }
            a -= 1;
            idx[a] += 1;
            dst += pstride[a];
            if idx[a] < n {
                break;
            }
            idx[a] = 0;
            dst -= n * pstride[a];
        }
    }
    map
}

/// The diagonal-support scatter order of
/// [`Tensor::scatter_broadcast_diagonals_axpy`]: destination offsets in
/// visit order, rep-major — entry `r · n^d + s` is where compact source
/// element `s` lands under lead index `r`.
pub(crate) fn scatter_diag_dsts(
    n: usize,
    lead_groups: &[usize],
    tail_groups: &[usize],
    axes: &[usize],
) -> Vec<usize> {
    let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
    assert_eq!(axes.len(), total);
    let t = lead_groups.len();
    let d = tail_groups.len();
    let mut planar_out_stride = vec![0usize; total];
    {
        let mut out_stride = vec![0usize; total];
        let mut s = 1usize;
        for p in (0..total).rev() {
            out_stride[p] = s;
            s *= n;
        }
        for (p, &a) in axes.iter().enumerate() {
            planar_out_stride[a] = out_stride[p];
        }
    }
    let mut gstride = vec![0usize; t + d];
    {
        let mut a = 0usize;
        for (g, &size) in lead_groups.iter().chain(tail_groups.iter()).enumerate() {
            for _ in 0..size {
                gstride[g] += planar_out_stride[a];
                a += 1;
            }
        }
    }
    let reps = n.pow(t as u32);
    let tail_len = n.pow(d as u32);
    let mut dsts = Vec::with_capacity(reps * tail_len);
    let mut lead_idx = vec![0usize; t];
    let mut lead_off = 0usize;
    for _ in 0..reps {
        let mut tail_idx = vec![0usize; d];
        let mut dst = lead_off;
        for _ in 0..tail_len {
            dsts.push(dst);
            let mut g = d;
            loop {
                if g == 0 {
                    break;
                }
                g -= 1;
                tail_idx[g] += 1;
                dst += gstride[t + g];
                if tail_idx[g] < n {
                    break;
                }
                tail_idx[g] = 0;
                dst -= n * gstride[t + g];
            }
        }
        let mut g = t;
        loop {
            if g == 0 {
                break;
            }
            g -= 1;
            lead_idx[g] += 1;
            lead_off += gstride[g];
            if lead_idx[g] < n {
                break;
            }
            lead_idx[g] = 0;
            lead_off -= n * gstride[g];
        }
    }
    dsts
}

// ---------------------------------------------------------------------
// Tile-windowed kernel variants
//
// The tiled schedule walk (`fastmult::schedule`) streams one output slab
// `[lo, hi)` of a chain at a time through tile-sized scratch buffers.
// Each windowed kernel below is its full-tensor counterpart restricted
// to one such slab: the loop body, accumulation order and stride
// arithmetic are copied verbatim from the full kernel, only the outer
// iteration range shrinks — so concatenating the slabs reproduces the
// full output **bitwise**. They operate on raw slices because the
// slab buffers are plain `ScratchArena` allocations, not `Tensor`s.
// ---------------------------------------------------------------------

/// Windowed [`Tensor::contract_trailing_diagonal_into`] (covers the pair
/// trace as `m = 2`): `src` is exactly the output slab's input window —
/// `src.len() == out.len() · n^m` — and local offsets match the full
/// kernel's because the contracted block is trailing and contiguous.
pub(crate) fn contract_diag_window<S: Scalar>(src: &[S], n: usize, m: usize, out: &mut [S]) {
    let block = n.pow(m as u32);
    let dstride: usize = (0..m).map(|a| n.pow(a as u32)).sum();
    debug_assert_eq!(src.len(), out.len() * block);
    for (o, slot) in out.iter_mut().enumerate() {
        let mut s = S::ZERO;
        let mut off = o * block;
        for _ in 0..n {
            s += src[off];
            off += dstride;
        }
        *slot = s;
    }
}

/// Windowed [`Tensor::trace_trailing_pair_eps_into`]: `src.len() ==
/// out.len() · n²`, same interleaved ε pairing and summation order.
pub(crate) fn trace_eps_window<S: Scalar>(src: &[S], n: usize, out: &mut [S]) {
    debug_assert_eq!(n % 2, 0, "Sp(n) requires even n");
    let block = n * n;
    debug_assert_eq!(src.len(), out.len() * block);
    for (o, slot) in out.iter_mut().enumerate() {
        let base = o * block;
        let mut s = S::ZERO;
        for i in 0..n / 2 {
            let a = 2 * i;
            let b = 2 * i + 1;
            s += src[base + a * n + b] - src[base + b * n + a];
        }
        *slot = s;
    }
}

/// Windowed blocked-permute replay: fill `out` with the source blocks
/// named by `map` (a contiguous slice of the full block map; offsets
/// are absolute into `src`). One `copy_from_slice` per block, exactly
/// like [`Tensor::permute_blocks_into`].
pub(crate) fn permute_blocks_window<S: Scalar>(
    src: &[S],
    map: &[usize],
    block: usize,
    out: &mut [S],
) {
    debug_assert_eq!(map.len() * block, out.len());
    let mut d = 0usize;
    for &s in map {
        out[d..d + block].copy_from_slice(&src[s..s + block]);
        d += block;
    }
}

/// Windowed pure-gather replay (`offs` is a contiguous slice of the
/// full offset table, absolute into `src`).
pub(crate) fn gather_window<S: Scalar>(src: &[S], offs: &[usize], out: &mut [S]) {
    debug_assert_eq!(offs.len(), out.len());
    for (slot, &s) in out.iter_mut().zip(offs) {
        *slot = src[s];
    }
}

/// Windowed [`Tensor::gather_contract_with`] (`base` is a contiguous
/// slice of the full outer-offset table, absolute into `src`).
pub(crate) fn gather_contract_window<S: Scalar>(
    src: &[S],
    n: usize,
    base: &[usize],
    dstride: usize,
    out: &mut [S],
) {
    debug_assert_eq!(base.len(), out.len());
    for (slot, &b) in out.iter_mut().zip(base) {
        let mut s = S::ZERO;
        let mut off = b;
        for _ in 0..n {
            s += src[off];
            off += dstride;
        }
        *slot = s;
    }
}

/// Windowed [`Tensor::gather_eps_trace_with`] (`base` sliced like
/// [`gather_contract_window`]).
pub(crate) fn gather_eps_trace_window<S: Scalar>(
    src: &[S],
    n: usize,
    base: &[usize],
    sa: usize,
    sb: usize,
    out: &mut [S],
) {
    debug_assert_eq!(base.len(), out.len());
    for (slot, &b) in out.iter_mut().zip(base) {
        let mut s = S::ZERO;
        for i in 0..n / 2 {
            let p = 2 * i;
            let q = 2 * i + 1;
            s += src[b + p * sa + q * sb] - src[b + q * sa + p * sb];
        }
        *slot = s;
    }
}

#[cfg(test)]
mod tests {
    use super::super::index::unflat_index;
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn permute_axes_identity() {
        let t = Tensor::linspace(3, 3);
        let p = t.permute_axes(&[0, 1, 2]);
        assert_eq!(t, p);
    }

    #[test]
    fn permute_axes_matches_pointwise() {
        let t = Tensor::linspace(3, 4);
        let axes = [2, 0, 3, 1];
        let p = t.permute_axes(&axes);
        for f in 0..p.len() {
            let idx = unflat_index(3, 4, f);
            // out axis q carries input axis axes[q]: J[axes[q]] = I[q].
            let mut src = vec![0usize; 4];
            for (q, &a) in axes.iter().enumerate() {
                src[a] = idx[q];
            }
            assert_eq!(p.data[f], t.get(&src), "at {idx:?}");
        }
    }

    #[test]
    fn permute_axes_inverse_roundtrip() {
        let mut rng = Rng::new(31);
        let t = Tensor::random(3, 5, &mut rng);
        let axes = [4, 2, 0, 1, 3];
        let mut inv = [0usize; 5];
        for (i, &a) in axes.iter().enumerate() {
            inv[a] = i;
        }
        let back = t.permute_axes(&axes).permute_axes(&inv);
        assert!(t.allclose(&back, 0.0));
    }

    #[test]
    fn contract_trailing_diagonal_small() {
        // order-2, contract both axes: trace.
        let t = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let c = t.contract_trailing_diagonal(2);
        assert_eq!(c.order, 0);
        assert_eq!(c.data[0], 5.0); // 1 + 4
    }

    #[test]
    fn contract_trailing_diagonal_keeps_leading() {
        let mut t = Tensor::zeros(2, 3);
        // out[m] = t[m,0,0] + t[m,1,1]
        t.set(&[0, 0, 0], 1.0);
        t.set(&[0, 1, 1], 2.0);
        t.set(&[1, 0, 0], 5.0);
        t.set(&[1, 1, 0], 100.0); // off-diagonal, ignored
        let c = t.contract_trailing_diagonal(2);
        assert_eq!(c.data, vec![3.0, 5.0]);
    }

    #[test]
    fn eps_trace_antisymmetry() {
        // For n = 2: out = t[0,1] - t[1,0].
        let t = Tensor::from_vec(2, 2, vec![9.0, 3.0, 7.0, 9.0]).unwrap();
        let c = t.trace_trailing_pair_eps();
        assert_eq!(c.data[0], 3.0 - 7.0);
    }

    #[test]
    fn levi_civita_full_contraction_is_det() {
        // Contracting an order-n tensor v ⊗ … against ε with s = 0 gives
        // Σ_p sign(p) t[p] — for t = a⊗b⊗c this is det[a b c].
        let n = 3;
        let mut rng = Rng::new(17);
        let a: Vec<f64> = rng.gaussian_vec(n);
        let b: Vec<f64> = rng.gaussian_vec(n);
        let c: Vec<f64> = rng.gaussian_vec(n);
        let mut t = Tensor::zeros(n, 3);
        let mut it = t.indices();
        let mut flat = 0usize;
        while let Some(idx) = it.next_index() {
            t.data[flat] = a[idx[0]] * b[idx[1]] * c[idx[2]];
            flat += 1;
        }
        let out = t.levi_civita_contract_trailing(0);
        assert_eq!(out.order, 0);
        let det = a[0] * (b[1] * c[2] - b[2] * c[1]) - a[1] * (b[0] * c[2] - b[2] * c[0])
            + a[2] * (b[0] * c[1] - b[1] * c[0]);
        // ε_{ijk} t_{ijk} = det of the matrix with *rows* a, b, c
        assert!((out.data[0] - det).abs() < 1e-12, "{} vs {det}", out.data[0]);
    }

    #[test]
    fn group_diagonals_roundtrip() {
        let mut rng = Rng::new(23);
        let compact = Tensor::random(3, 2, &mut rng);
        let groups = [2usize, 3usize];
        let big = compact.embed_group_diagonals(&groups);
        assert_eq!(big.order, 5);
        let back = big.extract_group_diagonals(&groups);
        assert!(compact.allclose(&back, 0.0));
        // Off-diagonal entries are zero.
        assert_eq!(big.get(&[0, 1, 0, 0, 0]), 0.0);
    }

    #[test]
    fn mode_apply_identity() {
        let t = Tensor::linspace(3, 3);
        let id: Vec<f64> = {
            let mut m = vec![0.0; 9];
            for i in 0..3 {
                m[i * 3 + i] = 1.0;
            }
            m
        };
        for axis in 0..3 {
            assert!(t.mode_apply(&id, axis).allclose(&t, 0.0));
        }
    }

    #[test]
    fn rho_apply_scales_by_power() {
        // g = 2·I ⇒ ρ_k(g) v = 2^k v.
        let t = Tensor::linspace(2, 3);
        let g = vec![2.0, 0.0, 0.0, 2.0];
        let r = t.rho_apply(&g);
        let mut want = t.clone();
        want.scale(8.0);
        assert!(r.allclose(&want, 1e-12));
    }

    #[test]
    fn axpy_permuted_matches_permute_then_axpy() {
        let mut rng = Rng::new(41);
        let t = Tensor::random(3, 4, &mut rng);
        let axes = [2, 0, 3, 1];
        let mut a = Tensor::random(3, 4, &mut rng);
        let mut b = a.clone();
        a.axpy(0.7, &t.permute_axes(&axes));
        t.axpy_permuted_into(0.7, &axes, &mut b);
        assert!(a.allclose(&b, 1e-14));
        // identity fast path
        let mut c = Tensor::zeros(3, 4);
        t.axpy_permuted_into(2.0, &[0, 1, 2, 3], &mut c);
        let mut want = t.clone();
        want.scale(2.0);
        assert!(c.allclose(&want, 0.0));
    }

    #[test]
    fn scatter_broadcast_matches_broadcast_then_embed() {
        let mut rng = Rng::new(43);
        for (lead, tail) in [
            (vec![2usize, 1], vec![1usize, 2]),
            (vec![], vec![2, 2]),
            (vec![3], vec![]),
            (vec![], vec![]),
        ] {
            let n = 2;
            let x = Tensor::random(n, tail.len(), &mut rng);
            let mut groups = lead.clone();
            groups.extend(tail.iter().copied());
            let want = x
                .broadcast_leading(lead.len())
                .embed_group_diagonals(&groups);
            let got = x.scatter_broadcast_diagonals(&lead, &tail);
            assert!(got.allclose(&want, 0.0), "lead {lead:?} tail {tail:?}");
        }
    }

    #[test]
    fn permute_axes_blocked_tail_matches_pointwise() {
        // Trailing axes unmoved: exercises the contiguous-block fast path.
        let mut rng = Rng::new(44);
        let t = Tensor::random(3, 4, &mut rng);
        for axes in [[1usize, 0, 2, 3], [2, 0, 1, 3], [1, 2, 0, 3]] {
            let p = t.permute_axes(&axes);
            for f in 0..p.len() {
                let idx = unflat_index(3, 4, f);
                let mut src = vec![0usize; 4];
                for (q, &a) in axes.iter().enumerate() {
                    src[a] = idx[q];
                }
                assert_eq!(p.data[f], t.get(&src), "axes {axes:?} at {idx:?}");
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_ops() {
        let mut rng = Rng::new(45);
        let t = Tensor::random(3, 4, &mut rng);
        // Stale buffers: the _into ops must fully overwrite (or zero) them.
        let stale = |order: usize| {
            let mut s = Tensor::zeros(3, order);
            s.data.fill(7.25);
            s
        };
        let axes = [2usize, 0, 3, 1];
        let mut out = stale(4);
        t.permute_axes_into(&axes, &mut out);
        assert!(out.allclose(&t.permute_axes(&axes), 0.0));
        let mut out = stale(4);
        t.permute_axes_into(&[0, 1, 2, 3], &mut out);
        assert!(out.allclose(&t, 0.0));
        let mut out = stale(2);
        t.contract_trailing_diagonal_into(2, &mut out);
        assert!(out.allclose(&t.contract_trailing_diagonal(2), 0.0));
        let mut out = stale(2);
        t.trace_trailing_pair_into(&mut out);
        assert!(out.allclose(&t.trace_trailing_pair(), 0.0));
        let mut out = stale(2);
        t.extract_group_diagonals_into(&[3, 1], &mut out);
        assert!(out.allclose(&t.extract_group_diagonals(&[3, 1]), 0.0));
        // ε-trace needs even n.
        let t4 = Tensor::random(4, 3, &mut rng);
        let mut out = Tensor::from_vec(4, 1, vec![9.0; 4]).unwrap();
        t4.trace_trailing_pair_eps_into(&mut out);
        assert!(out.allclose(&t4.trace_trailing_pair_eps(), 0.0));
        // Levi-Civita scatters, so its _into must zero the stale buffer.
        let t3 = Tensor::random(3, 3, &mut rng);
        let want = t3.levi_civita_contract_trailing(1);
        let mut out = stale(want.order);
        t3.levi_civita_contract_trailing_into(1, &mut out);
        assert!(out.allclose(&want, 0.0));
    }

    #[test]
    fn axpy_permuted_multi_matches_sequential_passes() {
        let mut rng = Rng::new(46);
        let t = Tensor::random(3, 3, &mut rng);
        let a1 = vec![2usize, 0, 1];
        let a2 = vec![1usize, 2, 0];
        let a3 = vec![0usize, 1, 2];
        let mut want = Tensor::random(3, 3, &mut rng);
        let mut got = want.clone();
        t.axpy_permuted_into(0.5, &a1, &mut want);
        t.axpy_permuted_into(-1.25, &a2, &mut want);
        t.axpy_permuted_into(2.0, &a3, &mut want);
        t.axpy_permuted_multi_into(&[(&a1, 0.5), (&a2, -1.25), (&a3, 2.0)], &mut got);
        assert!(
            want.allclose(&got, 1e-12),
            "multi axpy diverges by {}",
            want.max_abs_diff(&got)
        );
        // A single-pattern class is bitwise identical to the single kernel.
        let mut a = Tensor::zeros(3, 3);
        let mut b = Tensor::zeros(3, 3);
        t.axpy_permuted_into(0.7, &a1, &mut a);
        t.axpy_permuted_multi_into(&[(&a1, 0.7)], &mut b);
        assert!(a.allclose(&b, 0.0));
        // Empty class and order-0 both work.
        t.axpy_permuted_multi_into(&[], &mut b);
        assert!(a.allclose(&b, 0.0));
        let s = Tensor::from_vec(3, 0, vec![2.0]).unwrap();
        let mut o = Tensor::from_vec(3, 0, vec![1.0]).unwrap();
        let e: Vec<usize> = Vec::new();
        s.axpy_permuted_multi_into(&[(&e[..], 3.0), (&e[..], 1.0)], &mut o);
        assert_eq!(o.data[0], 9.0);
    }

    #[test]
    fn scatter_multi_matches_sequential_passes() {
        let mut rng = Rng::new(47);
        for (lead, tail) in [
            (vec![2usize, 1], vec![1usize, 2]),
            (vec![], vec![2, 2]),
            (vec![2], vec![]),
            (vec![], vec![1, 1]),
        ] {
            let n = 2;
            let total: usize = lead.iter().sum::<usize>() + tail.iter().sum::<usize>();
            let x = Tensor::random(n, tail.len(), &mut rng);
            let a1: Vec<usize> = (0..total).collect();
            let a2: Vec<usize> = (0..total).rev().collect();
            let mut want = Tensor::random(n, total, &mut rng);
            let mut got = want.clone();
            x.scatter_broadcast_diagonals_axpy(&lead, &tail, &a1, 0.4, &mut want);
            x.scatter_broadcast_diagonals_axpy(&lead, &tail, &a2, -0.9, &mut want);
            x.scatter_broadcast_diagonals_multi_axpy(
                &lead,
                &tail,
                &[(&a1, 0.4), (&a2, -0.9)],
                &mut got,
            );
            assert!(
                want.allclose(&got, 1e-12),
                "lead {lead:?} tail {tail:?}: diff {}",
                want.max_abs_diff(&got)
            );
            // Single-pattern class is bitwise identical.
            let mut a = Tensor::zeros(n, total);
            let mut b = Tensor::zeros(n, total);
            x.scatter_broadcast_diagonals_axpy(&lead, &tail, &a2, 1.5, &mut a);
            x.scatter_broadcast_diagonals_multi_axpy(&lead, &tail, &[(&a2, 1.5)], &mut b);
            assert!(a.allclose(&b, 0.0), "lead {lead:?} tail {tail:?}");
        }
    }

    /// Every fused permute-gather kernel must be **bitwise** equal to the
    /// materialised permute-then-op composition (same element visit order,
    /// same reduction order).
    #[test]
    fn fused_gather_kernels_match_composition_bitwise() {
        let mut rng = Rng::new(49);
        // Permuted diagonal contraction, several (order, m, axes) shapes.
        let t = Tensor::random(3, 4, &mut rng);
        for (axes, m) in [
            (vec![2usize, 0, 3, 1], 2usize),
            (vec![3, 1, 0, 2], 1),
            (vec![1, 0, 3, 2], 3),
            (vec![0, 1, 2, 3], 2), // identity permute degenerates to the plain op
        ] {
            let want = t.permute_axes(&axes).contract_trailing_diagonal(m);
            let mut got = Tensor::zeros(3, 4 - m);
            got.data.fill(7.25); // stale buffer must be fully overwritten
            t.contract_permuted_diagonal_into(&axes, m, &mut got);
            assert!(
                got.allclose(&want, 0.0),
                "contract axes {axes:?} m {m}: diff {}",
                got.max_abs_diff(&want)
            );
        }
        // Permuted ε-trace (even n).
        let t4 = Tensor::random(4, 3, &mut rng);
        for axes in [[2usize, 0, 1], [1, 2, 0], [0, 1, 2]] {
            let want = t4.permute_axes(&axes).trace_trailing_pair_eps();
            let mut got = Tensor::from_vec(4, 1, vec![9.0; 4]).unwrap();
            t4.trace_permuted_pair_eps_into(&axes, &mut got);
            assert!(got.allclose(&want, 0.0), "eps axes {axes:?}");
        }
        // Permuted group-diagonal extraction.
        for (axes, groups) in [
            (vec![2usize, 0, 3, 1], vec![3usize, 1]),
            (vec![1, 3, 0, 2], vec![2, 2]),
            (vec![3, 2, 1, 0], vec![1, 2, 1]),
        ] {
            let want = t.permute_axes(&axes).extract_group_diagonals(&groups);
            let mut got = Tensor::zeros(3, groups.len());
            got.data.fill(-3.5);
            t.extract_permuted_group_diagonals_into(&axes, &groups, &mut got);
            assert!(got.allclose(&want, 0.0), "extract axes {axes:?} groups {groups:?}");
        }
    }

    /// The precomputed-map replay helpers reproduce their building ops.
    #[test]
    fn replay_helpers_match_direct_kernels() {
        let mut rng = Rng::new(50);
        let t = Tensor::random(3, 3, &mut rng);
        // Blocked permute replay.
        let axes = [1usize, 2, 0];
        let (map, block) = permute_block_map(3, 3, &axes);
        let mut got = Tensor::zeros(3, 3);
        t.permute_blocks_into(&map, block, &mut got);
        assert!(got.allclose(&t.permute_axes(&axes), 0.0));
        // Levi-Civita replay off precomputed entries.
        let entries = levi_civita_entries(3, 1);
        let want = t.levi_civita_contract_trailing(1);
        let mut got = Tensor::zeros(3, want.order);
        got.data.fill(4.5);
        t.levi_civita_entries_into(1, &entries, &mut got);
        assert!(got.allclose(&want, 0.0));
        // Single-pattern sink replay: permuted axpy map…
        let dsts = permute_dst_map(3, 3, &axes);
        let mut a = Tensor::zeros(3, 3);
        let mut b = Tensor::zeros(3, 3);
        t.axpy_permuted_into(0.7, &axes, &mut a);
        t.axpy_dsts_into(&dsts, 0.7, &mut b);
        assert!(a.allclose(&b, 0.0));
        // …and the diagonal-support scatter map (reps > 1).
        let (lead, tail) = (vec![2usize], vec![1usize, 1]);
        let x = Tensor::random(2, 2, &mut rng);
        let saxes: Vec<usize> = (0..4).rev().collect();
        let sdsts = scatter_diag_dsts(2, &lead, &tail, &saxes);
        let mut a = Tensor::zeros(2, 4);
        let mut b = Tensor::zeros(2, 4);
        x.scatter_broadcast_diagonals_axpy(&lead, &tail, &saxes, 1.5, &mut a);
        x.axpy_dsts_into(&sdsts, 1.5, &mut b);
        assert!(a.allclose(&b, 0.0));
    }

    #[test]
    fn permute_dst_map_matches_permute() {
        let mut rng = Rng::new(48);
        let t = Tensor::random(3, 4, &mut rng);
        let axes = [2usize, 0, 3, 1];
        let map = permute_dst_map(3, 4, &axes);
        let p = t.permute_axes(&axes);
        for (s, &d) in map.iter().enumerate() {
            assert_eq!(p.data[d], t.data[s]);
        }
        assert_eq!(permute_dst_map(3, 0, &[]), vec![0]);
    }

    #[test]
    fn signed_permutations_count_and_signs() {
        let ps = signed_permutations(4);
        assert_eq!(ps.len(), 24);
        let plus = ps.iter().filter(|(_, s)| *s > 0.0).count();
        assert_eq!(plus, 12);
        // identity has sign +1
        let id = ps.iter().find(|(p, _)| p == &vec![0, 1, 2, 3]).unwrap();
        assert_eq!(id.1, 1.0);
    }

    /// Every windowed kernel, run slab by slab, must reproduce its
    /// full-tensor counterpart bitwise (slab width deliberately not a
    /// divisor of the output length to exercise the ragged tail).
    #[test]
    fn windowed_kernels_match_full_bitwise() {
        let n = 3;
        let mut rng = Rng::new(0x71);
        let slabs = |len: usize, width: usize| -> Vec<(usize, usize)> {
            (0..len)
                .step_by(width)
                .map(|lo| (lo, (lo + width).min(len)))
                .collect()
        };

        // contract_diag_window vs contract_trailing_diagonal (m = 2).
        let t = Tensor::random(n, 4, &mut rng);
        let full = t.contract_trailing_diagonal(2);
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(full.len(), 4) {
            let block = n * n;
            contract_diag_window(
                &t.data[lo * block..hi * block],
                n,
                2,
                &mut got[lo..hi],
            );
        }
        assert_eq!(got, full.data);

        // trace_eps_window vs trace_trailing_pair_eps (even n).
        let t = Tensor::random(4, 3, &mut rng);
        let full = t.trace_trailing_pair_eps();
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(full.len(), 3) {
            trace_eps_window(&t.data[lo * 16..hi * 16], 4, &mut got[lo..hi]);
        }
        assert_eq!(got, full.data);

        // permute_blocks_window vs permute_axes via the block map.
        let t = Tensor::random(n, 4, &mut rng);
        let axes = [2usize, 0, 1, 3];
        let (map, block) = permute_block_map(n, 4, &axes);
        let full = t.permute_axes(&axes);
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(map.len(), 5) {
            permute_blocks_window(
                &t.data,
                &map[lo..hi],
                block,
                &mut got[lo * block..hi * block],
            );
        }
        assert_eq!(got, full.data);

        // gather_window vs extract_group_diagonals via the offset table.
        let groups = [2usize, 2];
        let offs = group_diag_offsets(n, 4, &groups);
        let full = t.extract_group_diagonals(&groups);
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(offs.len(), 4) {
            gather_window(&t.data, &offs[lo..hi], &mut got[lo..hi]);
        }
        assert_eq!(got, full.data);

        // gather_contract_window vs contract_permuted_diagonal_into.
        let axes = [1usize, 3, 0, 2];
        let m = 2;
        let mut full = Tensor::zeros(n, 2);
        t.contract_permuted_diagonal_into(&axes, m, &mut full);
        let strides = axis_strides(n, 4);
        let dstride: usize = axes[4 - m..].iter().map(|&a| strides[a]).sum();
        let base = permuted_gather_base(n, 4, &axes, m);
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(base.len(), 4) {
            gather_contract_window(&t.data, n, &base[lo..hi], dstride, &mut got[lo..hi]);
        }
        assert_eq!(got, full.data);

        // gather_eps_trace_window vs trace_permuted_pair_eps_into.
        let t = Tensor::random(4, 3, &mut rng);
        let axes = [2usize, 0, 1];
        let mut full = Tensor::zeros(4, 1);
        t.trace_permuted_pair_eps_into(&axes, &mut full);
        let strides = axis_strides(4, 3);
        let sa = strides[axes[1]];
        let sb = strides[axes[2]];
        let base = permuted_gather_base(4, 3, &axes, 2);
        let mut got = vec![0.0f64; full.len()];
        for (lo, hi) in slabs(base.len(), 3) {
            gather_eps_trace_window(&t.data, 4, &base[lo..hi], sa, sb, &mut got[lo..hi]);
        }
        assert_eq!(got, full.data);
    }
}
