//! Contiguous batch layout `[B, n^k]` for batch-axis fused execution.
//!
//! The per-item kernels in [`super::ops`] recompute their odometer index
//! arithmetic on every call; when a layer applies the same schedule node to
//! every item of a batch, that arithmetic is identical across items. A
//! [`BatchTensorOf`] stores `B` same-shape tensors back to back so a
//! batched kernel can build its index map **once per node** and then sweep
//! the batch with pure loads/stores:
//!
//! - odometer-driven ops (permute, group-diagonal extraction, the
//!   diagonal-support scatter, Levi-Civita, the Sp(n) ε-expansion) share a
//!   precomputed offset map across all `B` items,
//! - constant-stride scans (diagonal contraction, pair traces) keep their
//!   incremental per-item form — their index math is already O(1) per
//!   element — and simply loop the items over one precomputed descriptor,
//! - the fused gather-contract kernels additionally tile their outer-offset
//!   tables in L1-sized chunks and sweep the batch inside each tile, so the
//!   table stays cache-resident across items.
//!
//! Every batched kernel applies, per item, **exactly** the arithmetic of
//! its per-item counterpart in the same order, so batch-fused schedule
//! execution ([`crate::fastmult::LayerSchedule::execute_batch`]) is bitwise
//! identical per item to the per-item walk — tiling reorders *which output
//! is computed when*, never the summation order within an output. See
//! `docs/batched_execution.md` and `docs/scalar_precision.md`.

use super::ops::{
    axis_strides, group_diag_offsets, levi_civita_entries, permute_block_map, permute_dst_map,
    permuted_gather_base, permuted_group_diag_offsets, scatter_diag_dsts,
};
use super::scalar::{axpy_slice, ramp_base, Scalar};
use super::TensorOf;
use crate::error::{Error, Result};

/// Output-tile width for the fused gather kernels: how many outer-offset
/// table entries are processed per batch sweep. 512 `usize` entries ≈ 4 KiB
/// — comfortably L1-resident alongside the source/destination lines.
const GATHER_TILE: usize = 512;

/// `B` tensors of shape `(n, order)` over scalar type `S`, stored
/// contiguously item-major: item `b` occupies
/// `data[b * n^order .. (b + 1) * n^order]`, each item row-major exactly
/// like a [`TensorOf`].
#[derive(Debug, Clone, PartialEq)]
pub struct BatchTensorOf<S: Scalar> {
    n: usize,
    order: usize,
    batch: usize,
    data: Vec<S>,
}

/// The training-precision batch — the alias pre-existing call sites use.
pub type BatchTensor = BatchTensorOf<f64>;

impl<S: Scalar> BatchTensorOf<S> {
    /// All-zeros batch of `batch` tensors of shape `(n, order)`.
    pub fn zeros(n: usize, order: usize, batch: usize) -> Self {
        BatchTensorOf {
            n,
            order,
            batch,
            data: vec![S::ZERO; batch * n.pow(order as u32)],
        }
    }

    /// Wrap an existing buffer (length must be `batch · n^order`). Used by
    /// the scratch arena, which recycles buffers across shapes.
    pub(crate) fn from_raw(n: usize, order: usize, batch: usize, data: Vec<S>) -> Self {
        debug_assert_eq!(data.len(), batch * n.pow(order as u32));
        BatchTensorOf {
            n,
            order,
            batch,
            data,
        }
    }

    /// Give the buffer back (for the scratch arena's recycling buckets).
    pub(crate) fn into_raw(self) -> Vec<S> {
        self.data
    }

    /// Pack owned tensors into one contiguous batch. All items must share
    /// the same `(n, order)`; an empty slice is rejected (there is no shape
    /// to infer).
    pub fn pack(items: &[TensorOf<S>]) -> Result<Self> {
        let refs: Vec<&TensorOf<S>> = items.iter().collect();
        Self::pack_refs(&refs)
    }

    /// [`BatchTensorOf::pack`] over borrowed tensors (the coordinator
    /// batches requests it does not own).
    pub fn pack_refs(items: &[&TensorOf<S>]) -> Result<Self> {
        let Some(first) = items.first() else {
            return Err(Error::ShapeMismatch {
                expected: "a non-empty batch".into(),
                got: "0 tensors".into(),
            });
        };
        let (n, order) = (first.n, first.order);
        for t in items {
            if t.n != n || t.order != order {
                return Err(Error::ShapeMismatch {
                    expected: format!("uniform batch of order-{order} tensors over R^{n}"),
                    got: format!("order {} over R^{}", t.order, t.n),
                });
            }
        }
        let mut data = Vec::with_capacity(items.len() * first.len());
        for t in items {
            data.extend_from_slice(&t.data);
        }
        Ok(BatchTensorOf {
            n,
            order,
            batch: items.len(),
            data,
        })
    }

    /// Split back into per-item tensors, in batch order.
    pub fn unpack(self) -> Vec<TensorOf<S>> {
        let len = self.item_len();
        self.data
            .chunks(len)
            .map(|chunk| TensorOf {
                n: self.n,
                order: self.order,
                data: chunk.to_vec(),
            })
            .collect()
    }

    /// Axis extent.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    /// Tensor-power order of each item.
    #[inline]
    pub fn order(&self) -> usize {
        self.order
    }
    /// Number of items.
    #[inline]
    pub fn batch(&self) -> usize {
        self.batch
    }
    /// Coefficients per item, `n^order`.
    #[inline]
    pub fn item_len(&self) -> usize {
        self.n.pow(self.order as u32)
    }

    /// The whole `[B, n^order]` buffer (item-major).
    pub fn data(&self) -> &[S] {
        &self.data
    }
    /// Mutable access to the whole buffer.
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Item `b`'s coefficients.
    #[inline]
    pub fn item(&self, b: usize) -> &[S] {
        let len = self.item_len();
        &self.data[b * len..(b + 1) * len]
    }

    /// Mutable coefficients of item `b`.
    #[inline]
    pub fn item_mut(&mut self, b: usize) -> &mut [S] {
        let len = self.item_len();
        &mut self.data[b * len..(b + 1) * len]
    }

    /// Item `b` copied out as a standalone [`TensorOf`].
    pub fn item_tensor(&self, b: usize) -> TensorOf<S> {
        TensorOf {
            n: self.n,
            order: self.order,
            data: self.item(b).to_vec(),
        }
    }

    /// `item_b += alpha * t` for every item — the batch-shared bias add
    /// (lane-chunked per item; bitwise equal to the scalar loop).
    pub fn axpy_broadcast(&mut self, alpha: f64, t: &TensorOf<S>) {
        assert_eq!(self.n, t.n);
        assert_eq!(self.order, t.order);
        let alpha = S::from_f64(alpha);
        let len = self.item_len();
        for chunk in self.data.chunks_mut(len) {
            axpy_slice(alpha, &t.data, chunk);
        }
    }

    /// Max absolute difference from a same-shape batch (computed in `S`,
    /// reported in `f64`).
    pub fn max_abs_diff(&self, other: &BatchTensorOf<S>) -> f64 {
        assert_eq!(self.n, other.n);
        assert_eq!(self.order, other.order);
        assert_eq!(self.batch, other.batch);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(S::ZERO, S::max)
            .to_f64()
    }

    // -----------------------------------------------------------------
    // Batched kernels (see module docs: per-item arithmetic is bitwise
    // identical to the ops in `super::ops`, index maps are shared).
    // -----------------------------------------------------------------

    fn check_like(&self, out: &BatchTensorOf<S>, order: usize) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.order, order);
        assert_eq!(out.batch, self.batch);
    }

    /// Same validation as the per-item kernels: `axes` must be a
    /// permutation of the item axes (the fused gather kernels would
    /// silently read garbage through duplicate strides otherwise).
    fn check_axes(&self, axes: &[usize]) {
        assert_eq!(axes.len(), self.order, "axes arity must match order");
        debug_assert!({
            let mut seen = vec![false; self.order];
            axes.iter().all(|&a| {
                let fresh = !seen[a];
                seen[a] = true;
                fresh
            })
        });
    }

    /// Batched [`TensorOf::permute_axes_into`]: the block map is built
    /// once, every item is then a sequence of contiguous block copies.
    pub fn permute_axes_into(&self, axes: &[usize], out: &mut BatchTensorOf<S>) {
        let (map, block) = permute_block_map(self.n, self.order, axes);
        self.permute_blocks_into(&map, block, out);
    }

    /// Replay of [`BatchTensorOf::permute_axes_into`] off a precomputed
    /// block map (built once per kernel plan by `fastmult::schedule`).
    pub(crate) fn permute_blocks_into(
        &self,
        map: &[usize],
        block: usize,
        out: &mut BatchTensorOf<S>,
    ) {
        self.check_like(out, self.order);
        let len = self.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * len..(b + 1) * len];
            let dst = &mut out.data[b * len..(b + 1) * len];
            let mut d = 0usize;
            for &s in map {
                dst[d..d + block].copy_from_slice(&src[s..s + block]);
                d += block;
            }
        }
    }

    /// Batched [`TensorOf::contract_permuted_diagonal_into`]: the fused
    /// permute-contract gather with one outer-offset table shared by every
    /// item; per item bitwise identical to the per-item fused kernel (and
    /// therefore to the materialised permute-then-contract composition).
    pub fn contract_permuted_diagonal_into(
        &self,
        axes: &[usize],
        m: usize,
        out: &mut BatchTensorOf<S>,
    ) {
        self.check_axes(axes);
        assert!(m >= 1 && m <= self.order);
        self.check_like(out, self.order - m);
        let strides = axis_strides(self.n, self.order);
        let dstride: usize = axes[self.order - m..].iter().map(|&a| strides[a]).sum();
        let base = permuted_gather_base(self.n, self.order, axes, m);
        self.gather_contract_with(&base, dstride, out);
    }

    /// Replay of [`BatchTensorOf::contract_permuted_diagonal_into`] off a
    /// precomputed outer-offset table. The table is swept in
    /// [`GATHER_TILE`]-sized output tiles with the batch loop inside each
    /// tile, so the tile stays L1-resident across all `B` items; outputs
    /// are independent and each keeps its full `n`-term sum order, so
    /// tiling is bitwise-neutral.
    pub(crate) fn gather_contract_with(
        &self,
        base: &[usize],
        dstride: usize,
        out: &mut BatchTensorOf<S>,
    ) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let n = self.n;
        let ilen = self.item_len();
        let olen = out.item_len();
        debug_assert_eq!(base.len(), olen);
        for (t, tile) in base.chunks(GATHER_TILE).enumerate() {
            let obase = t * GATHER_TILE;
            for b in 0..self.batch {
                let src = &self.data[b * ilen..(b + 1) * ilen];
                let start = b * olen + obase;
                let dst = &mut out.data[start..start + tile.len()];
                for (slot, &bo) in dst.iter_mut().zip(tile) {
                    let mut s = S::ZERO;
                    let mut off = bo;
                    for _ in 0..n {
                        s += src[off];
                        off += dstride;
                    }
                    *slot = s;
                }
            }
        }
    }

    /// Batched [`TensorOf::trace_permuted_pair_eps_into`].
    pub fn trace_permuted_pair_eps_into(&self, axes: &[usize], out: &mut BatchTensorOf<S>) {
        self.check_axes(axes);
        assert!(self.order >= 2);
        assert_eq!(self.n % 2, 0, "Sp(n) requires even n");
        self.check_like(out, self.order - 2);
        let strides = axis_strides(self.n, self.order);
        let sa = strides[axes[self.order - 2]];
        let sb = strides[axes[self.order - 1]];
        let base = permuted_gather_base(self.n, self.order, axes, 2);
        self.gather_eps_trace_with(&base, sa, sb, out);
    }

    /// Replay of [`BatchTensorOf::trace_permuted_pair_eps_into`] off a
    /// precomputed outer-offset table plus the traced axes' strides;
    /// L1-tiled the same way as [`BatchTensorOf::gather_contract_with`].
    pub(crate) fn gather_eps_trace_with(
        &self,
        base: &[usize],
        sa: usize,
        sb: usize,
        out: &mut BatchTensorOf<S>,
    ) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let n = self.n;
        let ilen = self.item_len();
        let olen = out.item_len();
        debug_assert_eq!(base.len(), olen);
        for (t, tile) in base.chunks(GATHER_TILE).enumerate() {
            let obase = t * GATHER_TILE;
            for b in 0..self.batch {
                let src = &self.data[b * ilen..(b + 1) * ilen];
                let start = b * olen + obase;
                let dst = &mut out.data[start..start + tile.len()];
                for (slot, &bo) in dst.iter_mut().zip(tile) {
                    let mut s = S::ZERO;
                    for i in 0..n / 2 {
                        let p = 2 * i;
                        let q = 2 * i + 1;
                        s += src[bo + p * sa + q * sb] - src[bo + q * sa + p * sb];
                    }
                    *slot = s;
                }
            }
        }
    }

    /// Batched [`TensorOf::extract_permuted_group_diagonals_into`].
    pub fn extract_permuted_group_diagonals_into(
        &self,
        axes: &[usize],
        groups: &[usize],
        out: &mut BatchTensorOf<S>,
    ) {
        self.check_axes(axes);
        self.check_like(out, groups.len());
        let offs = permuted_group_diag_offsets(self.n, self.order, axes, groups);
        self.gather_with(&offs, out);
    }

    /// Pure gather replay, one offset table shared by every item (group-
    /// diagonal extraction, permuted or not).
    pub(crate) fn gather_with(&self, offs: &[usize], out: &mut BatchTensorOf<S>) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let ilen = self.item_len();
        let olen = out.item_len();
        debug_assert_eq!(offs.len(), olen);
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for (slot, &s) in dst.iter_mut().zip(offs) {
                *slot = src[s];
            }
        }
    }

    /// Single-pattern sink replay off a precomputed destination map, per
    /// item: the batched twin of [`TensorOf::axpy_dsts_into`]. Contiguous
    /// (ramp) destination maps route through the lane-chunked axpy.
    pub(crate) fn axpy_dsts_into(&self, dsts: &[usize], alpha: f64, out: &mut BatchTensorOf<S>) {
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let alpha = S::from_f64(alpha);
        let ilen = self.item_len();
        let olen = out.item_len();
        debug_assert_eq!(dsts.len() % ilen.max(1), 0);
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for rep in dsts.chunks(ilen) {
                if let Some(d0) = ramp_base(rep) {
                    axpy_slice(alpha, src, &mut dst[d0..d0 + rep.len()]);
                } else {
                    for (&d, &x) in rep.iter().zip(src) {
                        dst[d] += alpha * x;
                    }
                }
            }
        }
    }

    /// Batched [`TensorOf::contract_trailing_diagonal_into`].
    pub fn contract_trailing_diagonal_into(&self, m: usize, out: &mut BatchTensorOf<S>) {
        assert!(m >= 1 && m <= self.order);
        self.check_like(out, self.order - m);
        let n = self.n;
        let keep = self.order - m;
        let block = n.pow(m as u32);
        let dstride: usize = (0..m).map(|a| n.pow(a as u32)).sum();
        let outer = n.pow(keep as u32);
        let ilen = self.item_len();
        let olen = out.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for (o, slot) in dst.iter_mut().enumerate().take(outer) {
                let mut s = S::ZERO;
                let mut off = o * block;
                for _ in 0..n {
                    s += src[off];
                    off += dstride;
                }
                *slot = s;
            }
        }
    }

    /// Batched [`TensorOf::trace_trailing_pair_into`].
    pub fn trace_trailing_pair_into(&self, out: &mut BatchTensorOf<S>) {
        self.contract_trailing_diagonal_into(2, out)
    }

    /// Batched [`TensorOf::trace_trailing_pair_eps_into`].
    pub fn trace_trailing_pair_eps_into(&self, out: &mut BatchTensorOf<S>) {
        assert!(self.order >= 2);
        self.check_like(out, self.order - 2);
        let n = self.n;
        assert_eq!(n % 2, 0, "Sp(n) requires even n");
        let block = n * n;
        let outer = n.pow((self.order - 2) as u32);
        let ilen = self.item_len();
        let olen = out.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for (o, slot) in dst.iter_mut().enumerate().take(outer) {
                let base = o * block;
                let mut s = S::ZERO;
                for i in 0..n / 2 {
                    let p = 2 * i;
                    let q = 2 * i + 1;
                    s += src[base + p * n + q] - src[base + q * n + p];
                }
                *slot = s;
            }
        }
    }

    /// Batched [`TensorOf::levi_civita_contract_trailing_into`]: the
    /// signed permutation table and its flat offsets are built once for all
    /// items.
    pub fn levi_civita_contract_trailing_into(&self, s: usize, out: &mut BatchTensorOf<S>) {
        let n = self.n;
        assert!(s <= n);
        let nb = n - s;
        assert!(nb <= self.order);
        let entries = levi_civita_entries(n, s);
        self.levi_civita_entries_into(s, &entries, out);
    }

    /// Replay of [`BatchTensorOf::levi_civita_contract_trailing_into`] off
    /// a precomputed signed-permutation offset table (see
    /// [`levi_civita_entries`]); scatters, so each item is zeroed first.
    pub(crate) fn levi_civita_entries_into(
        &self,
        s: usize,
        entries: &[(usize, usize, f64)],
        out: &mut BatchTensorOf<S>,
    ) {
        let n = self.n;
        let nb = n - s;
        self.check_like(out, self.order - nb + s);
        let signs: Vec<S> = entries.iter().map(|&(_, _, sg)| S::from_f64(sg)).collect();
        let keep = self.order - nb;
        let in_block = n.pow(nb as u32);
        let out_block = n.pow(s as u32);
        let outer = n.pow(keep as u32);
        let ilen = self.item_len();
        let olen = out.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            dst.fill(S::ZERO);
            for o in 0..outer {
                let in_base = o * in_block;
                let out_base = o * out_block;
                for (&(t_off, b_off, _), &sign) in entries.iter().zip(&signs) {
                    dst[out_base + t_off] += sign * src[in_base + b_off];
                }
            }
        }
    }

    /// Batched [`TensorOf::extract_group_diagonals_into`]: one gather-
    /// offset map shared by every item.
    pub fn extract_group_diagonals_into(&self, groups: &[usize], out: &mut BatchTensorOf<S>) {
        self.check_like(out, groups.len());
        let offs = group_diag_offsets(self.n, self.order, groups);
        self.gather_with(&offs, out);
    }

    /// Batched [`TensorOf::axpy_permuted_into`], via the shared block map;
    /// each contiguous block tail goes through the lane-chunked axpy.
    pub fn axpy_permuted_into(&self, alpha: f64, axes: &[usize], out: &mut BatchTensorOf<S>) {
        self.check_like(out, self.order);
        let alpha = S::from_f64(alpha);
        let (map, block) = permute_block_map(self.n, self.order, axes);
        let len = self.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * len..(b + 1) * len];
            let dst = &mut out.data[b * len..(b + 1) * len];
            let mut d = 0usize;
            for &s in &map {
                axpy_slice(alpha, &src[s..s + block], &mut dst[d..d + block]);
                d += block;
            }
        }
    }

    /// Batched [`TensorOf::axpy_permuted_multi_into`]: one destination map
    /// per pattern, built once and replayed over every item. Per item the
    /// arithmetic (source-major, pattern-inner) is exactly that of the
    /// per-item multi kernel, so batched folded-class execution stays
    /// bitwise identical per item to the per-item folded walk. A
    /// single-pattern class delegates to the blocked
    /// [`BatchTensorOf::axpy_permuted_into`] (bitwise exact — one
    /// contribution per destination either way), skipping the per-pattern
    /// map indirection.
    pub fn axpy_permuted_multi_into(&self, pats: &[(&[usize], f64)], out: &mut BatchTensorOf<S>) {
        self.check_like(out, self.order);
        if pats.is_empty() {
            return;
        }
        if let [(axes, alpha)] = pats {
            return self.axpy_permuted_into(*alpha, axes, out);
        }
        let ws: Vec<S> = pats.iter().map(|&(_, alpha)| S::from_f64(alpha)).collect();
        let maps: Vec<Vec<usize>> = pats
            .iter()
            .map(|(axes, _)| permute_dst_map(self.n, self.order, axes))
            .collect();
        let len = self.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * len..(b + 1) * len];
            let dst = &mut out.data[b * len..(b + 1) * len];
            for (s, &x) in src.iter().enumerate() {
                for (map, &w) in maps.iter().zip(&ws) {
                    dst[map[s]] += w * x;
                }
            }
        }
    }

    /// Batched [`TensorOf::scatter_broadcast_diagonals_multi_axpy`]: one
    /// diagonal-support destination map per pattern, shared by every item.
    /// Per item the visit order (rep-major, source-inner, pattern-inner)
    /// matches the per-item multi kernel exactly.
    pub fn scatter_broadcast_diagonals_multi_axpy(
        &self,
        lead_groups: &[usize],
        tail_groups: &[usize],
        pats: &[(&[usize], f64)],
        out: &mut BatchTensorOf<S>,
    ) {
        assert_eq!(tail_groups.len(), self.order);
        if pats.is_empty() {
            return;
        }
        let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
        assert_eq!(out.order, total);
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let ws: Vec<S> = pats.iter().map(|&(_, alpha)| S::from_f64(alpha)).collect();
        let maps: Vec<Vec<usize>> = pats
            .iter()
            .map(|(axes, _)| scatter_diag_dsts(self.n, lead_groups, tail_groups, axes))
            .collect();
        let tail_len = self.item_len();
        let reps = maps[0].len() / tail_len;
        let olen = out.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * tail_len..(b + 1) * tail_len];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for r in 0..reps {
                let base = r * tail_len;
                for (s, &x) in src.iter().enumerate() {
                    for (map, &w) in maps.iter().zip(&ws) {
                        dst[map[base + s]] += w * x;
                    }
                }
            }
        }
    }

    /// Batched [`TensorOf::scatter_broadcast_diagonals_axpy`]: the
    /// diagonal-support destination offsets are computed once; each item is
    /// then a blocked axpy over `B · n^{t+d}` contiguous source lanes, with
    /// ramp destination maps routed through the lane-chunked axpy.
    pub fn scatter_broadcast_diagonals_axpy(
        &self,
        lead_groups: &[usize],
        tail_groups: &[usize],
        axes: &[usize],
        alpha: f64,
        out: &mut BatchTensorOf<S>,
    ) {
        assert_eq!(tail_groups.len(), self.order);
        let total: usize = lead_groups.iter().sum::<usize>() + tail_groups.iter().sum::<usize>();
        assert_eq!(axes.len(), total);
        assert_eq!(out.order, total);
        assert_eq!(out.n, self.n);
        assert_eq!(out.batch, self.batch);
        let alpha = S::from_f64(alpha);
        let dsts = scatter_diag_dsts(self.n, lead_groups, tail_groups, axes);
        let tail_len = self.item_len();
        let ilen = tail_len;
        let olen = out.item_len();
        for b in 0..self.batch {
            let src = &self.data[b * ilen..(b + 1) * ilen];
            let dst = &mut out.data[b * olen..(b + 1) * olen];
            for rep in dsts.chunks(tail_len) {
                if let Some(d0) = ramp_base(rep) {
                    axpy_slice(alpha, src, &mut dst[d0..d0 + rep.len()]);
                } else {
                    for (&d, &x) in rep.iter().zip(src) {
                        dst[d] += alpha * x;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn random_batch(n: usize, order: usize, b: usize, rng: &mut Rng) -> (Vec<Tensor>, BatchTensor) {
        let items: Vec<Tensor> = (0..b).map(|_| Tensor::random(n, order, rng)).collect();
        let packed = BatchTensor::pack(&items).unwrap();
        (items, packed)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(1001);
        let (items, packed) = random_batch(3, 2, 5, &mut rng);
        assert_eq!(packed.batch(), 5);
        assert_eq!(packed.item_len(), 9);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(packed.item(b), t.data.as_slice());
            assert!(packed.item_tensor(b).allclose(t, 0.0));
        }
        let back = packed.unpack();
        for (a, b) in items.iter().zip(&back) {
            assert!(a.allclose(b, 0.0));
        }
    }

    #[test]
    fn pack_rejects_mixed_and_empty() {
        let a = Tensor::zeros(3, 2);
        let b = Tensor::zeros(3, 1);
        assert!(BatchTensor::pack(&[a.clone(), b]).is_err());
        let c = Tensor::zeros(2, 2);
        assert!(BatchTensor::pack(&[a, c]).is_err());
        assert!(BatchTensor::pack(&[]).is_err());
    }

    /// Every batched kernel must match the per-item `_into` op bitwise on
    /// every item.
    #[test]
    fn batched_kernels_match_per_item_bitwise() {
        let mut rng = Rng::new(1002);
        let (items, packed) = random_batch(3, 4, 4, &mut rng);

        // permute
        let axes = [2usize, 0, 3, 1];
        let mut out = BatchTensor::zeros(3, 4, 4);
        packed.permute_axes_into(&axes, &mut out);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(out.item(b), t.permute_axes(&axes).data.as_slice());
        }
        // identity permute fast path
        let mut out = BatchTensor::zeros(3, 4, 4);
        packed.permute_axes_into(&[0, 1, 2, 3], &mut out);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(out.item(b), t.data.as_slice());
        }

        // diagonal contraction
        let mut out = BatchTensor::zeros(3, 2, 4);
        packed.contract_trailing_diagonal_into(2, &mut out);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(out.item(b), t.contract_trailing_diagonal(2).data.as_slice());
        }

        // pair trace
        let mut out = BatchTensor::zeros(3, 2, 4);
        packed.trace_trailing_pair_into(&mut out);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(out.item(b), t.trace_trailing_pair().data.as_slice());
        }

        // ε-trace (even n)
        let (items4, packed4) = random_batch(4, 3, 3, &mut rng);
        let mut out = BatchTensor::zeros(4, 1, 3);
        packed4.trace_trailing_pair_eps_into(&mut out);
        for (b, t) in items4.iter().enumerate() {
            assert_eq!(out.item(b), t.trace_trailing_pair_eps().data.as_slice());
        }

        // Levi-Civita
        let (items3, packed3) = random_batch(3, 3, 3, &mut rng);
        let want0 = items3[0].levi_civita_contract_trailing(1);
        let mut out = BatchTensor::zeros(3, want0.order, 3);
        packed3.levi_civita_contract_trailing_into(1, &mut out);
        for (b, t) in items3.iter().enumerate() {
            assert_eq!(
                out.item(b),
                t.levi_civita_contract_trailing(1).data.as_slice()
            );
        }

        // group-diagonal extraction
        let mut out = BatchTensor::zeros(3, 2, 4);
        packed.extract_group_diagonals_into(&[3, 1], &mut out);
        for (b, t) in items.iter().enumerate() {
            assert_eq!(out.item(b), t.extract_group_diagonals(&[3, 1]).data.as_slice());
        }

        // permuted axpy
        let mut got = BatchTensor::pack(&items).unwrap();
        let mut want: Vec<Tensor> = items.clone();
        packed.axpy_permuted_into(0.75, &axes, &mut got);
        for (b, w) in want.iter_mut().enumerate() {
            items[b].axpy_permuted_into(0.75, &axes, w);
            assert_eq!(got.item(b), w.data.as_slice());
        }
    }

    #[test]
    fn batched_scatter_matches_per_item_bitwise() {
        let mut rng = Rng::new(1003);
        for (lead, tail) in [
            (vec![2usize, 1], vec![1usize, 2]),
            (vec![], vec![2, 2]),
            (vec![2], vec![]),
        ] {
            let n = 2;
            let total: usize = lead.iter().sum::<usize>() + tail.iter().sum::<usize>();
            let axes: Vec<usize> = (0..total).rev().collect(); // a nontrivial σ_l
            let (items, packed) = random_batch(n, tail.len(), 3, &mut rng);
            let mut got = BatchTensor::zeros(n, total, 3);
            packed.scatter_broadcast_diagonals_axpy(&lead, &tail, &axes, 0.5, &mut got);
            for (b, t) in items.iter().enumerate() {
                let mut want = Tensor::zeros(n, total);
                t.scatter_broadcast_diagonals_axpy(&lead, &tail, &axes, 0.5, &mut want);
                assert_eq!(got.item(b), want.data.as_slice(), "lead {lead:?} tail {tail:?}");
            }
        }
    }

    /// The batched multi-pattern kernels must match their per-item multi
    /// counterparts bitwise on every item (same source-major, pattern-inner
    /// visit order, shared index maps).
    #[test]
    fn batched_multi_kernels_match_per_item_bitwise() {
        let mut rng = Rng::new(1005);
        let (items, packed) = random_batch(3, 3, 4, &mut rng);
        let a1 = vec![2usize, 0, 1];
        let a2 = vec![1usize, 2, 0];
        let pats: Vec<(&[usize], f64)> = vec![(&a1, 0.5), (&a2, -1.5)];
        let mut got = BatchTensor::zeros(3, 3, 4);
        packed.axpy_permuted_multi_into(&pats, &mut got);
        for (b, t) in items.iter().enumerate() {
            let mut want = Tensor::zeros(3, 3);
            t.axpy_permuted_multi_into(&pats, &mut want);
            assert_eq!(got.item(b), want.data.as_slice());
        }

        let (lead, tail) = (vec![2usize], vec![1usize, 1]);
        let total = 4usize;
        let s1: Vec<usize> = (0..total).collect();
        let s2: Vec<usize> = (0..total).rev().collect();
        let spats: Vec<(&[usize], f64)> = vec![(&s1, 0.25), (&s2, 2.0)];
        let (sitems, spacked) = random_batch(2, tail.len(), 3, &mut rng);
        let mut got = BatchTensor::zeros(2, total, 3);
        spacked.scatter_broadcast_diagonals_multi_axpy(&lead, &tail, &spats, &mut got);
        for (b, t) in sitems.iter().enumerate() {
            let mut want = Tensor::zeros(2, total);
            t.scatter_broadcast_diagonals_multi_axpy(&lead, &tail, &spats, &mut want);
            assert_eq!(got.item(b), want.data.as_slice(), "item {b}");
        }
    }

    /// The batched fused permute-gather kernels match the per-item fused
    /// kernels bitwise on every item (shared tables, same visit order).
    #[test]
    fn batched_fused_gather_kernels_match_per_item_bitwise() {
        let mut rng = Rng::new(1006);
        let (items, packed) = random_batch(3, 4, 3, &mut rng);
        let axes = [2usize, 0, 3, 1];
        // permuted diagonal contraction
        let mut got = BatchTensor::zeros(3, 2, 3);
        packed.contract_permuted_diagonal_into(&axes, 2, &mut got);
        for (b, t) in items.iter().enumerate() {
            let mut want = Tensor::zeros(3, 2);
            t.contract_permuted_diagonal_into(&axes, 2, &mut want);
            assert_eq!(got.item(b), want.data.as_slice(), "item {b}");
        }
        // permuted group-diagonal extraction
        let groups = [3usize, 1];
        let mut got = BatchTensor::zeros(3, 2, 3);
        packed.extract_permuted_group_diagonals_into(&axes, &groups, &mut got);
        for (b, t) in items.iter().enumerate() {
            let mut want = Tensor::zeros(3, 2);
            t.extract_permuted_group_diagonals_into(&axes, &groups, &mut want);
            assert_eq!(got.item(b), want.data.as_slice(), "item {b}");
        }
        // permuted ε-trace (even n)
        let (items4, packed4) = random_batch(4, 3, 2, &mut rng);
        let eaxes = [1usize, 2, 0];
        let mut got = BatchTensor::zeros(4, 1, 2);
        packed4.trace_permuted_pair_eps_into(&eaxes, &mut got);
        for (b, t) in items4.iter().enumerate() {
            let mut want = Tensor::zeros(4, 1);
            t.trace_permuted_pair_eps_into(&eaxes, &mut want);
            assert_eq!(got.item(b), want.data.as_slice(), "item {b}");
        }
    }

    #[test]
    fn axpy_broadcast_adds_shared_tensor() {
        let mut rng = Rng::new(1004);
        let (items, mut packed) = random_batch(3, 2, 3, &mut rng);
        let bias = Tensor::random(3, 2, &mut rng);
        packed.axpy_broadcast(2.0, &bias);
        for (b, t) in items.iter().enumerate() {
            let mut want = t.clone();
            want.axpy(2.0, &bias);
            assert!(packed.item_tensor(b).allclose(&want, 0.0));
        }
    }

    /// The generic batch kernels instantiated at `f32` track the `f64`
    /// reference within the scaled tolerance (same inputs narrowed once).
    #[test]
    fn f32_batch_tracks_f64_within_tolerance() {
        let mut rng = Rng::new(1007);
        let (_, packed) = random_batch(3, 4, 3, &mut rng);
        let packed32 = BatchTensorOf::<f32>::from_raw(
            3,
            4,
            3,
            packed.data().iter().map(|&x| x as f32).collect(),
        );
        let axes = [2usize, 0, 3, 1];
        let mut out64 = BatchTensor::zeros(3, 2, 3);
        packed.contract_permuted_diagonal_into(&axes, 2, &mut out64);
        let mut out32 = BatchTensorOf::<f32>::zeros(3, 2, 3);
        packed32.contract_permuted_diagonal_into(&axes, 2, &mut out32);
        let tol = <f32 as Scalar>::TOLERANCE * 16.0;
        for (a, b) in out64.data().iter().zip(out32.data()) {
            assert!((a - *b as f64).abs() <= tol, "{a} vs {b}");
        }
    }
}
