//! The equivariant linear layer.

use super::input::{BatchInput, BatchOutput};
use crate::diagram::{
    all_brauer_diagrams, all_jellyfish_diagrams, all_partition_diagrams, Diagram,
};
use crate::error::{Error, Result};
use crate::fastmult::{
    Group, LayerSchedule, MultPlan, PlanCache, PooledArenaOf, ScheduleStats,
};
use crate::tensor::{BatchTensorOf, Scalar, Tensor, TensorOf};
use crate::util::parallel::{max_threads, parallel_map, span_len};
use crate::util::Rng;
use std::sync::Arc;

/// Weight initialisation schemes for the diagram coefficients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    /// All coefficients zero (useful for testing).
    Zeros,
    /// iid normal with the given standard deviation.
    Normal(f64),
    /// Scaled by `1/sqrt(#terms)` — keeps output variance bounded as the
    /// spanning set grows (the layer analogue of Xavier initialisation).
    ScaledNormal,
}

/// Adjoint sign of a spanning diagram: `F(d)ᵀ = sign · F(dᵀ)`.
///
/// 1 for S_n / O(n) / Sp(n) and SO(n) Brauer diagrams; `(-1)^{s(n-s)}` for
/// SO(n) `(l+k)\n`-diagrams with `s` free top vertices.
pub fn transpose_sign(group: Group, d: &Diagram, n: usize) -> f64 {
    if group == Group::SpecialOrthogonal && !d.is_brauer() {
        let s = d.free_vertices().iter().filter(|&&v| v < d.l).count();
        if (s * (n - s)) % 2 == 1 {
            return -1.0;
        }
    }
    1.0
}

/// One spanning term: the diagram, its forward plan, its transposed plan
/// and the adjoint sign. Plans come from the global [`PlanCache`], so two
/// layers (or model replicas) over the same spanning set share the factored
/// form instead of re-running `Factor`.
#[derive(Debug, Clone)]
struct Term {
    diagram: Diagram,
    forward: Arc<MultPlan>,
    backward: Arc<MultPlan>,
    adjoint_sign: f64,
}

/// An equivariant linear layer `(R^n)^{⊗k} → (R^n)^{⊗l}` with learned
/// coefficients over the full spanning set, plus an equivariant bias
/// (spanning diagrams of `Hom((R^n)^{⊗0}, (R^n)^{⊗l})`).
#[derive(Debug, Clone)]
pub struct EquivariantLinear {
    group: Group,
    n: usize,
    k: usize,
    l: usize,
    terms: Vec<Term>,
    bias_terms: Vec<Term>,
    /// The folded execution schedule for the weight sum `Σ λ_d F(d)`: the
    /// per-term op chains canonicalised and hash-consed into a globally
    /// CSE'd DAG, terms folded into `(node, pattern)` scatter classes, all
    /// executed against a recycled scratch arena. The structure is
    /// weight-independent — λ coefficients are gathered from this layer's
    /// `coeffs` on every call — so it is shared across layer clones and,
    /// through [`PlanCache`], across every layer of the same shape.
    schedule: Arc<LayerSchedule>,
    /// Schedule over the term-wise transposed plans, for the backward pass.
    backward_schedule: Arc<LayerSchedule>,
    /// Learnable coefficient per weight diagram.
    pub coeffs: Vec<f64>,
    /// Learnable coefficient per bias diagram.
    pub bias_coeffs: Vec<f64>,
}

/// The spanning diagrams for `Hom_G((R^n)^{⊗k}, (R^n)^{⊗l})`.
pub(crate) fn spanning_diagrams(
    group: Group,
    n: usize,
    k: usize,
    l: usize,
) -> Result<Vec<Diagram>> {
    match group {
        Group::Symmetric => Ok(all_partition_diagrams(l, k, Some(n))),
        Group::Orthogonal => Ok(all_brauer_diagrams(l, k)),
        Group::Symplectic => {
            if n % 2 != 0 {
                return Err(Error::DimensionConstraint("Sp(n) needs even n".into()));
            }
            Ok(all_brauer_diagrams(l, k))
        }
        Group::SpecialOrthogonal => {
            let mut ds = all_brauer_diagrams(l, k);
            if l + k >= n && (l + k - n) % 2 == 0 {
                ds.extend(all_jellyfish_diagrams(l, k, n)?);
            }
            Ok(ds)
        }
    }
}

/// The spanning plans for `Hom_G((R^n)^{⊗k}, (R^n)^{⊗l})` in enumeration
/// order, built through the global [`PlanCache`]. This is the term order
/// every [`LayerSchedule`] compiled for this shape uses; exposed for the
/// schedule property tests and benches.
pub fn spanning_plans(group: Group, n: usize, k: usize, l: usize) -> Result<Vec<Arc<MultPlan>>> {
    let cache = PlanCache::global();
    spanning_diagrams(group, n, k, l)?
        .iter()
        .map(|d| cache.get_or_build(group, d, n))
        .collect()
}

impl EquivariantLinear {
    /// Build the layer with the full spanning set and the given
    /// initialisation.
    pub fn new(
        group: Group,
        n: usize,
        k: usize,
        l: usize,
        init: Init,
        rng: &mut Rng,
    ) -> Result<Self> {
        let weight_diagrams = spanning_diagrams(group, n, k, l)?;
        let bias_diagrams = spanning_diagrams(group, n, 0, l)?;
        let cache = PlanCache::global();
        let make_terms = |ds: Vec<Diagram>| -> Result<Vec<Term>> {
            ds.into_iter()
                .map(|d| {
                    let forward = cache.get_or_build(group, &d, n)?;
                    let dt = d.transpose();
                    let backward = cache.get_or_build(group, &dt, n)?;
                    let adjoint_sign = transpose_sign(group, &d, n);
                    Ok(Term {
                        diagram: d,
                        forward,
                        backward,
                        adjoint_sign,
                    })
                })
                .collect()
        };
        let terms = make_terms(weight_diagrams)?;
        let bias_terms = make_terms(bias_diagrams)?;
        let forward_plans: Vec<Arc<MultPlan>> = terms.iter().map(|t| t.forward.clone()).collect();
        let backward_plans: Vec<Arc<MultPlan>> =
            terms.iter().map(|t| t.backward.clone()).collect();
        let schedule = cache.get_or_build_schedule(group, n, k, l, false, &forward_plans)?;
        let backward_schedule =
            cache.get_or_build_schedule(group, n, k, l, true, &backward_plans)?;
        let draw = |count: usize, rng: &mut Rng| -> Vec<f64> {
            match init {
                Init::Zeros => vec![0.0; count],
                Init::Normal(sd) => (0..count).map(|_| sd * rng.gaussian()).collect(),
                Init::ScaledNormal => {
                    let sd = 1.0 / (count.max(1) as f64).sqrt();
                    (0..count).map(|_| sd * rng.gaussian()).collect()
                }
            }
        };
        let coeffs = draw(terms.len(), rng);
        let bias_coeffs = draw(bias_terms.len(), rng);
        Ok(EquivariantLinear {
            group,
            n,
            k,
            l,
            terms,
            bias_terms,
            schedule,
            backward_schedule,
            coeffs,
            bias_coeffs,
        })
    }

    /// Group of the layer.
    pub fn group(&self) -> Group {
        self.group
    }
    /// Representation dimension.
    pub fn n(&self) -> usize {
        self.n
    }
    /// Input order.
    pub fn k(&self) -> usize {
        self.k
    }
    /// Output order.
    pub fn l(&self) -> usize {
        self.l
    }
    /// Spanning diagrams of the weight.
    pub fn diagrams(&self) -> impl Iterator<Item = &Diagram> {
        self.terms.iter().map(|t| &t.diagram)
    }
    /// Number of learnable parameters.
    pub fn num_params(&self) -> usize {
        self.coeffs.len() + self.bias_coeffs.len()
    }

    /// Forward pass: `W v + bias` via the folded execution schedule — the
    /// whole diagram sum in one DAG walk, each distinct intermediate
    /// computed once (global CSE), permutes feeding contractions fused
    /// into strided gather kernels that never materialise the permuted
    /// intermediate, one multi-pattern scatter pass per `(node, pattern)`
    /// class with the λ-weights folded in, every index table precompiled
    /// into the schedule's kernel plan, and all scratch (tensor buffers
    /// *and* index scratch) drawn from the pooled arena — zero
    /// steady-state heap allocations. Matches
    /// [`EquivariantLinear::forward_per_term`] to ≤ 1e-12 (class folding
    /// reassociates the per-term additions); deterministic run to run.
    /// Generic over the scalar type: the `f64` instantiation is the
    /// historical path bit for bit, `f32` halves the bytes the walk moves.
    pub(crate) fn forward_one<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        self.forward_one_with(&self.schedule, v)
    }

    /// [`EquivariantLinear::forward_one`] through an explicit schedule
    /// instead of the layer's own `Arc` (which is fixed at construction).
    /// The schedule must have been compiled for this layer's shape — the
    /// integrity verifier uses this to re-verify freshly recompiled
    /// schedules after a quarantine, and the brownout uses it to route a
    /// layer through shrunken-tile-budget schedules without touching the
    /// layer.
    pub(crate) fn forward_one_with<S: Scalar>(
        &self,
        schedule: &LayerSchedule,
        v: &TensorOf<S>,
    ) -> Result<TensorOf<S>> {
        // Check the input up front (not per-term): a zero-initialised layer
        // skips every term, and the batched path must agree with this one
        // on malformed input.
        self.check_input(v)?;
        let mut out = TensorOf::zeros(self.n, self.l);
        let mut arena = PooledArenaOf::<S>::get();
        schedule.execute_tiled_parallel(v, &self.coeffs, &mut out, &mut arena)?;
        self.accumulate_bias(&mut out)?;
        Ok(out)
    }

    /// Unified forward entry point: accepts any [`BatchInput`] packaging —
    /// a single tensor, owned or borrowed slices, or an already-packed
    /// batch — and returns a [`BatchOutput`] of the matching shape. This
    /// subsumes the deprecated `forward`/`forward_batch`/
    /// `forward_batch_refs`/`forward_batched` quartet.
    pub fn apply<'a, S: Scalar>(
        &self,
        input: impl Into<BatchInput<'a, S>>,
    ) -> Result<BatchOutput<S>> {
        match input.into() {
            BatchInput::Single(v) => Ok(BatchOutput::Single(self.forward_one(v)?)),
            BatchInput::Slice(vs) => {
                let refs: Vec<&TensorOf<S>> = vs.iter().collect();
                Ok(BatchOutput::Batch(self.forward_refs_core(&refs)?))
            }
            BatchInput::Refs(vs) => Ok(BatchOutput::Batch(self.forward_refs_core(vs)?)),
            BatchInput::Packed(vb) => Ok(BatchOutput::Packed(self.forward_packed_core(vb)?)),
        }
    }

    /// Unified backward entry point, mirroring [`EquivariantLinear::apply`]:
    /// `input` and `grad_out` must use the same packaging. Parameter
    /// gradients are accumulated into `grads` (summed over the batch) and
    /// the input gradients come back shaped like the inputs.
    pub fn apply_grad<'a, S: Scalar>(
        &self,
        input: impl Into<BatchInput<'a, S>>,
        grad_out: impl Into<BatchInput<'a, S>>,
        grads: &mut LayerGrads,
    ) -> Result<BatchOutput<S>> {
        match (input.into(), grad_out.into()) {
            (BatchInput::Single(v), BatchInput::Single(g)) => {
                Ok(BatchOutput::Single(self.backward(v, g, grads)?))
            }
            (BatchInput::Slice(vs), BatchInput::Slice(gs)) => {
                Ok(BatchOutput::Batch(self.backward_batch(vs, gs, grads)?))
            }
            (BatchInput::Refs(vs), BatchInput::Refs(gs)) => {
                if vs.len() != gs.len() {
                    return Err(Error::ShapeMismatch {
                        expected: format!("{} upstream gradients", vs.len()),
                        got: format!("{}", gs.len()),
                    });
                }
                let vb = BatchTensorOf::pack_refs(vs)?;
                let gb = BatchTensorOf::pack_refs(gs)?;
                Ok(BatchOutput::Batch(
                    self.backward_batched(&vb, &gb, grads)?.unpack(),
                ))
            }
            (BatchInput::Packed(vb), BatchInput::Packed(gb)) => {
                Ok(BatchOutput::Packed(self.backward_batched(vb, gb, grads)?))
            }
            (v, g) => Err(Error::ShapeMismatch {
                expected: format!("gradient packaged like the input (`{}`)", v.kind()),
                got: format!("`{}`", g.kind()),
            }),
        }
    }

    /// Deprecated spelling of the single-tensor forward.
    #[deprecated(note = "use `apply` with a single tensor instead")]
    pub fn forward<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        self.forward_one(v)
    }

    /// Reference forward path: one `MultPlan::apply_accumulate` per
    /// spanning term, exactly as before schedule fusion (the §5 linearity
    /// observation, term by term). Kept for the equivalence property tests
    /// and the fused-vs-per-term benchmark; [`EquivariantLinear::forward`]
    /// matches it to ≤ 1e-12 (folded classes reassociate the additions).
    pub fn forward_per_term<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        self.check_input(v)?;
        let mut out = TensorOf::zeros(self.n, self.l);
        for (term, &lambda) in self.terms.iter().zip(&self.coeffs) {
            if lambda == 0.0 {
                continue;
            }
            term.forward.apply_accumulate(v, lambda, &mut out)?;
        }
        self.accumulate_bias(&mut out)?;
        Ok(out)
    }

    /// Shared closing bias accumulation (kept term-by-term: bias spanning
    /// sets are tiny and their "input" is the scalar 1).
    fn accumulate_bias<S: Scalar>(&self, out: &mut TensorOf<S>) -> Result<()> {
        if !self.bias_terms.is_empty() {
            let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
            for (term, &mu) in self.bias_terms.iter().zip(&self.bias_coeffs) {
                if mu == 0.0 {
                    continue;
                }
                term.forward.apply_accumulate(&one, mu, out)?;
            }
        }
        Ok(())
    }

    /// Batched forward pass: the fused batch-axis engine. Inputs are packed
    /// into contiguous `[B, n^k]` spans (one per worker thread) and each
    /// span runs [`LayerSchedule::execute_batch`] — **one schedule walk per
    /// span**, every DAG node evaluated for all its items before the walk
    /// moves on, index maps computed once per node, and the bias tensor
    /// materialised once per batch.
    ///
    /// Matches per-item [`EquivariantLinear::forward`] to rounding error
    /// (≤ 1e-12 in the property tests), **not** bit-exactly: the batch-
    /// shared bias (and, for single-item batches, subtree partial sums)
    /// change the accumulation order of the same terms.
    #[deprecated(note = "use `apply` with a slice of tensors instead")]
    pub fn forward_batch<S: Scalar>(&self, inputs: &[TensorOf<S>]) -> Result<Vec<TensorOf<S>>> {
        let refs: Vec<&TensorOf<S>> = inputs.iter().collect();
        self.forward_refs_core(&refs)
    }

    /// Deprecated spelling of the borrowed-batch forward.
    #[deprecated(note = "use `apply` with a slice of tensor refs instead")]
    pub fn forward_batch_refs<S: Scalar>(
        &self,
        inputs: &[&TensorOf<S>],
    ) -> Result<Vec<TensorOf<S>>> {
        self.forward_refs_core(inputs)
    }

    /// Batched forward over borrowed inputs (the coordinator batches
    /// tensors it does not own contiguously) — the worker-span fan-out
    /// described on the deprecated [`EquivariantLinear::forward_batch`].
    pub(crate) fn forward_refs_core<S: Scalar>(
        &self,
        inputs: &[&TensorOf<S>],
    ) -> Result<Vec<TensorOf<S>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        for v in inputs {
            self.check_input(v)?;
        }
        let bias = self.batch_bias::<S>()?;
        let workers = max_threads();
        // Single item: parallelise across independent schedule subtrees
        // instead, split by the cost model rather than evenly (the
        // DAG-level form of the old term-range fan-out). The clamp to ≥ 1
        // matters: a single-term layer has one subtree and must fall
        // through to the plain path, not compute with zero workers (the
        // old `terms / 2` heuristic hit exactly that).
        let tree_workers = workers.min(self.schedule.subtrees().len()).max(1);
        if inputs.len() == 1 && tree_workers > 1 {
            let mut out = self.forward_subtrees_parallel(inputs[0], tree_workers)?;
            if let Some(b) = &bias {
                out.axpy(1.0, b);
            }
            return Ok(vec![out]);
        }
        // One contiguous span per worker; each span is packed once and the
        // schedule walked once for all its items.
        let spans: Vec<&[&TensorOf<S>]> = inputs.chunks(span_len(inputs.len())).collect();
        let span_outs = parallel_map(&spans, spans.len(), |span| -> Result<Vec<TensorOf<S>>> {
            let vb = BatchTensorOf::pack_refs(span)?;
            let mut ob = BatchTensorOf::zeros(self.n, self.l, vb.batch());
            let mut arena = PooledArenaOf::<S>::get();
            self.schedule
                .execute_batch_tiled(&vb, &self.coeffs, &mut ob, &mut arena)?;
            if let Some(b) = &bias {
                ob.axpy_broadcast(1.0, b);
            }
            Ok(ob.unpack())
        });
        let mut out = Vec::with_capacity(inputs.len());
        for span in span_outs {
            out.extend(span?);
        }
        Ok(out)
    }

    /// Deprecated spelling of the packed-batch forward.
    #[deprecated(note = "use `apply` with a packed batch instead")]
    pub fn forward_batched<S: Scalar>(&self, v: &BatchTensorOf<S>) -> Result<BatchTensorOf<S>> {
        self.forward_packed_core(v)
    }

    /// Fused forward over an already-packed batch — the building block the
    /// network plumbing uses to keep activations batched between layers.
    /// One schedule walk for the whole batch, bias materialised once.
    pub(crate) fn forward_packed_core<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
    ) -> Result<BatchTensorOf<S>> {
        let bias = self.batch_bias::<S>()?;
        self.forward_batched_with_bias(v, bias.as_ref())
    }

    /// [`EquivariantLinear::forward_packed_core`] with the bias tensor
    /// supplied by the caller — the net-level span fan-out materialises
    /// each layer's bias once per batch and shares it across worker spans
    /// instead of rebuilding it per span.
    pub(crate) fn forward_batched_with_bias<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
        bias: Option<&TensorOf<S>>,
    ) -> Result<BatchTensorOf<S>> {
        let mut out = BatchTensorOf::zeros(self.n, self.l, v.batch());
        let mut arena = PooledArenaOf::<S>::get();
        self.schedule
            .execute_batch_tiled(v, &self.coeffs, &mut out, &mut arena)?;
        if let Some(b) = bias {
            out.axpy_broadcast(1.0, b);
        }
        Ok(out)
    }

    /// Batched backward pass over `(input, upstream gradient)` pairs:
    /// one transposed-schedule walk per worker span
    /// ([`LayerSchedule::execute_batch_map`]). Parameter gradients are
    /// accumulated into `grads` (summed over the batch, matching repeated
    /// [`EquivariantLinear::backward`] calls) and the per-item input
    /// gradients are returned in order.
    pub fn backward_batch<S: Scalar>(
        &self,
        inputs: &[TensorOf<S>],
        grad_outs: &[TensorOf<S>],
        grads: &mut LayerGrads,
    ) -> Result<Vec<TensorOf<S>>> {
        if inputs.len() != grad_outs.len() {
            return Err(Error::ShapeMismatch {
                expected: format!("{} upstream gradients", inputs.len()),
                got: format!("{}", grad_outs.len()),
            });
        }
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        // Single item: no batch axis to span — fan the *terms* out instead,
        // by cost-weighted partitions of the transposed schedule (the
        // backward mirror of the forward's subtree parallelism).
        let tree_workers = max_threads()
            .min(self.backward_schedule.subtrees().len())
            .max(1);
        if inputs.len() == 1 && tree_workers > 1 {
            let gv =
                self.backward_terms_parallel(&inputs[0], &grad_outs[0], grads, tree_workers)?;
            return Ok(vec![gv]);
        }
        let chunk = span_len(inputs.len());
        let spans: Vec<(&[TensorOf<S>], &[TensorOf<S>])> = inputs
            .chunks(chunk)
            .zip(grad_outs.chunks(chunk))
            .collect();
        let parts = parallel_map(
            &spans,
            spans.len(),
            |&(vs, gs)| -> Result<(BatchTensorOf<S>, LayerGrads)> {
                let mut local = self.zero_grads();
                let vb = BatchTensorOf::pack(vs)?;
                let gb = BatchTensorOf::pack(gs)?;
                let gv = self.backward_batched(&vb, &gb, &mut local)?;
                Ok((gv, local))
            },
        );
        let mut out = Vec::with_capacity(inputs.len());
        for part in parts {
            let (gv, local) = part?;
            for (a, b) in grads.coeffs.iter_mut().zip(&local.coeffs) {
                *a += b;
            }
            for (a, b) in grads.bias_coeffs.iter_mut().zip(&local.bias_coeffs) {
                *a += b;
            }
            out.extend(gv.unpack());
        }
        Ok(out)
    }

    /// Fused backward over already-packed batches: walks the transposed
    /// schedule **once for the whole batch**; per term, the batched tensor
    /// `F(dᵀ) g[·]` feeds both the coefficient gradients (one inner
    /// product per item) and the input gradients (a blocked axpy over
    /// `B · n^k` lanes). Gradients are summed over the batch.
    pub fn backward_batched<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
        g: &BatchTensorOf<S>,
        grads: &mut LayerGrads,
    ) -> Result<BatchTensorOf<S>> {
        if v.order() != self.k || v.n() != self.n || v.batch() != g.batch() {
            return Err(Error::ShapeMismatch {
                expected: format!(
                    "order {} input batch of {} over R^{}",
                    self.k,
                    g.batch(),
                    self.n
                ),
                got: format!(
                    "order {} batch of {} over R^{}",
                    v.order(),
                    v.batch(),
                    v.n()
                ),
            });
        }
        let batch = v.batch();
        let mut grad_v = BatchTensorOf::zeros(self.n, self.k, batch);
        let mut arena = PooledArenaOf::<S>::get();
        self.backward_schedule.execute_batch_map_tiled(g, &mut arena, |i, bt| {
            // bt = F(dᵀ) g for every item of the batch (a reused scratch
            // buffer).
            let sign = self.terms[i].adjoint_sign;
            let alpha = self.coeffs[i] * sign;
            let alpha_s = S::from_f64(alpha);
            let mut acc = S::ZERO;
            for b in 0..batch {
                let t = bt.item(b);
                // ∂L/∂λ_i += sign · Σ_b ⟨F(dᵀ) g_b, v_b⟩
                acc += t.iter().zip(v.item(b)).map(|(&a, &x)| a * x).sum::<S>();
                if alpha != 0.0 {
                    for (o, &tv) in grad_v.item_mut(b).iter_mut().zip(t) {
                        *o += alpha_s * tv;
                    }
                }
            }
            grads.coeffs[i] += sign * acc.to_f64();
            Ok(())
        })?;
        // Bias gradients: ∂L/∂μ_b = Σ_items ⟨g, F(b)(1)⟩ — the basis
        // tensor is materialised once per term for the whole batch.
        if !self.bias_terms.is_empty() {
            let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
            for (j, term) in self.bias_terms.iter().enumerate() {
                let basis = term.forward.apply(&one)?;
                let mut acc = S::ZERO;
                for b in 0..batch {
                    acc += basis
                        .data
                        .iter()
                        .zip(g.item(b))
                        .map(|(&a, &x)| a * x)
                        .sum::<S>();
                }
                grads.bias_coeffs[j] += acc.to_f64();
            }
        }
        Ok(grad_v)
    }

    /// Shape guard shared by the per-item and batched forward paths.
    fn check_input<S: Scalar>(&self, v: &TensorOf<S>) -> Result<()> {
        if v.order != self.k || v.n != self.n {
            return Err(Error::ShapeMismatch {
                expected: format!("order {} tensor over R^{}", self.k, self.n),
                got: format!("order {} over R^{}", v.order, v.n),
            });
        }
        Ok(())
    }

    /// Weight part of the forward pass split across `workers` threads by
    /// **cost-weighted** groups of schedule subtrees (the §5 parallelism-
    /// across-terms observation, lifted to the DAG: subtrees share no
    /// nodes, so each worker keeps full node reuse inside its slice with no
    /// shared mutable state). [`LayerSchedule::cost_partitions`] balances
    /// the cost-model work (LPT over subtree flops/bytes) instead of the
    /// old even chunking, so one dominant subtree no longer serialises a
    /// worker span; partial sums are reduced on the calling thread.
    fn forward_subtrees_parallel<S: Scalar>(
        &self,
        v: &TensorOf<S>,
        workers: usize,
    ) -> Result<TensorOf<S>> {
        self.check_input(v)?;
        let parts = self.schedule.cost_partitions(workers);
        let partials = parallel_map(&parts, parts.len(), |classes| -> Result<TensorOf<S>> {
            let mut partial = TensorOf::zeros(self.n, self.l);
            let mut arena = PooledArenaOf::<S>::get();
            self.schedule
                .execute_subset_tiled(v, &self.coeffs, classes, &mut partial, &mut arena)?;
            Ok(partial)
        });
        let mut out = TensorOf::zeros(self.n, self.l);
        for p in partials {
            out.axpy(1.0, &p?);
        }
        Ok(out)
    }

    /// Single-item backward fanned out across workers by cost-weighted
    /// term partitions of the transposed schedule
    /// ([`LayerSchedule::cost_term_partitions`]): each worker walks its own
    /// term set with its own pooled arena (full node reuse inside the
    /// partition), accumulating local coefficient gradients and a local
    /// input-gradient partial; both are reduced on the calling thread.
    fn backward_terms_parallel<S: Scalar>(
        &self,
        v: &TensorOf<S>,
        g: &TensorOf<S>,
        grads: &mut LayerGrads,
        workers: usize,
    ) -> Result<TensorOf<S>> {
        self.check_input(v)?;
        let parts = self.backward_schedule.cost_term_partitions(workers);
        let partials = parallel_map(
            &parts,
            parts.len(),
            |terms| -> Result<(TensorOf<S>, Vec<f64>)> {
                let mut local_gv = TensorOf::zeros(self.n, self.k);
                let mut local_coeffs = vec![0.0; self.coeffs.len()];
                let mut arena = PooledArenaOf::<S>::get();
                self.backward_schedule
                    .execute_map_subset_tiled(g, terms, &mut arena, |i, bt| {
                        let sign = self.terms[i].adjoint_sign;
                        local_coeffs[i] += sign * bt.dot(v);
                        let lambda = self.coeffs[i];
                        if lambda != 0.0 {
                            local_gv.axpy(lambda * sign, bt);
                        }
                        Ok(())
                    })?;
                Ok((local_gv, local_coeffs))
            },
        );
        let mut grad_v = TensorOf::zeros(self.n, self.k);
        for part in partials {
            let (gv, coeffs) = part?;
            grad_v.axpy(1.0, &gv);
            for (a, b) in grads.coeffs.iter_mut().zip(&coeffs) {
                *a += b;
            }
        }
        self.accumulate_bias_grads(g, grads)?;
        Ok(grad_v)
    }

    /// Bias-diagram gradients `∂L/∂μ_j = sign_j · ⟨F(bᵀ) g, 1⟩`,
    /// accumulated into `grads` — shared by the sequential and the
    /// term-parallel backward paths.
    fn accumulate_bias_grads<S: Scalar>(&self, g: &TensorOf<S>, grads: &mut LayerGrads) -> Result<()> {
        let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
        for (j, term) in self.bias_terms.iter().enumerate() {
            let bt = term.backward.apply(g)?; // order-0 scalar
            grads.bias_coeffs[j] += term.adjoint_sign * bt.dot(&one);
        }
        Ok(())
    }

    /// The batch-shared bias tensor `Σ μ_b F(b)(1)`, or `None` when the
    /// layer has no active bias term. Computed against the `f64` master
    /// coefficients and narrowed once per batch (`S = f64` is a value-
    /// preserving copy).
    pub(crate) fn batch_bias<S: Scalar>(&self) -> Result<Option<TensorOf<S>>> {
        if self.bias_terms.is_empty() || self.bias_coeffs.iter().all(|&m| m == 0.0) {
            return Ok(None);
        }
        Ok(Some(self.materialize_bias()?.cast::<S>()))
    }

    /// Backward pass. Given the upstream gradient `g = ∂L/∂out`, returns
    /// `∂L/∂v` and accumulates `∂L/∂λ`, `∂L/∂bias` into `grads`.
    ///
    /// `∂L/∂v = Σ λ_d · F(d)ᵀ g = Σ λ_d · sign(d) · F(dᵀ) g` and
    /// `∂L/∂λ_d = ⟨g, F(d) v⟩ = ⟨F(dᵀ) g · sign(d), v⟩` — both computed
    /// with the fast path only, through the transposed-term schedule so
    /// every `F(dᵀ) g` shares its `σ` permute and contraction prefix with
    /// its neighbours (and all scratch comes from the pooled arena).
    pub fn backward<S: Scalar>(
        &self,
        v: &TensorOf<S>,
        g: &TensorOf<S>,
        grads: &mut LayerGrads,
    ) -> Result<TensorOf<S>> {
        let mut grad_v = TensorOf::zeros(self.n, self.k);
        let mut arena = PooledArenaOf::<S>::get();
        self.backward_schedule.execute_map_tiled(g, &mut arena, |i, bt| {
            // bt = F(dᵀ) g for term i (a reused scratch buffer).
            let signed = self.terms[i].adjoint_sign;
            // ∂L/∂λ_i = sign · ⟨F(dᵀ) g, v⟩
            grads.coeffs[i] += signed * bt.dot(v);
            let lambda = self.coeffs[i];
            if lambda != 0.0 {
                grad_v.axpy(lambda * signed, bt);
            }
            Ok(())
        })?;
        self.accumulate_bias_grads(g, grads)?;
        Ok(grad_v)
    }

    /// Compile-time statistics of the fused forward schedule (prefix-
    /// sharing ratio, node counts, strided-fusion savings).
    pub fn schedule_stats(&self) -> ScheduleStats {
        self.schedule.stats()
    }

    /// The compiled forward schedule (shared through the global
    /// [`PlanCache`] with every layer of the same shape).
    pub fn schedule(&self) -> &Arc<LayerSchedule> {
        &self.schedule
    }

    /// Fresh zeroed gradient buffers for this layer.
    pub fn zero_grads(&self) -> LayerGrads {
        LayerGrads {
            coeffs: vec![0.0; self.coeffs.len()],
            bias_coeffs: vec![0.0; self.bias_coeffs.len()],
        }
    }

    /// Materialise the full weight matrix (naïve baseline, for tests and
    /// benchmark comparisons): `Σ λ_d F(d)` as an `n^l × n^k` matrix.
    pub fn materialize_weight(&self) -> Result<crate::linalg::Matrix> {
        let mut w = crate::linalg::Matrix::zeros(self.n.pow(self.l as u32), self.n.pow(self.k as u32));
        for (term, &lambda) in self.terms.iter().zip(&self.coeffs) {
            let m = crate::functor::materialize(self.group, &term.diagram, self.n)?;
            for (a, b) in w.data.iter_mut().zip(&m.data) {
                *a += lambda * b;
            }
        }
        Ok(w)
    }

    /// Materialise the bias vector.
    pub fn materialize_bias(&self) -> Result<Tensor> {
        let mut out = Tensor::zeros(self.n, self.l);
        let one = Tensor::from_vec(self.n, 0, vec![1.0])?;
        for (term, &mu) in self.bias_terms.iter().zip(&self.bias_coeffs) {
            let t = term.forward.apply(&one)?;
            out.axpy(mu, &t);
        }
        Ok(out)
    }
}

/// Gradient buffers for one layer.
#[derive(Debug, Clone)]
pub struct LayerGrads {
    /// `∂L/∂λ` per weight diagram.
    pub coeffs: Vec<f64>,
    /// `∂L/∂bias` per bias diagram.
    pub bias_coeffs: Vec<f64>,
}

#[cfg(test)]
mod tests {
    // Coverage of the legacy names — the deprecated wrappers must keep
    // working until downstream callers migrate to `apply`.
    #![allow(deprecated)]

    use super::*;
    use crate::functor::materialize;
    use crate::groups;

    /// Adjoint identity: F(d)ᵀ == sign · F(dᵀ) as matrices, all groups.
    #[test]
    fn transpose_identity_all_groups() {
        let mut rng = Rng::new(71);
        let cases: Vec<(Group, usize, Diagram)> = {
            let mut v = Vec::new();
            for _ in 0..20 {
                let l = rng.below(3);
                let k = rng.below(3);
                v.push((Group::Symmetric, 2, Diagram::random_partition(l, k, &mut rng)));
            }
            for _ in 0..20 {
                let l = rng.below(3);
                let k = 4 - l.min(3); // keep l+k even-ish; skip invalid below
                if (l + k) % 2 == 0 {
                    if let Ok(d) = Diagram::random_brauer(l, k, &mut rng) {
                        v.push((Group::Orthogonal, 3, d.clone()));
                        v.push((Group::Symplectic, 2, d));
                    }
                }
            }
            let n = 3;
            for (l, k) in [(2usize, 1usize), (1, 2), (2, 3), (3, 2)] {
                if l + k >= n && (l + k - n) % 2 == 0 {
                    let d = Diagram::random_jellyfish(l, k, n, &mut rng).unwrap();
                    v.push((Group::SpecialOrthogonal, n, d));
                }
            }
            v
        };
        for (group, n, d) in cases {
            let m = materialize(group, &d, n).unwrap();
            let mt = materialize(group, &d.transpose(), n).unwrap();
            let sign = transpose_sign(group, &d, n);
            let direct = m.transpose();
            let mut scaled = mt.clone();
            for x in &mut scaled.data {
                *x *= sign;
            }
            assert!(
                direct.max_abs_diff(&scaled) < 1e-12,
                "group {group}, diagram {d}: adjoint sign wrong"
            );
        }
    }

    /// The layer equals its materialised weight matrix.
    #[test]
    fn forward_matches_materialized() {
        let mut rng = Rng::new(72);
        for group in [Group::Symmetric, Group::Orthogonal, Group::Symplectic] {
            let n = if group == Group::Symplectic { 4 } else { 3 };
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 2, &mut rng);
            let got = layer.forward(&v).unwrap();
            let w = layer.materialize_weight().unwrap();
            let bias = layer.materialize_bias().unwrap();
            let mv = w.matvec(&v.data).unwrap();
            let want: Vec<f64> = mv.iter().zip(&bias.data).map(|(a, b)| a + b).collect();
            for (a, b) in got.data.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "group {group}");
            }
        }
    }

    /// Layer is equivariant: forward(ρ_k(g) v) == ρ_l(g) forward(v).
    #[test]
    fn layer_equivariance() {
        let mut rng = Rng::new(73);
        for group in [
            Group::Symmetric,
            Group::Orthogonal,
            Group::SpecialOrthogonal,
            Group::Symplectic,
        ] {
            let n = if group == Group::Symplectic { 4 } else { 3 };
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 2, &mut rng);
            let g = groups::sample(group, n, &mut rng).unwrap();
            let lhs = layer.forward(&groups::rho(&g, &v)).unwrap();
            let rhs = groups::rho(&g, &layer.forward(&v).unwrap());
            assert!(
                lhs.allclose(&rhs, 1e-7),
                "group {group}: equivariance violated, diff {}",
                lhs.max_abs_diff(&rhs)
            );
        }
    }

    /// Gradient check against finite differences (coefficients and input).
    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Rng::new(74);
        let n = 2;
        let layer =
            EquivariantLinear::new(Group::Symmetric, n, 2, 1, Init::Normal(0.4), &mut rng)
                .unwrap();
        let v = Tensor::random(n, 2, &mut rng);
        // Loss L = 0.5 ||forward(v)||².
        let out = layer.forward(&v).unwrap();
        let g = out.clone(); // dL/dout = out
        let mut grads = layer.zero_grads();
        let grad_v = layer.backward(&v, &g, &mut grads).unwrap();
        let loss = |layer: &EquivariantLinear, v: &Tensor| -> f64 {
            let o = layer.forward(v).unwrap();
            0.5 * o.data.iter().map(|x| x * x).sum::<f64>()
        };
        let eps = 1e-6;
        // Coefficient gradients.
        for i in 0..layer.coeffs.len() {
            let mut lp = layer.clone();
            lp.coeffs[i] += eps;
            let mut lm = layer.clone();
            lm.coeffs[i] -= eps;
            let fd = (loss(&lp, &v) - loss(&lm, &v)) / (2.0 * eps);
            assert!(
                (fd - grads.coeffs[i]).abs() < 1e-5,
                "coeff {i}: fd {fd} vs {0}",
                grads.coeffs[i]
            );
        }
        // Bias gradients.
        for j in 0..layer.bias_coeffs.len() {
            let mut lp = layer.clone();
            lp.bias_coeffs[j] += eps;
            let mut lm = layer.clone();
            lm.bias_coeffs[j] -= eps;
            let fd = (loss(&lp, &v) - loss(&lm, &v)) / (2.0 * eps);
            assert!(
                (fd - grads.bias_coeffs[j]).abs() < 1e-5,
                "bias {j}: fd {fd} vs {0}",
                grads.bias_coeffs[j]
            );
        }
        // Input gradient.
        for f in 0..v.len() {
            let mut vp = v.clone();
            vp.data[f] += eps;
            let mut vm = v.clone();
            vm.data[f] -= eps;
            let fd = (loss(&layer, &vp) - loss(&layer, &vm)) / (2.0 * eps);
            assert!(
                (fd - grad_v.data[f]).abs() < 1e-5,
                "input {f}: fd {fd} vs {0}",
                grad_v.data[f]
            );
        }
    }

    #[test]
    fn forward_batch_matches_per_item_forward() {
        let mut rng = Rng::new(77);
        for group in [
            Group::Symmetric,
            Group::Orthogonal,
            Group::SpecialOrthogonal,
            Group::Symplectic,
        ] {
            let n = if group == Group::Symplectic { 4 } else { 3 };
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let inputs: Vec<Tensor> = (0..7).map(|_| Tensor::random(n, 2, &mut rng)).collect();
            let batched = layer.forward_batch(&inputs).unwrap();
            assert_eq!(batched.len(), inputs.len());
            for (v, b) in inputs.iter().zip(&batched) {
                let seq = layer.forward(v).unwrap();
                assert!(
                    seq.allclose(b, 1e-9),
                    "group {group}: batch diverges by {}",
                    seq.max_abs_diff(b)
                );
            }
        }
    }

    #[test]
    fn forward_batch_single_item_uses_term_parallel_path() {
        let mut rng = Rng::new(78);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Normal(0.5), &mut rng)
                .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let batched = layer.forward_batch(std::slice::from_ref(&v)).unwrap();
        let seq = layer.forward(&v).unwrap();
        assert_eq!(batched.len(), 1);
        assert!(seq.allclose(&batched[0], 1e-9));
    }

    #[test]
    fn forward_matches_per_term_reference() {
        let mut rng = Rng::new(82);
        for group in [
            Group::Symmetric,
            Group::Orthogonal,
            Group::SpecialOrthogonal,
            Group::Symplectic,
        ] {
            let n = if group == Group::Symplectic { 4 } else { 3 };
            let layer =
                EquivariantLinear::new(group, n, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(n, 2, &mut rng);
            let fused = layer.forward(&v).unwrap();
            let reference = layer.forward_per_term(&v).unwrap();
            // ≤ 1e-12, not bitwise: the folded classes reassociate the
            // per-term additions into each output element.
            assert!(
                fused.allclose(&reference, 1e-12),
                "group {group}: folded forward diverges by {}",
                fused.max_abs_diff(&reference)
            );
            // …but the folded path itself is run-to-run bitwise stable.
            let again = layer.forward(&v).unwrap();
            assert!(fused.allclose(&again, 0.0), "group {group}: unstable");
        }
    }

    #[test]
    fn schedule_stats_report_folding() {
        let mut rng = Rng::new(85);
        let layer =
            EquivariantLinear::new(Group::Orthogonal, 4, 3, 3, Init::Normal(0.5), &mut rng)
                .unwrap();
        let stats = layer.schedule_stats();
        assert_eq!(stats.terms, layer.coeffs.len());
        assert!(stats.classes < stats.terms, "expected λ-folding: {stats:?}");
        assert!(stats.executed_ops() < stats.executed_ops_prefix());
        assert!(stats.estimated_flops > 0 && stats.estimated_bytes > 0);
    }

    #[test]
    fn single_term_layer_batch_of_one() {
        // Regression: O(n) at (k, l) = (1, 1) has exactly one spanning
        // diagram; the old single-item fan-out heuristic (`terms / 2`)
        // computed zero term-workers for it. The batch-of-one path must
        // both run and agree with the plain forward.
        let mut rng = Rng::new(83);
        let layer =
            EquivariantLinear::new(Group::Orthogonal, 3, 1, 1, Init::Normal(0.5), &mut rng)
                .unwrap();
        assert_eq!(layer.coeffs.len(), 1, "test premise: single-term layer");
        let v = Tensor::random(3, 1, &mut rng);
        let batched = layer.forward_batch(std::slice::from_ref(&v)).unwrap();
        assert_eq!(batched.len(), 1);
        let seq = layer.forward(&v).unwrap();
        assert!(seq.allclose(&batched[0], 1e-12));
    }

    #[test]
    fn layers_share_schedules_through_the_global_cache() {
        let mut rng = Rng::new(84);
        let a = EquivariantLinear::new(Group::Symmetric, 5, 2, 2, Init::Zeros, &mut rng).unwrap();
        let b = EquivariantLinear::new(Group::Symmetric, 5, 2, 2, Init::Zeros, &mut rng).unwrap();
        assert!(
            Arc::ptr_eq(a.schedule(), b.schedule()),
            "same-shape layers must share one compiled schedule"
        );
        let stats = a.schedule_stats();
        assert_eq!(stats.terms, a.coeffs.len());
    }

    #[test]
    fn forward_batch_empty_and_bad_shapes() {
        let mut rng = Rng::new(79);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Normal(0.5), &mut rng)
                .unwrap();
        assert!(layer.forward_batch(&[]).unwrap().is_empty());
        let bad = vec![Tensor::zeros(3, 1)];
        assert!(layer.forward_batch(&bad).is_err());
    }

    #[test]
    fn backward_batch_matches_sequential_backward() {
        let mut rng = Rng::new(80);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 2, 2, 1, Init::Normal(0.4), &mut rng)
                .unwrap();
        let inputs: Vec<Tensor> = (0..5).map(|_| Tensor::random(2, 2, &mut rng)).collect();
        let gs: Vec<Tensor> = (0..5).map(|_| Tensor::random(2, 1, &mut rng)).collect();
        // Sequential reference.
        let mut want_grads = layer.zero_grads();
        let mut want_gv = Vec::new();
        for (v, g) in inputs.iter().zip(&gs) {
            want_gv.push(layer.backward(v, g, &mut want_grads).unwrap());
        }
        // Batched.
        let mut got_grads = layer.zero_grads();
        let got_gv = layer.backward_batch(&inputs, &gs, &mut got_grads).unwrap();
        for (a, b) in want_gv.iter().zip(&got_gv) {
            assert!(a.allclose(b, 1e-9));
        }
        for (a, b) in want_grads.coeffs.iter().zip(&got_grads.coeffs) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        for (a, b) in want_grads.bias_coeffs.iter().zip(&got_grads.bias_coeffs) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        // Length mismatch is rejected.
        assert!(layer
            .backward_batch(&inputs, &gs[..3], &mut layer.zero_grads())
            .is_err());
    }

    #[test]
    fn layers_share_plans_through_the_global_cache() {
        // Two layers over the same spanning set must hold the *same*
        // factored plans (checked by Arc identity — immune to the counter
        // races other tests cause on the shared global cache).
        let mut rng = Rng::new(81);
        let a = EquivariantLinear::new(Group::Symmetric, 5, 2, 2, Init::Zeros, &mut rng).unwrap();
        let b = EquivariantLinear::new(Group::Symmetric, 5, 2, 2, Init::Zeros, &mut rng).unwrap();
        assert_eq!(a.terms.len(), b.terms.len());
        for (ta, tb) in a.terms.iter().zip(&b.terms) {
            assert!(
                Arc::ptr_eq(&ta.forward, &tb.forward),
                "forward plan for {} was re-factored",
                ta.diagram
            );
            assert!(Arc::ptr_eq(&ta.backward, &tb.backward));
        }
    }

    #[test]
    fn spanning_set_sizes_match_theory() {
        // S_n basis size = B(l+k, n); Brauer = (l+k-1)!!.
        use crate::diagram::{bell_bounded, double_factorial};
        let mut rng = Rng::new(75);
        let l = EquivariantLinear::new(Group::Symmetric, 2, 2, 2, Init::Zeros, &mut rng).unwrap();
        assert_eq!(l.coeffs.len() as u128, bell_bounded(4, 2));
        let o = EquivariantLinear::new(Group::Orthogonal, 3, 2, 2, Init::Zeros, &mut rng).unwrap();
        assert_eq!(o.coeffs.len() as u128, double_factorial(3));
        // Odd l+k for O(n): no weight diagrams at all.
        let o2 =
            EquivariantLinear::new(Group::Orthogonal, 3, 2, 1, Init::Zeros, &mut rng).unwrap();
        assert_eq!(o2.coeffs.len(), 0);
    }

    #[test]
    fn zero_init_gives_zero_output() {
        let mut rng = Rng::new(76);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Zeros, &mut rng).unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let out = layer.forward(&v).unwrap();
        assert_eq!(out.norm(), 0.0);
    }

    #[test]
    fn apply_matches_legacy_entry_points() {
        use crate::tensor::BatchTensor;
        let mut rng = Rng::new(86);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Normal(0.5), &mut rng)
                .unwrap();
        let inputs: Vec<Tensor> = (0..4).map(|_| Tensor::random(3, 2, &mut rng)).collect();
        // Single packaging == legacy forward, bitwise.
        let single = layer.apply(&inputs[0]).unwrap().into_single().unwrap();
        assert!(single.allclose(&layer.forward(&inputs[0]).unwrap(), 0.0));
        // Slice and refs packagings == legacy forward_batch, bitwise.
        let legacy = layer.forward_batch(&inputs).unwrap();
        let slice_out = layer.apply(inputs.as_slice()).unwrap().into_vec();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let refs_out = layer.apply(refs.as_slice()).unwrap().into_vec();
        for (want, (a, b)) in legacy.iter().zip(slice_out.iter().zip(&refs_out)) {
            assert!(a.allclose(want, 0.0));
            assert!(b.allclose(want, 0.0));
        }
        // Packed packaging == legacy forward_batched, bitwise.
        let packed = BatchTensor::pack(&inputs).unwrap();
        let packed_out = layer.apply(&packed).unwrap().into_packed().unwrap();
        let legacy_packed = layer.forward_batched(&packed).unwrap();
        assert_eq!(packed_out.max_abs_diff(&legacy_packed), 0.0);
    }

    #[test]
    fn apply_grad_matches_backward_batch() {
        let mut rng = Rng::new(87);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 2, 2, 1, Init::Normal(0.4), &mut rng)
                .unwrap();
        let inputs: Vec<Tensor> = (0..4).map(|_| Tensor::random(2, 2, &mut rng)).collect();
        let gs: Vec<Tensor> = (0..4).map(|_| Tensor::random(2, 1, &mut rng)).collect();
        let mut got_grads = layer.zero_grads();
        let got = layer
            .apply_grad(inputs.as_slice(), gs.as_slice(), &mut got_grads)
            .unwrap()
            .into_vec();
        let mut want_grads = layer.zero_grads();
        let want = layer.backward_batch(&inputs, &gs, &mut want_grads).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!(a.allclose(b, 0.0));
        }
        assert_eq!(got_grads.coeffs, want_grads.coeffs);
        assert_eq!(got_grads.bias_coeffs, want_grads.bias_coeffs);
        // Mismatched packagings are rejected.
        assert!(layer
            .apply_grad(&inputs[0], gs.as_slice(), &mut layer.zero_grads())
            .is_err());
    }

    #[test]
    fn f32_forward_tracks_f64_within_tolerance() {
        let mut rng = Rng::new(88);
        for group in [Group::Symmetric, Group::Orthogonal] {
            let layer =
                EquivariantLinear::new(group, 3, 2, 2, Init::Normal(0.5), &mut rng).unwrap();
            let v = Tensor::random(3, 2, &mut rng);
            let want = layer.apply(&v).unwrap().into_single().unwrap();
            let v32 = v.cast::<f32>();
            let got = layer.apply(&v32).unwrap().into_single().unwrap();
            let scale = want.data.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
            let tol = 64.0 * <f32 as Scalar>::TOLERANCE * scale;
            assert!(
                got.cast::<f64>().allclose(&want, tol),
                "group {group}: f32 diverges by {}",
                got.cast::<f64>().max_abs_diff(&want)
            );
        }
    }
}
