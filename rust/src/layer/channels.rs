//! Multi-channel equivariant linear layers.
//!
//! Practical equivariant networks (Maron et al. 2019 and descendants) use
//! feature channels: the layer maps
//! `(R^n)^{⊗k} ⊗ R^{c_in} → (R^n)^{⊗l} ⊗ R^{c_out}` and equivariance
//! constrains only the tensor-power part, so the weight is one learned
//! `c_out × c_in` matrix **per spanning diagram**:
//!
//! `out[o] = Σ_d F(d) · ( Σ_i λ_d[o, i] · in[i] )  +  bias`.
//!
//! The implementation exploits linearity the other way round —
//! `Σ_i Σ_d λ_d[o,i] · F(d)(in[i])` — so each input channel makes a single
//! pass over the layer's folded [`LayerSchedule`]
//! ([`LayerSchedule::execute_multi`]) feeding every output channel at once:
//! the interior diagram work (permutes, contractions) runs `c_in` times per
//! forward, and per output channel only the folded per-*class* scatter
//! passes repeat — terms differing only in their closing `σ_l` fold into
//! one multi-pattern pass with the per-channel λ-weights gathered on the
//! fly.

use super::input::{ChannelBatchInput, ChannelBatchOutput};
use super::linear::spanning_diagrams;
use crate::diagram::Diagram;
use crate::error::{Error, Result};
use crate::fastmult::{Group, LayerSchedule, MultPlan, PlanCache, PooledArenaOf, ScheduleStats};
use crate::tensor::{BatchTensorOf, Scalar, TensorOf};
use crate::util::Rng;
use std::sync::Arc;

/// One spanning term with its per-channel coefficient matrix. Plans are
/// shared through the global [`PlanCache`].
#[derive(Debug, Clone)]
struct ChannelTerm {
    #[allow(dead_code)]
    diagram: Diagram,
    forward: Arc<MultPlan>,
    backward: Arc<MultPlan>,
    adjoint_sign: f64,
    /// `c_out × c_in`, row-major.
    weights: Vec<f64>,
}

/// A multi-channel equivariant linear layer.
#[derive(Debug, Clone)]
pub struct ChannelEquivariantLinear {
    group: Group,
    n: usize,
    k: usize,
    l: usize,
    c_in: usize,
    c_out: usize,
    terms: Vec<ChannelTerm>,
    /// Per-bias-diagram, per-output-channel coefficients (`c_out` each).
    bias_terms: Vec<(Arc<MultPlan>, Vec<f64>)>,
    /// Fused execution schedule over the spanning terms (shared with every
    /// same-shape layer through the global [`PlanCache`]).
    schedule: Arc<LayerSchedule>,
    /// Schedule over the transposed plans, for the backward pass.
    backward_schedule: Arc<LayerSchedule>,
}

impl ChannelEquivariantLinear {
    /// Build with the full spanning set; weights iid normal scaled by
    /// `1/sqrt(#diagrams · c_in)`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        group: Group,
        n: usize,
        k: usize,
        l: usize,
        c_in: usize,
        c_out: usize,
        rng: &mut Rng,
    ) -> Result<Self> {
        assert!(c_in >= 1 && c_out >= 1);
        let cache = PlanCache::global();
        let diagrams = spanning_diagrams(group, n, k, l)?;
        let scale = 1.0 / ((diagrams.len().max(1) * c_in) as f64).sqrt();
        let mut terms = Vec::with_capacity(diagrams.len());
        for d in diagrams {
            let forward = cache.get_or_build(group, &d, n)?;
            let backward = cache.get_or_build(group, &d.transpose(), n)?;
            let adjoint_sign = super::linear::transpose_sign(group, &d, n);
            let weights = (0..c_out * c_in).map(|_| scale * rng.gaussian()).collect();
            terms.push(ChannelTerm {
                diagram: d,
                forward,
                backward,
                adjoint_sign,
                weights,
            });
        }
        let bias_diagrams = spanning_diagrams(group, n, 0, l)?;
        let mut bias_terms = Vec::with_capacity(bias_diagrams.len());
        for d in bias_diagrams {
            let plan = cache.get_or_build(group, &d, n)?;
            bias_terms.push((plan, vec![0.0; c_out]));
        }
        let forward_plans: Vec<Arc<MultPlan>> = terms.iter().map(|t| t.forward.clone()).collect();
        let backward_plans: Vec<Arc<MultPlan>> =
            terms.iter().map(|t| t.backward.clone()).collect();
        let schedule = cache.get_or_build_schedule(group, n, k, l, false, &forward_plans)?;
        let backward_schedule =
            cache.get_or_build_schedule(group, n, k, l, true, &backward_plans)?;
        Ok(ChannelEquivariantLinear {
            group,
            n,
            k,
            l,
            c_in,
            c_out,
            terms,
            bias_terms,
            schedule,
            backward_schedule,
        })
    }

    /// Input channel count.
    pub fn c_in(&self) -> usize {
        self.c_in
    }
    /// Output channel count.
    pub fn c_out(&self) -> usize {
        self.c_out
    }
    /// Total learnable parameters.
    pub fn num_params(&self) -> usize {
        self.terms.len() * self.c_out * self.c_in + self.bias_terms.len() * self.c_out
    }
    /// The group.
    pub fn group(&self) -> Group {
        self.group
    }

    /// Compile-time statistics of the shared forward schedule (CSE node
    /// counts, folded classes, strided-fusion savings) — the channel-layer
    /// twin of [`super::EquivariantLinear::schedule_stats`].
    pub fn schedule_stats(&self) -> ScheduleStats {
        self.schedule.stats()
    }

    fn check_channels<S: Scalar>(&self, x: &[TensorOf<S>]) -> Result<()> {
        if x.len() != self.c_in {
            return Err(Error::ShapeMismatch {
                expected: format!("{} input channels", self.c_in),
                got: format!("{}", x.len()),
            });
        }
        for t in x {
            if t.order != self.k || t.n != self.n {
                return Err(Error::ShapeMismatch {
                    expected: format!("order-{} tensors over R^{}", self.k, self.n),
                    got: format!("order {} over R^{}", t.order, t.n),
                });
            }
        }
        Ok(())
    }

    /// Unified forward entry point: accepts one multi-channel item
    /// (`&[TensorOf<S>]`) or a batch of them (`&[Vec<TensorOf<S>>]`) via
    /// [`ChannelBatchInput`] and returns a [`ChannelBatchOutput`] shaped
    /// like the input. Replaces the `forward`/`forward_batch` pair.
    pub fn apply<'a, S: Scalar>(
        &self,
        input: impl Into<ChannelBatchInput<'a, S>>,
    ) -> Result<ChannelBatchOutput<S>> {
        match input.into() {
            ChannelBatchInput::Single(x) => {
                Ok(ChannelBatchOutput::Single(self.forward_channels_core(x)?))
            }
            ChannelBatchInput::Batch(x) => {
                Ok(ChannelBatchOutput::Batch(self.forward_batch_core(x)?))
            }
        }
    }

    /// Unified backward entry point: `input` and `grad_out` must use the
    /// same packaging ([`ChannelBatchInput::Single`] with `Single`, `Batch`
    /// with `Batch`). Accumulates parameter gradients into `grads` and
    /// returns `∂L/∂x` shaped like the input.
    pub fn apply_grad<'a, S: Scalar>(
        &self,
        input: impl Into<ChannelBatchInput<'a, S>>,
        grad_out: impl Into<ChannelBatchInput<'a, S>>,
        grads: &mut ChannelGrads,
    ) -> Result<ChannelBatchOutput<S>> {
        match (input.into(), grad_out.into()) {
            (ChannelBatchInput::Single(x), ChannelBatchInput::Single(g)) => {
                Ok(ChannelBatchOutput::Single(self.backward(x, g, grads)?))
            }
            (ChannelBatchInput::Batch(x), ChannelBatchInput::Batch(g)) => {
                Ok(ChannelBatchOutput::Batch(self.backward_batch(x, g, grads)?))
            }
            (v, g) => Err(Error::ShapeMismatch {
                expected: format!("gradient packaged like the input (`{}`)", v.kind()),
                got: format!("`{}`", g.kind()),
            }),
        }
    }

    /// Forward one item. Use [`Self::apply`] instead.
    #[deprecated(note = "use `apply` with a single multi-channel item instead")]
    pub fn forward<S: Scalar>(&self, x: &[TensorOf<S>]) -> Result<Vec<TensorOf<S>>> {
        self.forward_channels_core(x)
    }

    /// Forward a batch. Use [`Self::apply`] instead.
    #[deprecated(note = "use `apply` with a batch of multi-channel items instead")]
    pub fn forward_batch<S: Scalar>(
        &self,
        x: &[Vec<TensorOf<S>>],
    ) -> Result<Vec<Vec<TensorOf<S>>>> {
        self.forward_batch_core(x)
    }

    /// Forward: `out[o] = Σ_d F(d)(Σ_i λ_d[o,i] x[i]) + Σ_b μ_b[o] F(b)(1)`,
    /// computed by linearity as `Σ_i Σ_d λ_d[o,i] · F(d)(x[i])`: each input
    /// channel makes **one** pass over the fused schedule feeding every
    /// output channel at once ([`LayerSchedule::execute_multi`]), so
    /// interior DAG work (permutes, contractions) runs `c_in` times per
    /// forward — not `#diagrams · c_out` times as the old mix-then-apply
    /// loop did — and only the cheap diagonal-support scatters repeat per
    /// output channel.
    pub(crate) fn forward_channels_core<S: Scalar>(
        &self,
        x: &[TensorOf<S>],
    ) -> Result<Vec<TensorOf<S>>> {
        self.check_channels(x)?;
        let mut out: Vec<TensorOf<S>> = (0..self.c_out)
            .map(|_| TensorOf::zeros(self.n, self.l))
            .collect();
        let mut arena = PooledArenaOf::<S>::get();
        let mut rows: Vec<Vec<f64>> = vec![vec![0.0; self.terms.len()]; self.c_out];
        for (i, x_t) in x.iter().enumerate() {
            for (o, row) in rows.iter_mut().enumerate() {
                for (slot, term) in row.iter_mut().zip(&self.terms) {
                    *slot = term.weights[o * self.c_in + i];
                }
            }
            self.schedule
                .execute_multi_tiled(x_t, &rows, &mut out, &mut arena)?;
        }
        let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
        for (plan, mus) in &self.bias_terms {
            for (o, out_t) in out.iter_mut().enumerate() {
                if mus[o] != 0.0 {
                    plan.apply_accumulate(&one, mus[o], out_t)?;
                }
            }
        }
        Ok(out)
    }

    /// Batched forward: one batch item is a `c_in`-channel input, the
    /// whole batch is packed **per channel** into `[B, n^k]` tensors and
    /// each input channel makes a single pass over the fused schedule for
    /// the entire batch ([`LayerSchedule::execute_batch_multi`]): interior
    /// DAG work runs `c_in` times per batch — not `c_in · B` times — with
    /// index maps shared across items, and only the cheap diagonal-support
    /// scatters repeat per output channel. Returns `B` items of `c_out`
    /// channels each.
    pub(crate) fn forward_batch_core<S: Scalar>(
        &self,
        x: &[Vec<TensorOf<S>>],
    ) -> Result<Vec<Vec<TensorOf<S>>>> {
        if x.is_empty() {
            return Ok(Vec::new());
        }
        for item in x {
            self.check_channels(item)?;
        }
        let batch = x.len();
        let mut outs: Vec<BatchTensorOf<S>> = (0..self.c_out)
            .map(|_| BatchTensorOf::zeros(self.n, self.l, batch))
            .collect();
        let mut arena = PooledArenaOf::<S>::get();
        let mut rows: Vec<Vec<f64>> = vec![vec![0.0; self.terms.len()]; self.c_out];
        for i in 0..self.c_in {
            let channel: Vec<&TensorOf<S>> = x.iter().map(|item| &item[i]).collect();
            let xb = BatchTensorOf::pack_refs(&channel)?;
            for (o, row) in rows.iter_mut().enumerate() {
                for (slot, term) in row.iter_mut().zip(&self.terms) {
                    *slot = term.weights[o * self.c_in + i];
                }
            }
            self.schedule
                .execute_batch_multi_tiled(&xb, &rows, &mut outs, &mut arena)?;
        }
        // Bias: each basis tensor F(b)(1) is materialised once per batch
        // and broadcast-added to every item.
        let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
        for (plan, mus) in &self.bias_terms {
            if mus.iter().all(|&m| m == 0.0) {
                continue;
            }
            let basis = plan.apply(&one)?;
            for (o, out) in outs.iter_mut().enumerate() {
                if mus[o] != 0.0 {
                    out.axpy_broadcast(mus[o], &basis);
                }
            }
        }
        // outs is channel-major (c_out × B); transpose back to item-major.
        let mut per_item: Vec<Vec<TensorOf<S>>> = (0..batch)
            .map(|_| Vec::with_capacity(self.c_out))
            .collect();
        for out in outs {
            for (b, t) in out.unpack().into_iter().enumerate() {
                per_item[b].push(t);
            }
        }
        Ok(per_item)
    }

    /// Batched backward: per output channel, the upstream gradients are
    /// packed into one `[B, n^l]` batch and the transposed schedule walked
    /// **once for the whole batch** ([`LayerSchedule::execute_batch_map`]);
    /// parameter gradients are summed over the batch (matching repeated
    /// [`ChannelEquivariantLinear::backward`] calls) and the per-item
    /// input gradients are returned in order.
    pub fn backward_batch<S: Scalar>(
        &self,
        x: &[Vec<TensorOf<S>>],
        grad_out: &[Vec<TensorOf<S>>],
        grads: &mut ChannelGrads,
    ) -> Result<Vec<Vec<TensorOf<S>>>> {
        if x.len() != grad_out.len() {
            return Err(Error::ShapeMismatch {
                expected: format!("{} upstream gradients", x.len()),
                got: format!("{}", grad_out.len()),
            });
        }
        if x.is_empty() {
            return Ok(Vec::new());
        }
        for item in x {
            self.check_channels(item)?;
        }
        for gitem in grad_out {
            if gitem.len() != self.c_out {
                return Err(Error::ShapeMismatch {
                    expected: format!("{} gradient channels", self.c_out),
                    got: format!("{}", gitem.len()),
                });
            }
        }
        let batch = x.len();
        let mut grad_x: Vec<Vec<TensorOf<S>>> = (0..batch)
            .map(|_| {
                (0..self.c_in)
                    .map(|_| TensorOf::zeros(self.n, self.k))
                    .collect()
            })
            .collect();
        let mut arena = PooledArenaOf::<S>::get();
        for o in 0..self.c_out {
            let channel: Vec<&TensorOf<S>> = grad_out.iter().map(|g| &g[o]).collect();
            let gb = BatchTensorOf::pack_refs(&channel)?;
            self.backward_schedule.execute_batch_map_tiled(&gb, &mut arena, |ti, bt| {
                let term = &self.terms[ti];
                for b in 0..batch {
                    let t = bt.item(b);
                    for i in 0..self.c_in {
                        let w = term.weights[o * self.c_in + i];
                        // ∂L/∂λ_d[o,i] += sign · ⟨F(dᵀ) g_b, x_b[i]⟩
                        // (inner product accumulated in S, like the rest of
                        // the kernel stack — identity for S = f64).
                        grads.terms[ti][o * self.c_in + i] += term.adjoint_sign
                            * t.iter()
                                .zip(&x[b][i].data)
                                .map(|(&a, &v)| a * v)
                                .sum::<S>()
                                .to_f64();
                        if w != 0.0 {
                            let alpha = S::from_f64(w * term.adjoint_sign);
                            for (gx, &tv) in grad_x[b][i].data.iter_mut().zip(t) {
                                *gx += alpha * tv;
                            }
                        }
                    }
                }
                Ok(())
            })?;
        }
        let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
        for (bi, (plan, _)) in self.bias_terms.iter().enumerate() {
            let basis = plan.apply(&one)?;
            for (o, row) in grads.bias[bi].iter_mut().enumerate().take(self.c_out) {
                for gitem in grad_out {
                    *row += basis.dot(&gitem[o]);
                }
            }
        }
        Ok(grad_x)
    }

    /// Backward: returns `∂L/∂x` and accumulates parameter gradients.
    pub fn backward<S: Scalar>(
        &self,
        x: &[TensorOf<S>],
        grad_out: &[TensorOf<S>],
        grads: &mut ChannelGrads,
    ) -> Result<Vec<TensorOf<S>>> {
        self.check_channels(x)?;
        assert_eq!(grad_out.len(), self.c_out);
        let mut grad_x: Vec<TensorOf<S>> = (0..self.c_in)
            .map(|_| TensorOf::zeros(self.n, self.k))
            .collect();
        let mut arena = PooledArenaOf::<S>::get();
        for (o, g) in grad_out.iter().enumerate() {
            // One fused pass over the transposed-term schedule per output
            // gradient: every bt = F(dᵀ) g shares its permute/contraction
            // prefix with its neighbours and is handed out of a reused
            // scratch buffer, then fanned across the input channels.
            self.backward_schedule.execute_map_tiled(g, &mut arena, |ti, bt| {
                let term = &self.terms[ti];
                for (i, x_t) in x.iter().enumerate() {
                    let w = term.weights[o * self.c_in + i];
                    // ∂L/∂λ_d[o,i] = sign · ⟨F(dᵀ) g, x[i]⟩
                    grads.terms[ti][o * self.c_in + i] += term.adjoint_sign * bt.dot(x_t);
                    if w != 0.0 {
                        grad_x[i].axpy(w * term.adjoint_sign, bt);
                    }
                }
                Ok(())
            })?;
        }
        let one = TensorOf::from_vec(self.n, 0, vec![S::ONE])?;
        for (bi, (plan, _)) in self.bias_terms.iter().enumerate() {
            // Reuse the fast path via the transposed bias diagram? Bias
            // diagrams have k = 0; their adjoint maps order-l to order-0:
            // ⟨F(b)(1), g⟩ per output channel.
            let basis = plan.apply(&one)?;
            for (o, g) in grad_out.iter().enumerate() {
                grads.bias[bi][o] += basis.dot(g);
            }
        }
        Ok(grad_x)
    }

    /// Zeroed gradient buffers.
    pub fn zero_grads(&self) -> ChannelGrads {
        ChannelGrads {
            terms: self
                .terms
                .iter()
                .map(|t| vec![0.0; t.weights.len()])
                .collect(),
            bias: self
                .bias_terms
                .iter()
                .map(|(_, m)| vec![0.0; m.len()])
                .collect(),
        }
    }

    /// Flat parameter access (for optimisers).
    pub fn params_flat(&self) -> Vec<f64> {
        let mut p = Vec::new();
        for t in &self.terms {
            p.extend_from_slice(&t.weights);
        }
        for (_, m) in &self.bias_terms {
            p.extend_from_slice(m);
        }
        p
    }

    /// Write back a flat parameter vector.
    pub fn set_params_flat(&mut self, flat: &[f64]) {
        let mut off = 0;
        for t in &mut self.terms {
            let n = t.weights.len();
            t.weights.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        for (_, m) in &mut self.bias_terms {
            let n = m.len();
            m.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        debug_assert_eq!(off, flat.len());
    }

    /// Flatten gradients to match [`Self::params_flat`].
    pub fn grads_flat(&self, grads: &ChannelGrads) -> Vec<f64> {
        let mut g = Vec::new();
        for t in &grads.terms {
            g.extend_from_slice(t);
        }
        for b in &grads.bias {
            g.extend_from_slice(b);
        }
        g
    }
}

/// Gradient buffers for one channel layer.
#[derive(Debug, Clone)]
pub struct ChannelGrads {
    /// Per-term `c_out × c_in` gradient matrices.
    pub terms: Vec<Vec<f64>>,
    /// Per-bias-diagram, per-output-channel gradients.
    pub bias: Vec<Vec<f64>>,
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]
    use super::*;
    use crate::groups;
    use crate::tensor::Tensor;

    fn rand_channels(n: usize, k: usize, c: usize, rng: &mut Rng) -> Vec<Tensor> {
        (0..c).map(|_| Tensor::random(n, k, rng)).collect()
    }

    #[test]
    fn shapes_and_param_counts() {
        let mut rng = Rng::new(811);
        let layer =
            ChannelEquivariantLinear::new(Group::Symmetric, 3, 2, 2, 4, 5, &mut rng).unwrap();
        assert_eq!(layer.c_in(), 4);
        assert_eq!(layer.c_out(), 5);
        // 15 diagrams (n=3 → B(4,3)=14? n=3: B(4,3)=S(4,1)+S(4,2)+S(4,3)=1+7+6=14)
        let terms = layer.terms.len();
        assert_eq!(
            layer.num_params(),
            terms * 20 + layer.bias_terms.len() * 5
        );
        let x = rand_channels(3, 2, 4, &mut rng);
        let y = layer.forward(&x).unwrap();
        assert_eq!(y.len(), 5);
        assert_eq!(y[0].order, 2);
    }

    #[test]
    fn channelwise_equivariance() {
        let mut rng = Rng::new(812);
        for group in [Group::Symmetric, Group::Orthogonal, Group::Symplectic] {
            let n = if group == Group::Symplectic { 4 } else { 3 };
            let layer = ChannelEquivariantLinear::new(group, n, 2, 2, 2, 3, &mut rng).unwrap();
            let x = rand_channels(n, 2, 2, &mut rng);
            let g = groups::sample(group, n, &mut rng).unwrap();
            let gx: Vec<Tensor> = x.iter().map(|t| groups::rho(&g, t)).collect();
            let lhs = layer.forward(&gx).unwrap();
            let rhs: Vec<Tensor> = layer
                .forward(&x)
                .unwrap()
                .iter()
                .map(|t| groups::rho(&g, t))
                .collect();
            for (a, b) in lhs.iter().zip(&rhs) {
                assert!(a.allclose(b, 1e-7), "{group}: {}", a.max_abs_diff(b));
            }
        }
    }

    #[test]
    fn gradient_check() {
        let mut rng = Rng::new(813);
        let mut layer =
            ChannelEquivariantLinear::new(Group::Symmetric, 2, 1, 1, 2, 2, &mut rng).unwrap();
        // give biases non-zero values so their gradients are exercised
        let mut p = layer.params_flat();
        for v in &mut p {
            if *v == 0.0 {
                *v = 0.05;
            }
        }
        layer.set_params_flat(&p);
        let x = rand_channels(2, 1, 2, &mut rng);
        let loss = |layer: &ChannelEquivariantLinear, x: &[Tensor]| -> f64 {
            layer
                .forward(x)
                .unwrap()
                .iter()
                .map(|t| 0.5 * t.data.iter().map(|v| v * v).sum::<f64>())
                .sum()
        };
        let out = layer.forward(&x).unwrap();
        let mut grads = layer.zero_grads();
        let grad_x = layer.backward(&x, &out, &mut grads).unwrap();
        let flat_g = layer.grads_flat(&grads);
        let flat_p = layer.params_flat();
        let eps = 1e-6;
        for i in 0..flat_p.len() {
            let mut lp = layer.clone();
            let mut pp = flat_p.clone();
            pp[i] += eps;
            lp.set_params_flat(&pp);
            let mut lm = layer.clone();
            let mut pm = flat_p.clone();
            pm[i] -= eps;
            lm.set_params_flat(&pm);
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps);
            assert!(
                (fd - flat_g[i]).abs() < 1e-5,
                "param {i}: fd {fd} vs {}",
                flat_g[i]
            );
        }
        // Input gradients.
        for (ci, xt) in x.iter().enumerate() {
            for f in 0..xt.len() {
                let mut xp: Vec<Tensor> = x.clone();
                xp[ci].data[f] += eps;
                let mut xm: Vec<Tensor> = x.clone();
                xm[ci].data[f] -= eps;
                let fd = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps);
                assert!(
                    (fd - grad_x[ci].data[f]).abs() < 1e-5,
                    "input ({ci},{f}): fd {fd} vs {}",
                    grad_x[ci].data[f]
                );
            }
        }
    }

    #[test]
    fn single_channel_matches_equivariant_linear() {
        // c_in = c_out = 1 must reproduce the single-channel layer given
        // the same coefficients.
        use crate::layer::{EquivariantLinear, Init};
        let mut rng = Rng::new(814);
        let mut ch =
            ChannelEquivariantLinear::new(Group::Orthogonal, 3, 2, 2, 1, 1, &mut rng).unwrap();
        let mut single =
            EquivariantLinear::new(Group::Orthogonal, 3, 2, 2, Init::Zeros, &mut rng).unwrap();
        // Copy channel weights into the single-channel layer's coeffs.
        let w: Vec<f64> = ch.terms.iter().map(|t| t.weights[0]).collect();
        single.coeffs.copy_from_slice(&w);
        // zero biases in both (single starts at Zeros; ch bias starts 0)
        for (_, m) in &mut ch.bias_terms {
            m[0] = 0.0;
        }
        let x = Tensor::random(3, 2, &mut rng);
        let a = ch.forward(std::slice::from_ref(&x)).unwrap();
        let b = single.forward(&x).unwrap();
        assert!(a[0].allclose(&b, 1e-12));
    }

    #[test]
    fn apply_matches_legacy_entry_points() {
        let mut rng = Rng::new(816);
        let layer =
            ChannelEquivariantLinear::new(Group::Symmetric, 3, 2, 2, 2, 3, &mut rng).unwrap();
        let item = rand_channels(3, 2, 2, &mut rng);
        let single = layer.apply(item.as_slice()).unwrap().into_single().unwrap();
        let want = layer.forward(&item).unwrap();
        for (a, b) in single.iter().zip(&want) {
            assert!(a.allclose(b, 0.0));
        }
        let batch: Vec<Vec<Tensor>> = (0..3).map(|_| rand_channels(3, 2, 2, &mut rng)).collect();
        let got = layer.apply(batch.as_slice()).unwrap().into_vec();
        let legacy = layer.forward_batch(&batch).unwrap();
        for (gi, li) in got.iter().zip(&legacy) {
            for (a, b) in gi.iter().zip(li) {
                assert!(a.allclose(b, 0.0));
            }
        }
        // apply_grad mirrors backward_batch, gradients included.
        let gs: Vec<Vec<Tensor>> = (0..3).map(|_| rand_channels(3, 2, 3, &mut rng)).collect();
        let mut got_grads = layer.zero_grads();
        let gx = layer
            .apply_grad(batch.as_slice(), gs.as_slice(), &mut got_grads)
            .unwrap()
            .into_vec();
        let mut want_grads = layer.zero_grads();
        let wx = layer.backward_batch(&batch, &gs, &mut want_grads).unwrap();
        for (gi, wi) in gx.iter().zip(&wx) {
            for (a, b) in gi.iter().zip(wi) {
                assert!(a.allclose(b, 0.0));
            }
        }
        assert_eq!(
            layer.grads_flat(&got_grads),
            layer.grads_flat(&want_grads)
        );
        // Mismatched packagings are rejected.
        assert!(layer
            .apply_grad(item.as_slice(), gs.as_slice(), &mut layer.zero_grads())
            .is_err());
    }

    #[test]
    fn channel_count_validation() {
        let mut rng = Rng::new(815);
        let layer =
            ChannelEquivariantLinear::new(Group::Symmetric, 3, 1, 1, 2, 2, &mut rng).unwrap();
        let too_few = vec![Tensor::zeros(3, 1)];
        assert!(layer.forward(&too_few).is_err());
        let wrong_order = vec![Tensor::zeros(3, 2), Tensor::zeros(3, 2)];
        assert!(layer.forward(&wrong_order).is_err());
    }
}
