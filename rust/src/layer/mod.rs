//! Equivariant linear layers (Corollaries 6, 8, 10, 12).
//!
//! An equivariant weight matrix `W : (R^n)^{⊗k} → (R^n)^{⊗l}` is a linear
//! combination `W = Σ_d λ_d · F(d)` over the group's spanning diagrams,
//! with the `λ_d` learned. [`EquivariantLinear`] stores one pre-factored
//! [`MultPlan`] per diagram (plus one for its transpose, for the backward
//! pass) and never materialises `W` — every forward/backward runs the
//! paper's fast algorithm per spanning term and sums.
//!
//! Backward-pass identity: the adjoint of `F(d)` is `sign(d) · F(dᵀ)`
//! where `dᵀ` swaps the diagram's rows. The sign is 1 for Θ, Φ and X (the
//! Sp(n) γ-factors are preserved verbatim under row swap), and
//! `(-1)^{s(n-s)}` for SO(n) free-vertex diagrams (moving the `s` free top
//! indices past the `n-s` free bottom indices inside the Levi-Civita
//! symbol).

mod channels;
mod input;
mod linear;

pub use channels::{ChannelEquivariantLinear, ChannelGrads};
pub use input::{BatchInput, BatchOutput, ChannelBatchInput, ChannelBatchOutput};
pub use linear::{spanning_plans, transpose_sign, EquivariantLinear, Init, LayerGrads};
