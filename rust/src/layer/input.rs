//! Polymorphic inputs and outputs for the unified `apply`/`apply_grad`
//! entry points.
//!
//! The layers and the network historically grew one forward method per
//! input shape (`forward`, `forward_batch`, `forward_batch_refs`,
//! `forward_batched`), all computing the same linear map over differently
//! packaged batches. [`BatchInput`] collapses those shapes into one enum —
//! a single tensor, a slice of owned tensors, a slice of borrowed tensors,
//! or an already-packed `[B, n^k]` batch — so every caller goes through
//! `apply(&self, input: impl Into<BatchInput<S>>)` and the legacy names
//! survive only as `#[deprecated]` wrappers. [`BatchOutput`] mirrors the
//! input shape on the way out: `Single` in → `Single` out, slices in →
//! `Batch` out, `Packed` in → `Packed` out.

use crate::tensor::{BatchTensorOf, Scalar, TensorOf};

/// One forward (or upstream-gradient) argument to the unified layer API,
/// in whichever packaging the caller already has.
#[derive(Debug, Clone, Copy)]
pub enum BatchInput<'a, S: Scalar> {
    /// One tensor — the low-latency single-request path.
    Single(&'a TensorOf<S>),
    /// A batch of owned tensors.
    Slice(&'a [TensorOf<S>]),
    /// A batch of borrowed tensors (the coordinator batches requests it
    /// does not own contiguously).
    Refs(&'a [&'a TensorOf<S>]),
    /// An already-packed `[B, n^k]` batch — the zero-repack path the
    /// network plumbing uses between layers.
    Packed(&'a BatchTensorOf<S>),
}

impl<'a, S: Scalar> From<&'a TensorOf<S>> for BatchInput<'a, S> {
    fn from(v: &'a TensorOf<S>) -> Self {
        BatchInput::Single(v)
    }
}

impl<'a, S: Scalar> From<&'a [TensorOf<S>]> for BatchInput<'a, S> {
    fn from(vs: &'a [TensorOf<S>]) -> Self {
        BatchInput::Slice(vs)
    }
}

impl<'a, S: Scalar> From<&'a Vec<TensorOf<S>>> for BatchInput<'a, S> {
    fn from(vs: &'a Vec<TensorOf<S>>) -> Self {
        BatchInput::Slice(vs)
    }
}

impl<'a, S: Scalar> From<&'a [&'a TensorOf<S>]> for BatchInput<'a, S> {
    fn from(vs: &'a [&'a TensorOf<S>]) -> Self {
        BatchInput::Refs(vs)
    }
}

impl<'a, S: Scalar> From<&'a Vec<&'a TensorOf<S>>> for BatchInput<'a, S> {
    fn from(vs: &'a Vec<&'a TensorOf<S>>) -> Self {
        BatchInput::Refs(vs)
    }
}

impl<'a, S: Scalar> From<&'a BatchTensorOf<S>> for BatchInput<'a, S> {
    fn from(vb: &'a BatchTensorOf<S>) -> Self {
        BatchInput::Packed(vb)
    }
}

impl<'a, S: Scalar> BatchInput<'a, S> {
    /// Short name of the packaging, for shape-mismatch error messages.
    pub(crate) fn kind(&self) -> &'static str {
        match self {
            BatchInput::Single(_) => "single",
            BatchInput::Slice(_) => "slice",
            BatchInput::Refs(_) => "refs",
            BatchInput::Packed(_) => "packed",
        }
    }
}

/// Result of a unified `apply`/`apply_grad` call, shaped like the input
/// that produced it.
#[derive(Debug, Clone)]
pub enum BatchOutput<S: Scalar> {
    /// Output for a [`BatchInput::Single`] input.
    Single(TensorOf<S>),
    /// Per-item outputs for a [`BatchInput::Slice`]/[`BatchInput::Refs`]
    /// input, in order.
    Batch(Vec<TensorOf<S>>),
    /// Packed output for a [`BatchInput::Packed`] input.
    Packed(BatchTensorOf<S>),
}

impl<S: Scalar> BatchOutput<S> {
    /// The single output tensor, if this came from a single input.
    pub fn into_single(self) -> Option<TensorOf<S>> {
        match self {
            BatchOutput::Single(t) => Some(t),
            _ => None,
        }
    }

    /// The outputs as one owned vector, whatever the packaging: a single
    /// output becomes a one-element vector, a packed batch is unpacked.
    pub fn into_vec(self) -> Vec<TensorOf<S>> {
        match self {
            BatchOutput::Single(t) => vec![t],
            BatchOutput::Batch(ts) => ts,
            BatchOutput::Packed(b) => b.unpack(),
        }
    }

    /// The packed output batch, if this came from a packed input.
    pub fn into_packed(self) -> Option<BatchTensorOf<S>> {
        match self {
            BatchOutput::Packed(b) => Some(b),
            _ => None,
        }
    }
}

/// Input to the unified channel-layer API: one item is a `c_in`-long list
/// of tensors, a batch is a list of such items.
#[derive(Debug, Clone, Copy)]
pub enum ChannelBatchInput<'a, S: Scalar> {
    /// One multi-channel item (`c_in` tensors).
    Single(&'a [TensorOf<S>]),
    /// A batch of multi-channel items.
    Batch(&'a [Vec<TensorOf<S>>]),
}

impl<'a, S: Scalar> From<&'a [TensorOf<S>]> for ChannelBatchInput<'a, S> {
    fn from(x: &'a [TensorOf<S>]) -> Self {
        ChannelBatchInput::Single(x)
    }
}

impl<'a, S: Scalar> From<&'a Vec<TensorOf<S>>> for ChannelBatchInput<'a, S> {
    fn from(x: &'a Vec<TensorOf<S>>) -> Self {
        ChannelBatchInput::Single(x)
    }
}

impl<'a, S: Scalar> From<&'a [Vec<TensorOf<S>>]> for ChannelBatchInput<'a, S> {
    fn from(x: &'a [Vec<TensorOf<S>>]) -> Self {
        ChannelBatchInput::Batch(x)
    }
}

impl<'a, S: Scalar> From<&'a Vec<Vec<TensorOf<S>>>> for ChannelBatchInput<'a, S> {
    fn from(x: &'a Vec<Vec<TensorOf<S>>>) -> Self {
        ChannelBatchInput::Batch(x)
    }
}

impl<'a, S: Scalar> ChannelBatchInput<'a, S> {
    /// Short name of the packaging, for shape-mismatch error messages.
    pub(crate) fn kind(&self) -> &'static str {
        match self {
            ChannelBatchInput::Single(_) => "single",
            ChannelBatchInput::Batch(_) => "batch",
        }
    }
}

/// Output of the unified channel-layer API, shaped like its input.
#[derive(Debug, Clone)]
pub enum ChannelBatchOutput<S: Scalar> {
    /// `c_out` output channels for one item.
    Single(Vec<TensorOf<S>>),
    /// Per-item `c_out`-channel outputs, in order.
    Batch(Vec<Vec<TensorOf<S>>>),
}

impl<S: Scalar> ChannelBatchOutput<S> {
    /// The single item's channels, if this came from a single input.
    pub fn into_single(self) -> Option<Vec<TensorOf<S>>> {
        match self {
            ChannelBatchOutput::Single(t) => Some(t),
            _ => None,
        }
    }

    /// The per-item channel lists, whatever the packaging.
    pub fn into_vec(self) -> Vec<Vec<TensorOf<S>>> {
        match self {
            ChannelBatchOutput::Single(t) => vec![t],
            ChannelBatchOutput::Batch(ts) => ts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{BatchTensor, Tensor};

    #[test]
    fn from_impls_pick_the_right_variant() {
        let t = Tensor::zeros(2, 1);
        let owned = vec![Tensor::zeros(2, 1), Tensor::zeros(2, 1)];
        let refs: Vec<&Tensor> = owned.iter().collect();
        let packed = BatchTensor::pack(&owned).unwrap();
        assert_eq!(BatchInput::from(&t).kind(), "single");
        assert_eq!(BatchInput::from(owned.as_slice()).kind(), "slice");
        assert_eq!(BatchInput::from(&owned).kind(), "slice");
        assert_eq!(BatchInput::from(refs.as_slice()).kind(), "refs");
        assert_eq!(BatchInput::from(&packed).kind(), "packed");
    }

    #[test]
    fn output_accessors_match_variants() {
        let t = Tensor::linspace(2, 1);
        let single = BatchOutput::Single(t.clone());
        assert!(single.clone().into_single().is_some());
        assert_eq!(single.into_vec().len(), 1);
        let owned = vec![Tensor::zeros(2, 1), Tensor::zeros(2, 1)];
        let packed = BatchOutput::Packed(BatchTensor::pack(&owned).unwrap());
        assert!(packed.clone().into_single().is_none());
        assert_eq!(packed.clone().into_vec().len(), 2);
        assert!(packed.into_packed().is_some());
        let batch = BatchOutput::Batch(owned);
        assert!(batch.clone().into_packed().is_none());
        assert_eq!(batch.into_vec().len(), 2);
    }
}
