//! A hand-rolled TOML-subset parser (serde/toml substitute).
//!
//! Supported grammar:
//!
//! ```toml
//! # comment
//! [section]
//! string_key = "value"
//! int_key = 42
//! float_key = 3.5
//! bool_key = true
//! array_key = [2, 3, 4]
//! ```
//!
//! Keys are flattened as `section.key`. Nested tables, dates, multi-line
//! strings and inline tables are intentionally unsupported.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// As string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As integer (accepts `Int` only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// As float (accepts `Float` or `Int`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As a vec of usize (for order lists etc.).
    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        match self {
            Value::Array(xs) => xs
                .iter()
                .map(|x| x.as_int().and_then(|i| usize::try_from(i).ok()))
                .collect(),
            _ => None,
        }
    }
}

/// Parse a config document into a flat `section.key → value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(err(lineno, "unterminated section header"));
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full_key, value);
    }
    Ok(out)
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a quoted string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(err(lineno, "unterminated string"));
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err(lineno, "unterminated array"));
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>> = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), lineno))
            .collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // Arrays are flat (no nesting), so a plain comma split outside strings
    // suffices.
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_types() {
        let doc = r#"
# top comment
name = "equidiag"   # trailing comment
n = 5
lr = 0.01
verbose = true
orders = [2, 2, 1, 0]

[server]
workers = 4
"#;
        let m = parse(doc).unwrap();
        assert_eq!(m["name"].as_str(), Some("equidiag"));
        assert_eq!(m["n"].as_int(), Some(5));
        assert_eq!(m["lr"].as_float(), Some(0.01));
        assert_eq!(m["verbose"].as_bool(), Some(true));
        assert_eq!(m["orders"].as_usize_array(), Some(vec![2, 2, 1, 0]));
        assert_eq!(m["server.workers"].as_int(), Some(4));
    }

    #[test]
    fn int_coerces_to_float_not_vice_versa() {
        let m = parse("x = 3").unwrap();
        assert_eq!(m["x"].as_float(), Some(3.0));
        let m2 = parse("y = 3.5").unwrap();
        assert_eq!(m2["y"].as_int(), None);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(m["k"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_located() {
        let e = parse("\n\nbad line").unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
        assert!(parse("[unclosed").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = what").is_err());
    }

    #[test]
    fn empty_array() {
        let m = parse("a = []").unwrap();
        assert_eq!(m["a"], Value::Array(vec![]));
    }

    #[test]
    fn string_array() {
        let m = parse(r#"a = ["x", "y"]"#).unwrap();
        match &m["a"] {
            Value::Array(xs) => {
                assert_eq!(xs[0].as_str(), Some("x"));
                assert_eq!(xs[1].as_str(), Some("y"));
            }
            _ => panic!(),
        }
    }
}
