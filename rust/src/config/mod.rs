//! Configuration system: a minimal TOML-subset parser plus the typed
//! schemas the launcher consumes.
//!
//! Neither `serde` nor `toml` is available in the offline registry (see
//! DESIGN.md §3), so [`toml_lite`] implements the subset we use: `[section]`
//! headers, `key = value` with strings, integers, floats, booleans and flat
//! arrays, and `#` comments.

pub mod schema;
pub mod toml_lite;

pub use schema::{AppConfig, ModelConfig, NetworkConfig, ServerConfig, TrainingConfig};
pub use toml_lite::{parse, Value};
