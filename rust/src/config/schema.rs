//! Typed configuration schemas built on [`super::toml_lite`].

use super::toml_lite::{parse, Value};
use crate::error::{Error, Result};
use crate::fastmult::Group;
use crate::nn::Activation;
use crate::tensor::Precision;
use std::collections::BTreeMap;
use std::time::Duration;

/// Network architecture section (`[network]`).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkConfig {
    /// Which group the layers are equivariant to.
    pub group: Group,
    /// Representation dimension `n`.
    pub n: usize,
    /// Tensor orders per layer boundary, e.g. `[2, 2, 1, 0]`.
    pub orders: Vec<usize>,
    /// Hidden activation.
    pub activation: Activation,
    /// Initialisation standard deviation (0 means `ScaledNormal`).
    pub init_std: f64,
    /// Weight-init RNG seed.
    pub seed: u64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            group: Group::Symmetric,
            n: 5,
            orders: vec![2, 2, 0],
            activation: Activation::Relu,
            init_std: 0.0,
            seed: 42,
        }
    }
}

/// Training section (`[training]`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingConfig {
    /// Optimisation steps.
    pub steps: usize,
    /// Minibatch size.
    pub batch_size: usize,
    /// Learning rate.
    pub lr: f64,
    /// `"adam"` or `"sgd"`.
    pub optimizer: String,
    /// Momentum for SGD.
    pub momentum: f64,
    /// Log cadence (0 disables).
    pub log_every: usize,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            steps: 300,
            batch_size: 8,
            lr: 0.01,
            optimizer: "adam".into(),
            momentum: 0.9,
            log_every: 50,
        }
    }
}

/// Model-execution section (`[model]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Scalar precision the served network executes at: `"f64"` (default;
    /// bitwise-reference path) or `"f32"` (halved memory traffic). Training
    /// always runs in `f64`; this only selects the serving precision.
    pub precision: Precision,
    /// Cache budget in bytes for the tiled schedule walk (`tile_bytes`,
    /// see `docs/tiled_execution.md`). `None` (the default) auto-detects
    /// the L2 data-cache size ([`crate::util::hw::cache_bytes`], which the
    /// `PALLAS_CACHE_BYTES` env var overrides); `Some(0)` disables tiling.
    pub tile_bytes: Option<usize>,
    /// Whether the memory-pressure brownout may drop this model to `f32`
    /// at its deepest level (`brownout_f32`, default `true`). Models whose
    /// accuracy contract cannot tolerate single precision set this `false`
    /// and brownout stops at the tiled-f64 level for them.
    pub brownout_f32: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            precision: Precision::default(),
            tile_bytes: None,
            brownout_f32: true,
        }
    }
}

/// Serving section (`[server]`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Worker count (`workers`). In the config file `0` means "one per
    /// hardware thread" — resolved at parse time from the cached
    /// [`crate::util::executor::hw_threads`], so the struct always holds
    /// the concrete count.
    pub workers: usize,
    /// Maximum requests batched together.
    pub max_batch: usize,
    /// Batching window. With `target_p95_ms` set this is the *starting*
    /// window; the batcher then adapts it against the live p95.
    pub batch_window: Duration,
    /// Bounded request-queue capacity (backpressure).
    pub queue_capacity: usize,
    /// Per-request deadline (`request_timeout_ms`, `0`/absent = off).
    /// When set, every accepted request is stamped with a deadline;
    /// expired items are shed by the batcher before dispatch and by
    /// workers before execution, and `infer` becomes a bounded wait that
    /// returns [`crate::Error::DeadlineExceeded`].
    pub request_timeout: Option<Duration>,
    /// Per-model admission control (`max_inflight_per_model`, `0`/absent =
    /// unlimited): a route already carrying this many in-flight requests
    /// sheds new submissions with [`crate::Error::Overloaded`] instead of
    /// letting one hot model starve the shared queue.
    pub max_inflight_per_model: Option<usize>,
    /// Explicit bound on the process-wide [`crate::fastmult::PlanCache`]
    /// (number of pre-factored plans kept; `0` = unbounded). `None` (the
    /// default) leaves the global cache's bound untouched — the cache is
    /// shared by every coordinator in the process, so only an explicitly
    /// configured value is applied at start.
    pub plan_cache_capacity: Option<usize>,
    /// SLO target for the end-to-end p95 latency (`target_p95_ms`,
    /// `0`/absent = off). When set, the batcher adapts its window
    /// against the live p95 histogram: over target it narrows the
    /// window (dispatch sooner, cut queueing delay), comfortably under
    /// target it widens it (batch more, raise throughput). The window
    /// stays inside `[batch_window / 8, batch_window × 16]`.
    pub target_p95: Option<Duration>,
    /// Non-finite output canary (`numeric_guard`, default `false`). When
    /// on, every worker sweeps its outputs for NaN/±inf before responding
    /// and converts poisoned items into [`crate::Error::NumericFault`]
    /// instead of shipping silent garbage.
    pub numeric_guard: bool,
    /// Shadow-verification sampling rate in requests-per-thousand
    /// (`verify_per_mille`, `0`/absent = off, clamped to 1000). Sampled
    /// requests are re-executed through the per-term reference path on
    /// executor spare capacity and compared against the fused answer; a
    /// mismatch quarantines + recompiles the cached schedules and flags
    /// the model degraded.
    pub verify_per_mille: usize,
    /// Hung-batch watchdog threshold as a multiple of the live p99 batch
    /// execution time (`watchdog_factor`, `0`/absent = off). The effective
    /// threshold never drops below `request_timeout_ms` when that is set.
    pub watchdog_factor: f64,
    /// Arena budget for the memory-pressure brownout
    /// (`arena_budget_bytes`, `0`/absent = off). Sustained arena usage
    /// above the budget walks `BrownoutState` Normal → Tiled → TiledF32;
    /// a sustained under-budget window recovers it.
    pub arena_budget_bytes: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            max_batch: 16,
            batch_window: Duration::from_micros(200),
            queue_capacity: 1024,
            request_timeout: None,
            max_inflight_per_model: None,
            plan_cache_capacity: None,
            target_p95: None,
            numeric_guard: false,
            verify_per_mille: 0,
            watchdog_factor: 0.0,
            arena_budget_bytes: None,
        }
    }
}

/// Whole-application config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AppConfig {
    /// `[network]`.
    pub network: NetworkConfig,
    /// `[training]`.
    pub training: TrainingConfig,
    /// `[model]`.
    pub model: ModelConfig,
    /// `[server]`.
    pub server: ServerConfig,
    /// Optional HLO artifact to serve (`artifact = "…"` at top level).
    pub artifact: Option<String>,
}

fn get_usize(m: &BTreeMap<String, Value>, key: &str, default: usize) -> Result<usize> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_int()
            .and_then(|i| usize::try_from(i).ok())
            .ok_or_else(|| Error::Config(format!("{key} must be a non-negative integer"))),
    }
}

fn get_f64(m: &BTreeMap<String, Value>, key: &str, default: f64) -> Result<f64> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_float()
            .ok_or_else(|| Error::Config(format!("{key} must be a number"))),
    }
}

fn get_bool(m: &BTreeMap<String, Value>, key: &str, default: bool) -> Result<bool> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("{key} must be true or false"))),
    }
}

fn get_str(m: &BTreeMap<String, Value>, key: &str, default: &str) -> Result<String> {
    match m.get(key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| Error::Config(format!("{key} must be a string"))),
    }
}

impl AppConfig {
    /// Parse from config text.
    pub fn from_text(text: &str) -> Result<Self> {
        let m = parse(text)?;
        let d = AppConfig::default();

        let group = match m.get("network.group") {
            None => d.network.group,
            Some(v) => Group::parse(
                v.as_str()
                    .ok_or_else(|| Error::Config("network.group must be a string".into()))?,
            )?,
        };
        let orders = match m.get("network.orders") {
            None => d.network.orders.clone(),
            Some(v) => v
                .as_usize_array()
                .ok_or_else(|| Error::Config("network.orders must be an int array".into()))?,
        };
        if orders.len() < 2 {
            return Err(Error::Config(
                "network.orders needs at least two entries".into(),
            ));
        }
        let activation = {
            let s = get_str(&m, "network.activation", "relu")?;
            Activation::parse(&s)
                .ok_or_else(|| Error::Config(format!("unknown activation '{s}'")))?
        };
        let network = NetworkConfig {
            group,
            n: get_usize(&m, "network.n", d.network.n)?,
            orders,
            activation,
            init_std: get_f64(&m, "network.init_std", d.network.init_std)?,
            seed: get_usize(&m, "network.seed", d.network.seed as usize)? as u64,
        };

        let training = TrainingConfig {
            steps: get_usize(&m, "training.steps", d.training.steps)?,
            batch_size: get_usize(&m, "training.batch_size", d.training.batch_size)?.max(1),
            lr: get_f64(&m, "training.lr", d.training.lr)?,
            optimizer: get_str(&m, "training.optimizer", &d.training.optimizer)?,
            momentum: get_f64(&m, "training.momentum", d.training.momentum)?,
            log_every: get_usize(&m, "training.log_every", d.training.log_every)?,
        };
        if training.optimizer != "adam" && training.optimizer != "sgd" {
            return Err(Error::Config(format!(
                "training.optimizer must be adam|sgd, got '{}'",
                training.optimizer
            )));
        }

        let model = ModelConfig {
            precision: {
                let s = get_str(&m, "model.precision", Precision::default().name())?;
                Precision::parse(&s).ok_or_else(|| {
                    Error::Config(format!("model.precision must be f64|f32, got '{s}'"))
                })?
            },
            tile_bytes: match m.get("model.tile_bytes") {
                None => None,
                Some(v) => Some(v.as_int().and_then(|i| usize::try_from(i).ok()).ok_or_else(
                    || Error::Config("model.tile_bytes must be a non-negative integer".into()),
                )?),
            },
            brownout_f32: get_bool(&m, "model.brownout_f32", d.model.brownout_f32)?,
        };

        let server = ServerConfig {
            // `workers = 0` means "one per hardware thread" (the cached
            // count from the executor module); any explicit value is
            // taken as-is.
            workers: match get_usize(&m, "server.workers", d.server.workers)? {
                0 => crate::util::executor::hw_threads(),
                n => n,
            },
            max_batch: get_usize(&m, "server.max_batch", d.server.max_batch)?.max(1),
            batch_window: Duration::from_micros(get_usize(
                &m,
                "server.batch_window_us",
                d.server.batch_window.as_micros() as usize,
            )? as u64),
            queue_capacity: get_usize(&m, "server.queue_capacity", d.server.queue_capacity)?
                .max(1),
            request_timeout: match get_usize(&m, "server.request_timeout_ms", 0)? {
                0 => None,
                ms => Some(Duration::from_millis(ms as u64)),
            },
            max_inflight_per_model: match get_usize(&m, "server.max_inflight_per_model", 0)? {
                0 => None,
                n => Some(n),
            },
            plan_cache_capacity: match m.get("server.plan_cache_capacity") {
                None => None,
                Some(v) => Some(v.as_int().and_then(|i| usize::try_from(i).ok()).ok_or_else(
                    || {
                        Error::Config(
                            "server.plan_cache_capacity must be a non-negative integer".into(),
                        )
                    },
                )?),
            },
            target_p95: match get_usize(&m, "server.target_p95_ms", 0)? {
                0 => None,
                ms => Some(Duration::from_millis(ms as u64)),
            },
            numeric_guard: get_bool(&m, "server.numeric_guard", false)?,
            verify_per_mille: get_usize(&m, "server.verify_per_mille", 0)?.min(1000),
            watchdog_factor: {
                let f = get_f64(&m, "server.watchdog_factor", 0.0)?;
                if f < 0.0 {
                    return Err(Error::Config(
                        "server.watchdog_factor must be non-negative".into(),
                    ));
                }
                f
            },
            arena_budget_bytes: match get_usize(&m, "server.arena_budget_bytes", 0)? {
                0 => None,
                b => Some(b),
            },
        };

        let artifact = m
            .get("artifact")
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Config("artifact must be a string".into()))
            })
            .transpose()?;

        Ok(AppConfig {
            network,
            training,
            model,
            server,
            artifact,
        })
    }

    /// Parse from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {path}: {e}")))?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let c = AppConfig::from_text("").unwrap();
        assert_eq!(c, AppConfig::default());
    }

    #[test]
    fn full_document() {
        let c = AppConfig::from_text(
            r#"
artifact = "artifacts/model.hlo.txt"

[network]
group = "o"
n = 4
orders = [2, 2]
activation = "identity"
init_std = 0.5
seed = 7

[training]
steps = 10
batch_size = 2
lr = 0.1
optimizer = "sgd"
momentum = 0.8
log_every = 5

[model]
precision = "f32"
tile_bytes = 131072
brownout_f32 = false

[server]
workers = 2
max_batch = 8
batch_window_us = 500
queue_capacity = 64
request_timeout_ms = 250
max_inflight_per_model = 32
plan_cache_capacity = 128
target_p95_ms = 40
numeric_guard = true
verify_per_mille = 50
watchdog_factor = 4.0
arena_budget_bytes = 1048576
"#,
        )
        .unwrap();
        assert_eq!(c.network.group, Group::Orthogonal);
        assert_eq!(c.network.n, 4);
        assert_eq!(c.network.orders, vec![2, 2]);
        assert_eq!(c.network.activation, Activation::Identity);
        assert_eq!(c.training.optimizer, "sgd");
        assert_eq!(c.model.precision, Precision::F32);
        assert_eq!(c.model.tile_bytes, Some(131072));
        assert_eq!(c.server.batch_window, Duration::from_micros(500));
        assert_eq!(c.server.request_timeout, Some(Duration::from_millis(250)));
        assert_eq!(c.server.max_inflight_per_model, Some(32));
        assert_eq!(c.server.plan_cache_capacity, Some(128));
        assert_eq!(c.server.target_p95, Some(Duration::from_millis(40)));
        assert!(c.server.numeric_guard);
        assert_eq!(c.server.verify_per_mille, 50);
        assert_eq!(c.server.watchdog_factor, 4.0);
        assert_eq!(c.server.arena_budget_bytes, Some(1048576));
        assert!(!c.model.brownout_f32);
        assert_eq!(c.artifact.as_deref(), Some("artifacts/model.hlo.txt"));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(AppConfig::from_text("[network]\ngroup = \"u(n)\"").is_err());
        assert!(AppConfig::from_text("[network]\norders = [2]").is_err());
        assert!(AppConfig::from_text("[training]\noptimizer = \"lbfgs\"").is_err());
        assert!(AppConfig::from_text("[network]\nactivation = \"swish\"").is_err());
        assert!(AppConfig::from_text("[network]\nn = \"five\"").is_err());
        assert!(AppConfig::from_text("[server]\nplan_cache_capacity = \"big\"").is_err());
        assert!(AppConfig::from_text("[server]\nplan_cache_capacity = -1").is_err());
        assert!(AppConfig::from_text("[server]\nrequest_timeout_ms = \"soon\"").is_err());
        assert!(AppConfig::from_text("[server]\nmax_inflight_per_model = -3").is_err());
        assert!(AppConfig::from_text("[model]\nprecision = \"f16\"").is_err());
        assert!(AppConfig::from_text("[model]\ntile_bytes = \"big\"").is_err());
        assert!(AppConfig::from_text("[model]\ntile_bytes = -1").is_err());
        assert!(AppConfig::from_text("[server]\nnumeric_guard = \"yes\"").is_err());
        assert!(AppConfig::from_text("[server]\nverify_per_mille = -1").is_err());
        assert!(AppConfig::from_text("[server]\nwatchdog_factor = -2.0").is_err());
        assert!(AppConfig::from_text("[server]\narena_budget_bytes = \"lots\"").is_err());
        assert!(AppConfig::from_text("[model]\nbrownout_f32 = 1").is_err());
    }

    #[test]
    fn zero_disables_deadline_and_admission() {
        let c = AppConfig::from_text(
            "[server]\nrequest_timeout_ms = 0\nmax_inflight_per_model = 0",
        )
        .unwrap();
        assert_eq!(c.server.request_timeout, None);
        assert_eq!(c.server.max_inflight_per_model, None);
    }

    #[test]
    fn workers_zero_means_hardware_threads() {
        let c = AppConfig::from_text("[server]\nworkers = 0").unwrap();
        assert_eq!(c.server.workers, crate::util::executor::hw_threads());
        let c = AppConfig::from_text("[server]\nworkers = 3").unwrap();
        assert_eq!(c.server.workers, 3);
        assert!(AppConfig::from_text("[server]\nworkers = -1").is_err());
    }

    #[test]
    fn target_p95_zero_disables_adaptive_window() {
        let c = AppConfig::from_text("[server]\ntarget_p95_ms = 0").unwrap();
        assert_eq!(c.server.target_p95, None);
        let c = AppConfig::from_text("[server]\ntarget_p95_ms = 25").unwrap();
        assert_eq!(c.server.target_p95, Some(Duration::from_millis(25)));
        assert!(AppConfig::from_text("[server]\ntarget_p95_ms = \"fast\"").is_err());
    }

    #[test]
    fn precision_defaults_to_f64() {
        let c = AppConfig::from_text("").unwrap();
        assert_eq!(c.model.precision, Precision::F64);
        let c = AppConfig::from_text("[model]\nprecision = \"double\"").unwrap();
        assert_eq!(c.model.precision, Precision::F64);
    }

    #[test]
    fn tile_bytes_defaults_to_auto() {
        let c = AppConfig::from_text("").unwrap();
        assert_eq!(c.model.tile_bytes, None, "absent means auto-detect");
        // 0 is accepted verbatim: it means "tiling off", not "auto".
        let c = AppConfig::from_text("[model]\ntile_bytes = 0").unwrap();
        assert_eq!(c.model.tile_bytes, Some(0));
    }

    #[test]
    fn integrity_knobs_default_off() {
        let c = AppConfig::from_text("").unwrap();
        assert!(!c.server.numeric_guard);
        assert_eq!(c.server.verify_per_mille, 0);
        assert_eq!(c.server.watchdog_factor, 0.0);
        assert_eq!(c.server.arena_budget_bytes, None);
        assert!(c.model.brownout_f32);
        // Sampling clamps to the whole population; 0 disables brownout.
        let c = AppConfig::from_text(
            "[server]\nverify_per_mille = 5000\narena_budget_bytes = 0",
        )
        .unwrap();
        assert_eq!(c.server.verify_per_mille, 1000);
        assert_eq!(c.server.arena_budget_bytes, None);
    }
}
