//! # equidiag
//!
//! A production implementation of *"A Diagrammatic Approach to Improve
//! Computational Efficiency in Group Equivariant Neural Networks"*
//! (Pearce-Crump & Knottenbelt, 2024).
//!
//! The paper characterises the weight matrices of group equivariant neural
//! networks whose layers are tensor power spaces `(R^n)^{⊗k}`: every
//! equivariant weight matrix `W : (R^n)^{⊗k} → (R^n)^{⊗l}` is a linear
//! combination of *spanning-set matrices*, each the image of a **set
//! partition diagram** under a monoidal functor. It then gives a fast
//! multiplication algorithm (**Algorithm 1, `MatrixMult`**) that factors
//! each diagram as `σ_l ∘ d_planar ∘ σ_k` and applies the planar middle as a
//! Kronecker product of indecomposable pieces, reducing the cost of `W·v`
//! from `O(n^{l+k})` to `O(n^k)` (S_n, worst case), `O(n^{k-1})` (O(n),
//! Sp(n)), and `O(n^{k-(n-s)}(n! + n^{s-1}))` (SO(n), free-vertex diagrams).
//!
//! This crate provides:
//!
//! - [`diagram`] — set partition / Brauer / Brauer–Grood diagrams with the
//!   categorical operations (composition with the `n^c` scalar, tensor
//!   product, transpose), enumeration of spanning sets, algorithmic
//!   planarity (Definitions 31–33) and the constructive `Factor` procedure.
//! - [`tensor`] — the dense `(R^n)^{⊗k}` substrate with the axis
//!   permutation / contraction / scatter primitives the algorithm needs.
//! - [`functor`] — the monoidal functors Θ, Φ, X, Ψ materialised as (sparse
//!   or dense) matrices; the exact-but-slow baseline the paper compares
//!   against.
//! - [`fastmult`] — Algorithm 1 itself, per group, plus reusable
//!   pre-factored plans for the layer hot path.
//! - [`groups`] — samplers for S_n, O(n), SO(n), Sp(n) elements and the
//!   diagonal tensor-power action `ρ_k`, used to *test* equivariance.
//! - [`layer`] / [`nn`] — equivariant linear layers with learned
//!   coefficients and a complete training stack (forward, backward,
//!   optimisers) running the fast path end to end.
//! - [`coordinator`] / [`runtime`] — a batched inference server that owns
//!   the event loop and serves both native diagram layers and AOT-compiled
//!   JAX/Pallas models through PJRT.
//! - [`config`] — the launcher's config-file layer.
//!
//! ## Quickstart
//!
//! ```
//! use equidiag::diagram::Diagram;
//! use equidiag::fastmult::{matrix_mult, Group};
//! use equidiag::functor::naive_apply;
//! use equidiag::tensor::Tensor;
//!
//! // A (5,4)-partition diagram in the spirit of the paper's Figure 1:
//! // top-only blocks, a cross block, and a bottom-only block.
//! let d = Diagram::from_blocks(4, 5, vec![
//!     vec![0], vec![1, 3], vec![2, 6, 7], vec![4, 5, 8],
//! ]).unwrap();
//! let n = 3;
//! let v = Tensor::linspace(n, 5);
//! let fast = matrix_mult(Group::Symmetric, &d, &v).unwrap();
//! let slow = naive_apply(Group::Symmetric, &d, &v).unwrap();
//! assert!(fast.allclose(&slow, 1e-10));
//! ```

pub mod config;
pub mod coordinator;
pub mod diagram;
pub mod error;
pub mod fastmult;
pub mod functor;
pub mod groups;
pub mod layer;
pub mod linalg;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
