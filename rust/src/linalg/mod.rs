//! Small dense linear algebra substrate: `Matrix` with LU decomposition,
//! determinant, inverse, Gram–Schmidt orthonormalisation and matmul.
//!
//! This exists to *sample group elements* (O(n), SO(n), Sp(n)) for the
//! equivariance test suite — the hot path of the library never touches it.

use crate::error::{Error, Result};
use crate::util::Rng;

/// Row-major dense `rows x cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major data, `data[r * cols + c]`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::ShapeMismatch {
                expected: format!("{rows}x{cols} = {}", rows * cols),
                got: format!("{}", data.len()),
            });
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Matrix with iid standard-normal entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix {
            rows,
            cols,
            data: rng.gaussian_vec(rows * cols),
        }
    }

    /// Element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    /// Element assignment.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::ShapeMismatch {
                expected: format!("inner dims equal, lhs {}x{}", self.rows, self.cols),
                got: format!("rhs {}x{}", other.rows, other.cols),
            });
        }
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        Ok(out)
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    /// LU decomposition with partial pivoting. Returns `(lu, perm, sign)`
    /// where `lu` packs L (unit diagonal) and U, `perm` is the row
    /// permutation, and `sign` is the permutation parity (+1/-1), or `None`
    /// if the matrix is singular to working precision.
    pub fn lu(&self) -> Option<(Matrix, Vec<usize>, f64)> {
        assert_eq!(self.rows, self.cols, "LU requires a square matrix");
        let n = self.rows;
        let mut lu = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for col in 0..n {
            // Pivot selection.
            let mut pivot = col;
            let mut max = lu.get(col, col).abs();
            for r in (col + 1)..n {
                let v = lu.get(r, col).abs();
                if v > max {
                    max = v;
                    pivot = r;
                }
            }
            if max < 1e-300 {
                return None;
            }
            if pivot != col {
                for c in 0..n {
                    let tmp = lu.get(col, c);
                    lu.set(col, c, lu.get(pivot, c));
                    lu.set(pivot, c, tmp);
                }
                perm.swap(col, pivot);
                sign = -sign;
            }
            let d = lu.get(col, col);
            for r in (col + 1)..n {
                let f = lu.get(r, col) / d;
                lu.set(r, col, f);
                for c in (col + 1)..n {
                    let v = lu.get(r, c) - f * lu.get(col, c);
                    lu.set(r, c, v);
                }
            }
        }
        Some((lu, perm, sign))
    }

    /// Determinant via LU.
    pub fn det(&self) -> f64 {
        match self.lu() {
            None => 0.0,
            Some((lu, _, sign)) => {
                let mut d = sign;
                for i in 0..self.rows {
                    d *= lu.get(i, i);
                }
                d
            }
        }
    }

    /// Inverse via LU; `None` if singular.
    pub fn inverse(&self) -> Option<Matrix> {
        let n = self.rows;
        let (lu, perm, _) = self.lu()?;
        let mut inv = Matrix::zeros(n, n);
        // Solve A x = e_j for each unit vector, using PA = LU.
        for j in 0..n {
            // b = P e_j
            let mut y = vec![0.0; n];
            for (i, &pi) in perm.iter().enumerate() {
                y[i] = if pi == j { 1.0 } else { 0.0 };
            }
            // Forward solve L y' = y (L unit lower).
            for i in 0..n {
                for k in 0..i {
                    y[i] -= lu.get(i, k) * y[k];
                }
            }
            // Back solve U x = y'.
            for i in (0..n).rev() {
                for k in (i + 1)..n {
                    y[i] -= lu.get(i, k) * y[k];
                }
                y[i] /= lu.get(i, i);
            }
            for i in 0..n {
                inv.set(i, j, y[i]);
            }
        }
        Some(inv)
    }

    /// Gram–Schmidt orthonormalisation of the columns (modified GS for
    /// stability). Requires full column rank; retries are the caller's job.
    pub fn gram_schmidt(&self) -> Option<Matrix> {
        let mut q = self.clone();
        let (n, m) = (q.rows, q.cols);
        for j in 0..m {
            for i in 0..j {
                // proj of col j on col i
                let mut dot = 0.0;
                for r in 0..n {
                    dot += q.get(r, i) * q.get(r, j);
                }
                for r in 0..n {
                    let v = q.get(r, j) - dot * q.get(r, i);
                    q.set(r, j, v);
                }
            }
            let mut norm = 0.0;
            for r in 0..n {
                norm += q.get(r, j) * q.get(r, j);
            }
            let norm = norm.sqrt();
            if norm < 1e-10 {
                return None;
            }
            for r in 0..n {
                let v = q.get(r, j) / norm;
                q.set(r, j, v);
            }
        }
        Some(q)
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.cols {
            return Err(Error::ShapeMismatch {
                expected: format!("{}", self.cols),
                got: format!("{}", v.len()),
            });
        }
        let mut out = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            out[r] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let i = Matrix::identity(4);
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(4, 4, &mut rng);
        let b = i.matmul(&a).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn det_of_known_matrix() {
        // det([[1,2],[3,4]]) = -2
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!((a.det() + 2.0).abs() < 1e-12);
    }

    #[test]
    fn det_of_permutation_is_sign() {
        // row swap of identity has det -1
        let a = Matrix::from_vec(3, 3, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]).unwrap();
        assert!((a.det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let a = Matrix::gaussian(5, 5, &mut rng);
            if let Some(inv) = a.inverse() {
                let prod = a.matmul(&inv).unwrap();
                assert!(prod.max_abs_diff(&Matrix::identity(5)) < 1e-8);
            }
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(13);
        let a = Matrix::gaussian(6, 6, &mut rng);
        let q = a.gram_schmidt().unwrap();
        let qtq = q.transpose().matmul(&q).unwrap();
        assert!(qtq.max_abs_diff(&Matrix::identity(6)) < 1e-10);
    }

    #[test]
    fn singular_matrix_has_no_inverse() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]).unwrap();
        assert!(a.inverse().is_none());
        assert_eq!(a.det(), 0.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(21);
        let a = Matrix::gaussian(3, 4, &mut rng);
        let v: Vec<f64> = rng.gaussian_vec(4);
        let got = a.matvec(&v).unwrap();
        let vm = Matrix::from_vec(4, 1, v).unwrap();
        let want = a.matmul(&vm).unwrap();
        for r in 0..3 {
            assert!((got[r] - want.get(r, 0)).abs() < 1e-12);
        }
    }
}
