//! The **orbit basis** for S_n (Maron et al. 2019) and its change of basis
//! to the paper's diagram basis.
//!
//! The orbit matrix `O_π` has a 1 at `(I, J)` iff the equality pattern of
//! the combined index is *exactly* the partition `π` (indices equal ⟺ same
//! block), whereas the diagram matrix `D_π` (Theorem 5) only requires
//! "equal *within* blocks". Hence
//!
//! `D_π = Σ_{σ ⪰ π} O_σ`            (sum over coarsenings of π)
//!
//! and by Möbius inversion on the partition lattice
//!
//! `O_π = Σ_{σ ⪰ π} μ(π, σ) D_σ`,   `μ(π, σ) = Π_{B ∈ σ} (−1)^{m_B−1}(m_B−1)!`
//!
//! where `m_B` counts the blocks of `π` merged into block `B` of `σ`.
//!
//! This module provides both bases, the conversion in both directions, and
//! — the practical payoff — [`orbit_apply_fast`]: multiplying by an
//! orbit-basis element at Algorithm-1 speed by expanding it over diagram
//! plans. Networks parameterised in the Maron orbit basis (the common
//! convention) can therefore run on the fast path unchanged.

use crate::diagram::Diagram;
use crate::error::Result;
use crate::fastmult::{Group, MultPlan};
use crate::linalg::Matrix;
use crate::tensor::{MultiIndexIter, Tensor};

/// All coarsenings of the partition underlying `d` (as diagrams with the
/// same `(k, l)` shape), including `d` itself.
///
/// A coarsening merges blocks; we enumerate set partitions of the *block
/// set* and flatten. Exponential in the block count — fine for the layer
/// shapes the basis is used at (`l + k ≤ 6`).
pub fn coarsenings(d: &Diagram) -> Vec<Diagram> {
    let blocks = d.blocks().to_vec();
    let b = blocks.len();
    let mut out = Vec::new();
    // Enumerate restricted growth strings over the b blocks.
    let mut assignment = vec![0usize; b];
    fn rec(
        i: usize,
        num_groups: usize,
        assignment: &mut Vec<usize>,
        blocks: &[Vec<usize>],
        d: &Diagram,
        out: &mut Vec<Diagram>,
    ) {
        if i == blocks.len() {
            let mut merged: Vec<Vec<usize>> = vec![Vec::new(); num_groups];
            for (bi, &g) in assignment.iter().enumerate() {
                merged[g].extend(blocks[bi].iter().copied());
            }
            out.push(
                Diagram::from_blocks(d.l, d.k, merged).expect("merged blocks partition [l+k]"),
            );
            return;
        }
        for g in 0..=num_groups.min(i) {
            assignment[i] = g;
            rec(
                i + 1,
                num_groups.max(g + 1),
                assignment,
                blocks,
                d,
                out,
            );
        }
    }
    if b == 0 {
        out.push(d.clone());
        return out;
    }
    rec(0, 0, &mut assignment, &blocks, d, &mut out);
    out
}

/// Möbius function `μ(π, σ)` of the partition lattice for `π ⪯ σ`
/// (σ a coarsening of π): `Π_{B ∈ σ} (−1)^{m_B−1} (m_B−1)!`.
pub fn mobius(fine: &Diagram, coarse: &Diagram) -> f64 {
    let fine_membership = fine.membership();
    let mut mu = 1.0;
    for block in coarse.blocks() {
        // Count distinct fine blocks inside this coarse block.
        let mut seen = std::collections::HashSet::new();
        for &v in block {
            seen.insert(fine_membership[v]);
        }
        let m = seen.len();
        // (−1)^{m−1} (m−1)!
        let mut term = 1.0;
        for i in 1..m {
            term *= -(i as f64);
        }
        mu *= term;
    }
    mu
}

/// Orbit matrix entry at `(I, J)`: 1 iff the equality pattern is exactly
/// the partition of `d`.
pub fn orbit_coeff(d: &Diagram, i_idx: &[usize], j_idx: &[usize]) -> f64 {
    let l = d.l;
    let at = |v: usize| if v < l { i_idx[v] } else { j_idx[v - l] };
    let blocks = d.blocks();
    // Equal within blocks…
    for b in blocks {
        let first = at(b[0]);
        for &v in &b[1..] {
            if at(v) != first {
                return 0.0;
            }
        }
    }
    // …and different across blocks.
    for a in 0..blocks.len() {
        for b in (a + 1)..blocks.len() {
            if at(blocks[a][0]) == at(blocks[b][0]) {
                return 0.0;
            }
        }
    }
    1.0
}

/// Materialise the orbit matrix `O_π` (naïve; test/baseline use).
pub fn materialize_orbit(d: &Diagram, n: usize) -> Matrix {
    let rows = n.pow(d.l as u32);
    let cols = n.pow(d.k as u32);
    let mut m = Matrix::zeros(rows, cols);
    let mut it_i = MultiIndexIter::new(n, d.l);
    let mut r = 0usize;
    while let Some(i_idx) = it_i.next_index() {
        let i_idx = i_idx.to_vec();
        let mut it_j = MultiIndexIter::new(n, d.k);
        let mut c = 0usize;
        while let Some(j_idx) = it_j.next_index() {
            let v = orbit_coeff(d, &i_idx, j_idx);
            if v != 0.0 {
                m.set(r, c, v);
            }
            c += 1;
        }
        r += 1;
    }
    m
}

/// Expand one orbit element over the diagram basis:
/// `O_π = Σ_{σ ⪰ π} μ(π, σ) D_σ`. Returns `(diagram, coefficient)` pairs.
pub fn orbit_to_diagram(d: &Diagram) -> Vec<(Diagram, f64)> {
    coarsenings(d)
        .into_iter()
        .map(|sigma| {
            let mu = mobius(d, &sigma);
            (sigma, mu)
        })
        .collect()
}

/// A pre-factored fast multiplier for one *orbit* basis element: the
/// Möbius expansion over diagram plans, applied term by term on the fast
/// path (each term `O(n^k)` instead of the naïve `O(n^{l+k})`).
#[derive(Debug, Clone)]
pub struct OrbitPlan {
    terms: Vec<(MultPlan, f64)>,
    l: usize,
    n: usize,
}

impl OrbitPlan {
    /// Build the plan for orbit element `d` over `R^n` (S_n only — the
    /// orbit basis is specific to the partition category).
    pub fn new(d: &Diagram, n: usize) -> Result<Self> {
        let mut terms = Vec::new();
        for (sigma, mu) in orbit_to_diagram(d) {
            // Coarsenings with more than n blocks have zero image under Θ
            // only if the original had ≤ n blocks… keep all terms; the
            // functor handles them correctly regardless.
            terms.push((MultPlan::new(Group::Symmetric, &sigma, n)?, mu));
        }
        Ok(OrbitPlan {
            terms,
            l: d.l,
            n,
        })
    }

    /// `O_π · v` on the fast path.
    pub fn apply(&self, v: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(self.n, self.l);
        for (plan, mu) in &self.terms {
            plan.apply_accumulate(v, *mu, &mut out)?;
        }
        Ok(out)
    }

    /// Number of diagram terms in the Möbius expansion.
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }
}

/// Naïve orbit matvec (baseline).
pub fn orbit_apply_naive(d: &Diagram, v: &Tensor) -> Tensor {
    let n = v.n;
    let mut out = Tensor::zeros(n, d.l);
    let mut it_i = MultiIndexIter::new(n, d.l);
    let mut fi = 0usize;
    while let Some(i_idx) = it_i.next_index() {
        let i_idx = i_idx.to_vec();
        let mut acc = 0.0;
        let mut it_j = MultiIndexIter::new(n, d.k);
        let mut fj = 0usize;
        while let Some(j_idx) = it_j.next_index() {
            let c = orbit_coeff(d, &i_idx, j_idx);
            if c != 0.0 {
                acc += c * v.data[fj];
            }
            fj += 1;
        }
        out.data[fi] = acc;
        fi += 1;
    }
    out
}

/// Fast orbit matvec through the Möbius expansion (one-shot convenience;
/// hold an [`OrbitPlan`] to amortise).
pub fn orbit_apply_fast(d: &Diagram, v: &Tensor) -> Result<Tensor> {
    OrbitPlan::new(d, v.n)?.apply(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diagram::{all_partition_diagrams, bell_bounded};
    use crate::functor::materialize;
    use crate::util::Rng;

    #[test]
    fn coarsening_counts_are_bell_numbers() {
        // A partition with b singleton blocks has Bell(b) coarsenings.
        let d = Diagram::from_blocks(2, 1, vec![vec![0], vec![1], vec![2]]).unwrap();
        assert_eq!(coarsenings(&d).len() as u128, bell_bounded(3, 3)); // 5
        let id = Diagram::identity(2); // 2 blocks
        assert_eq!(coarsenings(&id).len(), 2);
    }

    #[test]
    fn mobius_known_values() {
        // μ(π, π) = 1; merging two blocks gives −1; merging three gives 2.
        let fine = Diagram::from_blocks(2, 1, vec![vec![0], vec![1], vec![2]]).unwrap();
        assert_eq!(mobius(&fine, &fine), 1.0);
        let two = Diagram::from_blocks(2, 1, vec![vec![0, 1], vec![2]]).unwrap();
        assert_eq!(mobius(&fine, &two), -1.0);
        let one = Diagram::from_blocks(2, 1, vec![vec![0, 1, 2]]).unwrap();
        assert_eq!(mobius(&fine, &one), 2.0); // (−1)^2 · 2!
    }

    /// The defining identity: D_π = Σ_{σ ⪰ π} O_σ as matrices.
    #[test]
    fn diagram_is_sum_of_orbit_coarsenings() {
        let n = 3;
        for d in all_partition_diagrams(2, 2, None) {
            let dm = materialize(Group::Symmetric, &d, n).unwrap();
            let mut acc = Matrix::zeros(dm.rows, dm.cols);
            for sigma in coarsenings(&d) {
                let om = materialize_orbit(&sigma, n);
                for (a, b) in acc.data.iter_mut().zip(&om.data) {
                    *a += b;
                }
            }
            assert!(dm.max_abs_diff(&acc) < 1e-12, "failed for {d}");
        }
    }

    /// Möbius inversion: O_π = Σ μ(π,σ) D_σ as matrices.
    #[test]
    fn orbit_is_mobius_sum_of_diagrams() {
        let n = 3;
        for d in all_partition_diagrams(1, 2, None) {
            let om = materialize_orbit(&d, n);
            let mut acc = Matrix::zeros(om.rows, om.cols);
            for (sigma, mu) in orbit_to_diagram(&d) {
                let dm = materialize(Group::Symmetric, &sigma, n).unwrap();
                for (a, b) in acc.data.iter_mut().zip(&dm.data) {
                    *a += mu * b;
                }
            }
            assert!(om.max_abs_diff(&acc) < 1e-12, "failed for {d}");
        }
    }

    /// The payoff: orbit matvec on the fast path equals the naïve orbit
    /// matvec.
    #[test]
    fn orbit_fast_equals_naive() {
        let mut rng = Rng::new(0x0B17);
        let n = 3;
        for d in all_partition_diagrams(2, 2, None) {
            let v = Tensor::random(n, 2, &mut rng);
            let fast = orbit_apply_fast(&d, &v).unwrap();
            let slow = orbit_apply_naive(&d, &v);
            assert!(
                fast.allclose(&slow, 1e-9),
                "orbit mismatch for {d}: {}",
                fast.max_abs_diff(&slow)
            );
        }
    }

    /// Orbit basis elements are disjoint: for fixed (I, J) exactly one
    /// orbit matrix is non-zero, and summing all of them gives the all-ones
    /// matrix.
    #[test]
    fn orbit_elements_partition_index_space() {
        let n = 2;
        let all = all_partition_diagrams(1, 2, None);
        let mut sum = Matrix::zeros(n, n * n);
        for d in &all {
            let m = materialize_orbit(d, n);
            for (a, b) in sum.data.iter_mut().zip(&m.data) {
                *a += b;
            }
        }
        for &x in &sum.data {
            assert_eq!(x, 1.0);
        }
    }

    #[test]
    fn orbit_plan_reports_terms() {
        let d = Diagram::from_blocks(1, 1, vec![vec![0], vec![1]]).unwrap();
        let plan = OrbitPlan::new(&d, 3).unwrap();
        assert_eq!(plan.num_terms(), 2); // {0}{1} and {0,1}
    }
}
