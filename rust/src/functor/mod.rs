//! The monoidal functors Θ, Φ, X, Ψ materialised as explicit matrices —
//! the **naïve baseline** of the paper (`O(n^{l+k})` per matvec) and the
//! ground truth that every fast-path test compares against.
//!
//! For a `(k,l)`-diagram `d` and group `G(n)` the spanning matrix entry at
//! `(I, J)`, `I ∈ [n]^l`, `J ∈ [n]^k`, is:
//!
//! - **Θ (S_n, Theorem 5)** — `δ_{π,(I,J)}`: 1 iff the combined index is
//!   constant on every block of the partition.
//! - **Φ (O(n), Theorem 7)** — the same formula restricted to Brauer
//!   diagrams (every block a pair).
//! - **X (Sp(n), Theorem 9)** — a product of `γ` factors per pair: `δ` for
//!   cross-row pairs, the symplectic form `ε` (eqs. 24–25) for same-row
//!   pairs, read left-to-right within the pair.
//! - **Ψ (SO(n), Theorem 11)** — `Φ` on Brauer diagrams; on
//!   `(l+k)\n`-diagrams the entry is `det(e_T, e_B) · δ(pairs)` (eq. 31),
//!   the determinant being a Levi-Civita symbol over the free indices.

mod coeff;
pub mod orbit;

pub use coeff::{diagram_coeff, eps_symplectic, levi_civita};
pub use orbit::{orbit_apply_fast, orbit_to_diagram, OrbitPlan};

use crate::diagram::Diagram;
use crate::error::{Error, Result};
use crate::fastmult::Group;
use crate::linalg::Matrix;
use crate::tensor::{MultiIndexIter, Tensor};

/// Apply the spanning matrix of `d` to `v` by direct summation over all
/// `(I, J)` pairs — `O(n^{l+k})`, the paper's naïve baseline.
pub fn naive_apply(group: Group, d: &Diagram, v: &Tensor) -> Result<Tensor> {
    let n = v.n;
    d.validate_for(group, n)?;
    if v.order != d.k {
        return Err(Error::ShapeMismatch {
            expected: format!("input order {}", d.k),
            got: format!("{}", v.order),
        });
    }
    let mut out = Tensor::zeros(n, d.l);
    let membership = d.membership();
    let mut it_i = MultiIndexIter::new(n, d.l);
    let mut fi = 0usize;
    while let Some(i_idx) = it_i.next_index() {
        let i_idx = i_idx.to_vec();
        let mut acc = 0.0;
        let mut it_j = MultiIndexIter::new(n, d.k);
        let mut fj = 0usize;
        while let Some(j_idx) = it_j.next_index() {
            let c = diagram_coeff(group, d, &membership, &i_idx, j_idx, n);
            if c != 0.0 {
                acc += c * v.data[fj];
            }
            fj += 1;
        }
        out.data[fi] = acc;
        fi += 1;
    }
    Ok(out)
}

/// Materialise the full `n^l × n^k` spanning matrix of `d` under the
/// functor for `group`. Used by the functoriality / monoidality tests and
/// the layer-level naïve baseline.
pub fn materialize(group: Group, d: &Diagram, n: usize) -> Result<Matrix> {
    d.validate_for(group, n)?;
    let rows = n.pow(d.l as u32);
    let cols = n.pow(d.k as u32);
    let membership = d.membership();
    let mut m = Matrix::zeros(rows, cols);
    let mut it_i = MultiIndexIter::new(n, d.l);
    let mut r = 0usize;
    while let Some(i_idx) = it_i.next_index() {
        let i_idx = i_idx.to_vec();
        let mut it_j = MultiIndexIter::new(n, d.k);
        let mut c = 0usize;
        while let Some(j_idx) = it_j.next_index() {
            let v = diagram_coeff(group, d, &membership, &i_idx, j_idx, n);
            if v != 0.0 {
                m.set(r, c, v);
            }
            c += 1;
        }
        r += 1;
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diagram::{all_brauer_diagrams, all_partition_diagrams, compose, tensor_product};
    use crate::util::Rng;

    /// Functoriality (Theorem 27 Step 1): Θ(d2 • d1) = Θ(d2) Θ(d1), with
    /// the n^c scalar from the removed middle components.
    #[test]
    fn theta_functoriality_random() {
        let mut rng = Rng::new(101);
        let n = 2;
        for _ in 0..40 {
            let d1 = Diagram::random_partition(2, 2, &mut rng); // 2 -> 2
            let d2 = Diagram::random_partition(2, 2, &mut rng); // 2 -> 2
            let m1 = materialize(Group::Symmetric, &d1, n).unwrap();
            let m2 = materialize(Group::Symmetric, &d2, n).unwrap();
            let prod = m2.matmul(&m1).unwrap();
            let c = compose(&d2, &d1).unwrap();
            let mut want = materialize(Group::Symmetric, &c.diagram, n).unwrap();
            let scale = (n as f64).powi(c.removed_components as i32);
            for x in &mut want.data {
                *x *= scale;
            }
            assert!(
                prod.max_abs_diff(&want) < 1e-9,
                "functoriality failed: {d2} • {d1}"
            );
        }
    }

    /// Monoidality (Theorem 27 Step 3): Θ(d1 ⊗ d2) = Θ(d1) ⊗ Θ(d2).
    #[test]
    fn theta_monoidality_random() {
        let mut rng = Rng::new(102);
        let n = 2;
        for _ in 0..20 {
            let d1 = Diagram::random_partition(1, 2, &mut rng);
            let d2 = Diagram::random_partition(2, 1, &mut rng);
            let m1 = materialize(Group::Symmetric, &d1, n).unwrap();
            let m2 = materialize(Group::Symmetric, &d2, n).unwrap();
            let t = tensor_product(&d1, &d2);
            let mt = materialize(Group::Symmetric, &t, n).unwrap();
            // Kronecker product check, entry by entry.
            let (r1, c1) = (m1.rows, m1.cols);
            let (r2, c2) = (m2.rows, m2.cols);
            assert_eq!(mt.rows, r1 * r2);
            assert_eq!(mt.cols, c1 * c2);
            for a in 0..r1 {
                for b in 0..r2 {
                    for c in 0..c1 {
                        for e in 0..c2 {
                            let want = m1.get(a, c) * m2.get(b, e);
                            let got = mt.get(a * r2 + b, c * c2 + e);
                            assert!(
                                (want - got).abs() < 1e-12,
                                "kron mismatch at ({a},{b},{c},{e})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Φ functoriality on Brauer diagrams: Φ(d2 • d1) = Φ(d2) Φ(d1).
    #[test]
    fn phi_functoriality_brauer() {
        let n = 2;
        for d1 in all_brauer_diagrams(2, 2) {
            for d2 in all_brauer_diagrams(2, 2) {
                let m1 = materialize(Group::Orthogonal, &d1, n).unwrap();
                let m2 = materialize(Group::Orthogonal, &d2, n).unwrap();
                let prod = m2.matmul(&m1).unwrap();
                let c = compose(&d2, &d1).unwrap();
                // Composition of Brauer diagrams is Brauer again.
                let mut want = materialize(Group::Orthogonal, &c.diagram, n).unwrap();
                let scale = (n as f64).powi(c.removed_components as i32);
                for x in &mut want.data {
                    *x *= scale;
                }
                assert!(prod.max_abs_diff(&want) < 1e-9);
            }
        }
    }

    #[test]
    fn identity_diagram_is_identity_matrix() {
        for group in [Group::Symmetric, Group::Orthogonal] {
            let d = Diagram::identity(2);
            let m = materialize(group, &d, 3).unwrap();
            assert!(m.max_abs_diff(&Matrix::identity(9)) < 1e-14);
        }
        // Sp identity: cross pairs are δ, so also the identity matrix.
        let d = Diagram::identity(2);
        let m = materialize(Group::Symplectic, &d, 2).unwrap();
        assert!(m.max_abs_diff(&Matrix::identity(4)) < 1e-14);
    }

    #[test]
    fn naive_apply_matches_materialized_matvec() {
        let mut rng = Rng::new(103);
        let n = 3;
        for d in all_partition_diagrams(2, 2, None) {
            let v = Tensor::random(n, 2, &mut rng);
            let fast = naive_apply(Group::Symmetric, &d, &v).unwrap();
            let m = materialize(Group::Symmetric, &d, n).unwrap();
            let mv = m.matvec(&v.data).unwrap();
            assert!(fast
                .data
                .iter()
                .zip(&mv)
                .all(|(a, b)| (a - b).abs() < 1e-10));
        }
    }

    #[test]
    fn shape_validation() {
        let d = Diagram::identity(2);
        let v = Tensor::zeros(3, 3); // wrong order
        assert!(naive_apply(Group::Symmetric, &d, &v).is_err());
        // non-Brauer diagram for O(n)
        let p = Diagram::from_blocks(1, 1, vec![vec![0], vec![1]]).unwrap();
        let v1 = Tensor::zeros(3, 1);
        assert!(naive_apply(Group::Orthogonal, &p, &v1).is_err());
    }
}
