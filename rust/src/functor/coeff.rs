//! Matrix-entry coefficient functions for the four monoidal functors.

use crate::diagram::{BlockKind, Diagram};
use crate::fastmult::Group;

/// Symplectic form `ε` in the interleaved symplectic basis
/// `1, 1', 2, 2', …, m, m'` (0-based: `2i ↔ i+1`, `2i+1 ↔ (i+1)'`):
/// `ε_{α,β'} = δ_{α,β}`, `ε_{α',β} = -δ_{α,β}`, `ε_{α,β} = ε_{α',β'} = 0`
/// (eqs. 24–25).
#[inline]
pub fn eps_symplectic(a: usize, b: usize) -> f64 {
    if a / 2 != b / 2 {
        0.0
    } else if a % 2 == 0 && b == a + 1 {
        1.0
    } else if a % 2 == 1 && b + 1 == a {
        -1.0
    } else {
        0.0
    }
}

/// Levi-Civita symbol of an index tuple: the sign of the permutation if the
/// entries are a permutation of `0..len`, 0 otherwise. For a tuple of basis
/// indices `(t_1…t_s, b_1…b_{n-s})` this equals `det(e_T, e_B)` (eq. 32).
pub fn levi_civita(idx: &[usize]) -> f64 {
    let n = idx.len();
    let mut seen = vec![false; n];
    for &i in idx {
        if i >= n || seen[i] {
            return 0.0;
        }
        seen[i] = true;
    }
    // Count inversions (n is small — the free-vertex count equals the
    // representation dimension, so this is at most ~8 in practice).
    let mut sign = 1.0;
    for a in 0..n {
        for b in (a + 1)..n {
            if idx[a] > idx[b] {
                sign = -sign;
            }
        }
    }
    sign
}

/// The combined index of vertex `v`: top vertices read `I`, bottom read `J`.
#[inline]
fn vertex_index(d: &Diagram, i_idx: &[usize], j_idx: &[usize], v: usize) -> usize {
    if v < d.l {
        i_idx[v]
    } else {
        j_idx[v - d.l]
    }
}

/// Matrix entry of the spanning matrix of `d` at `(I, J)` for `group`.
///
/// `membership` must be `d.membership()` (hoisted by the callers since it
/// is shared across all `(I, J)`).
pub fn diagram_coeff(
    group: Group,
    d: &Diagram,
    membership: &[usize],
    i_idx: &[usize],
    j_idx: &[usize],
    n: usize,
) -> f64 {
    match group {
        Group::Symmetric | Group::Orthogonal => {
            // δ_{π,(I,J)} (eq. 13): constant on every block.
            let _ = membership;
            for b in d.blocks() {
                let first = vertex_index(d, i_idx, j_idx, b[0]);
                for &v in &b[1..] {
                    if vertex_index(d, i_idx, j_idx, v) != first {
                        return 0.0;
                    }
                }
            }
            1.0
        }
        Group::Symplectic => {
            // Product of γ factors per pair (eq. 23), left-to-right order
            // within same-row pairs.
            let mut prod = 1.0;
            for b in d.blocks() {
                debug_assert_eq!(b.len(), 2);
                let (x, y) = (b[0], b[1]);
                let (ix, iy) = (
                    vertex_index(d, i_idx, j_idx, x),
                    vertex_index(d, i_idx, j_idx, y),
                );
                let gamma = match d.block_kind(b) {
                    BlockKind::Cross => {
                        if ix == iy {
                            1.0
                        } else {
                            0.0
                        }
                    }
                    BlockKind::Top | BlockKind::Bottom => eps_symplectic(ix, iy),
                };
                if gamma == 0.0 {
                    return 0.0;
                }
                prod *= gamma;
            }
            prod
        }
        Group::SpecialOrthogonal => {
            if d.is_brauer() {
                // E_β: same as Φ.
                return diagram_coeff(Group::Orthogonal, d, membership, i_idx, j_idx, n);
            }
            // H_α (eq. 31): det(e_T, e_B) over the free indices times δ on
            // the pairs. T = free top vertices left→right, B = free bottom
            // vertices left→right.
            let mut free_idx: Vec<usize> = Vec::new();
            let mut free_top: Vec<usize> = Vec::new();
            let mut free_bottom: Vec<usize> = Vec::new();
            for b in d.blocks() {
                if b.len() == 1 {
                    if b[0] < d.l {
                        free_top.push(b[0]);
                    } else {
                        free_bottom.push(b[0]);
                    }
                } else {
                    let first = vertex_index(d, i_idx, j_idx, b[0]);
                    if vertex_index(d, i_idx, j_idx, b[1]) != first {
                        return 0.0;
                    }
                }
            }
            free_top.sort_unstable();
            free_bottom.sort_unstable();
            for &v in free_top.iter().chain(free_bottom.iter()) {
                free_idx.push(vertex_index(d, i_idx, j_idx, v));
            }
            debug_assert_eq!(free_idx.len(), n);
            levi_civita(&free_idx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eps_values() {
        // n = 4 (m = 2): pairs (0,1) and (2,3).
        assert_eq!(eps_symplectic(0, 1), 1.0);
        assert_eq!(eps_symplectic(1, 0), -1.0);
        assert_eq!(eps_symplectic(2, 3), 1.0);
        assert_eq!(eps_symplectic(3, 2), -1.0);
        assert_eq!(eps_symplectic(0, 2), 0.0);
        assert_eq!(eps_symplectic(0, 0), 0.0);
        assert_eq!(eps_symplectic(1, 3), 0.0);
    }

    #[test]
    fn eps_antisymmetric() {
        for a in 0..6 {
            for b in 0..6 {
                assert_eq!(eps_symplectic(a, b), -eps_symplectic(b, a));
            }
        }
    }

    #[test]
    fn levi_civita_values() {
        assert_eq!(levi_civita(&[0, 1, 2]), 1.0);
        assert_eq!(levi_civita(&[1, 0, 2]), -1.0);
        assert_eq!(levi_civita(&[2, 0, 1]), 1.0);
        assert_eq!(levi_civita(&[0, 0, 1]), 0.0);
        assert_eq!(levi_civita(&[]), 1.0);
    }

    #[test]
    fn levi_civita_matches_det_of_permutation_matrix() {
        use crate::linalg::Matrix;
        let perms: [Vec<usize>; 3] = [vec![0, 1, 2, 3], vec![3, 1, 2, 0], vec![1, 2, 3, 0]];
        for p in perms {
            let mut m = Matrix::zeros(4, 4);
            for (col, &row) in p.iter().enumerate() {
                m.set(row, col, 1.0);
            }
            assert!((levi_civita(&p) - m.det()).abs() < 1e-12, "{p:?}");
        }
    }
}
