//! Coordinator assembly: queue → batcher → supervised worker pool, plus
//! the client handle.
//!
//! Fault-tolerance duties live here (see `docs/serving_robustness.md`):
//! batch execution runs under `catch_unwind` with a per-item fallback so
//! one poisoned input cannot take down its batch-mates; a panicked worker
//! recycles itself and the **supervisor** respawns it with capped
//! exponential backoff; `submit` validates the route and tensor shape at
//! the door and enforces per-model admission control; `infer` is a
//! bounded wait whenever a request deadline is configured — no client
//! ever hangs on a response that will never come.
//!
//! The silent-failure defenses (`super::integrity`) hook in behind
//! off-by-default config knobs: numeric canaries and sampled shadow
//! verification screen responses at the output boundary, a hung-batch
//! watchdog piggybacks on the supervisor tick, and a memory-pressure
//! brownout degrades execution instead of letting the arena grow past
//! its budget. With the knobs off the batch path is untouched.

use super::batcher::{self, Batch, BatchQueue, PopWait, WorkItem};
use super::integrity::{self, Brownout, BrownoutCtl, BrownoutLevel, Heartbeats, Verifier};
use super::metrics::{Metrics, MetricsSnapshot};
use super::registry::{ModelKind, Registry};
use crate::config::ServerConfig;
use crate::error::{Error, Result};
use crate::fastmult::PlanCache;
use crate::nn::EquivariantNet;
use crate::tensor::{Precision, Tensor};
use crate::util::executor;
use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// First respawn delay after a worker panic; doubles per consecutive
/// restart of the same slot.
const BACKOFF_BASE: Duration = Duration::from_millis(5);
/// Ceiling on the respawn delay.
const BACKOFF_CAP: Duration = Duration::from_millis(200);
/// A worker that survives this long resets its slot's backoff.
const BACKOFF_HEALTHY_RESET: Duration = Duration::from_secs(1);
/// Backoff sleeps are sliced so shutdown (queue drained) is never stalled
/// behind a pending respawn.
const BACKOFF_SLICE: Duration = Duration::from_millis(5);
/// Extra slack `infer` waits past the request deadline before giving up
/// client-side: the server sheds on the same clock, so within the grace
/// window the typed outcome it delivers (response, error, or shed) wins
/// over a locally synthesised `DeadlineExceeded`.
const DEADLINE_GRACE: Duration = Duration::from_millis(50);

/// RAII admission slot for one in-flight request on one model: dropping
/// the guard releases the slot. The guard travels inside the `WorkItem`,
/// so *every* terminal path — response delivered, typed error delivered,
/// shed, or the item dropped on the floor during shutdown — releases it
/// without any path having to remember to.
pub(crate) struct InflightGuard(Arc<AtomicUsize>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// Per-model admission control: at most `limit` requests in flight per
/// route, so one hot model cannot starve the shared queue.
struct Admission {
    limit: usize,
    inflight: HashMap<String, Arc<AtomicUsize>>,
}

impl Admission {
    fn new(limit: usize, routes: &[&str]) -> Self {
        Admission {
            limit,
            inflight: routes
                .iter()
                .map(|r| (r.to_string(), Arc::new(AtomicUsize::new(0))))
                .collect(),
        }
    }

    /// Try to take a slot for `model`; `None` means the route is at its
    /// inflight limit (the caller sheds with [`Error::Overloaded`]).
    fn try_acquire(&self, model: &str) -> Option<InflightGuard> {
        let counter = self.inflight.get(model)?;
        let mut current = counter.load(Ordering::Relaxed);
        loop {
            if current >= self.limit {
                return None;
            }
            match counter.compare_exchange_weak(
                current,
                current + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(InflightGuard(counter.clone())),
                Err(actual) => current = actual,
            }
        }
    }
}

/// Builder for the serving engine: register models, then [`Coordinator::start`].
#[derive(Debug)]
pub struct Coordinator {
    config: ServerConfig,
    registry: Registry,
    brownout_f32: bool,
}

impl Default for Coordinator {
    fn default() -> Self {
        Coordinator::new(ServerConfig::default())
    }
}

impl Coordinator {
    /// New coordinator with the given serving config.
    pub fn new(config: ServerConfig) -> Self {
        Coordinator {
            config,
            registry: Registry::default(),
            brownout_f32: true,
        }
    }

    /// Precision policy for the brownout's deepest stage
    /// (`[model] brownout_f32`, default on): when `false` the
    /// memory-pressure brownout stops at shrunken-budget tiled walks and
    /// never narrows a model's inputs to `f32`.
    pub fn set_brownout_f32(&mut self, allow: bool) {
        self.brownout_f32 = allow;
    }

    /// Register a model under a route name.
    pub fn register(&mut self, name: &str, model: ModelKind) {
        self.registry.insert(name, model);
    }

    /// Registered route names (for startup logging / introspection).
    pub fn routes(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Spawn the batcher, worker pool, and supervisor; returns the client
    /// handle.
    pub fn start(self) -> CoordinatorHandle {
        // The plan cache is process-wide, so only an explicitly configured
        // bound is applied — a coordinator started with defaults must not
        // clobber a bound another embedder chose.
        if let Some(capacity) = self.config.plan_cache_capacity {
            PlanCache::global().set_capacity(capacity);
        }
        // Workers fan batches out via parallel_map; budget the per-call
        // fan-out so `workers × fan-out` stays at one thread per core.
        // (Raw hardware parallelism, NOT max_threads(): the latter already
        // applies any budget a previous coordinator set.) The prior budget
        // is restored when the handle shuts down.
        let prior_thread_budget = crate::util::parallel::thread_budget();
        let hw = executor::hw_threads();
        crate::util::parallel::set_thread_budget((hw / self.config.workers.max(1)).max(1));
        let metrics = Arc::new(Metrics::default());
        if let Some(target) = self.config.target_p95 {
            metrics.set_target_p95(target);
        }
        let (req_tx, req_rx) = mpsc::sync_channel::<WorkItem>(self.config.queue_capacity);
        let dispatch = BatchQueue::new();
        let registry = Arc::new(self.registry);
        let admission = self
            .config
            .max_inflight_per_model
            .map(|limit| Admission::new(limit, &registry.names()));
        let workers = self.config.workers.max(1);
        // Every defense is `None`/`false` at the default config, so the
        // knobs-off batch path carries no stamping, sampling, or extra
        // allocation.
        let policy = Arc::new(ServingPolicy {
            numeric_guard: self.config.numeric_guard,
            verifier: (self.config.verify_per_mille > 0)
                .then(|| Arc::new(Verifier::new(self.config.verify_per_mille))),
            heartbeats: (self.config.watchdog_factor > 0.0)
                .then(|| Arc::new(Heartbeats::new(workers))),
            watchdog_factor: self.config.watchdog_factor,
            request_timeout: self.config.request_timeout,
            brownout: self
                .config
                .arena_budget_bytes
                .map(|budget| Arc::new(BrownoutCtl::new(budget, self.brownout_f32))),
        });

        let mut threads: Vec<JoinHandle<()>> = Vec::new();
        {
            let metrics = metrics.clone();
            let dispatch = dispatch.clone();
            let max_batch = self.config.max_batch;
            let window = self.config.batch_window;
            let target_p95 = self.config.target_p95;
            threads.push(std::thread::spawn(move || {
                batcher::run(req_rx, dispatch, metrics, max_batch, window, target_p95)
            }));
        }
        {
            let reg = registry.clone();
            let metrics = metrics.clone();
            let policy = policy.clone();
            threads.push(std::thread::spawn(move || {
                supervisor_loop(dispatch, reg, metrics, workers, policy)
            }));
        }

        CoordinatorHandle {
            sender: Some(req_tx),
            metrics,
            registry,
            admission,
            request_timeout: self.config.request_timeout,
            threads,
            prior_thread_budget,
        }
    }
}

/// Why a worker's loop returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WorkerExit {
    /// The dispatch queue is closed and drained: orderly shutdown, no
    /// replacement needed.
    Clean,
    /// The worker hit a panic (caught at the batch boundary or escaped to
    /// the thread wrapper) and recycles itself: thread-local state is
    /// suspect after an unwind through model code, so a fresh thread
    /// replaces it — the supervisor respawns unless the queue drained.
    Recycled,
    /// The hung-batch watchdog superseded this incarnation while its
    /// batch ran: the waiters were already shed with
    /// [`Error::BatchStuck`] and a replacement slot task spawned, so the
    /// supervisor only decrements the live count — respawning again
    /// would double the slot.
    Superseded,
}

/// Off-by-default silent-failure defenses shared by every worker slot of
/// one coordinator (see `super::integrity`). At the default config every
/// field is `false`/`None` and `run_batch` behaves exactly as before.
struct ServingPolicy {
    numeric_guard: bool,
    verifier: Option<Arc<Verifier>>,
    heartbeats: Option<Arc<Heartbeats>>,
    watchdog_factor: f64,
    request_timeout: Option<Duration>,
    brownout: Option<Arc<BrownoutCtl>>,
}

impl ServingPolicy {
    /// Output-boundary screening for one served result: the numeric
    /// canary turns a non-finite answer into a typed
    /// [`Error::NumericFault`] (its finite batch-mates pass untouched),
    /// and the shadow sampler re-executes its deterministic fraction of
    /// the healthy answers on executor spare capacity. `shadow` is
    /// `false` for browned-out responses — the brownout deliberately
    /// changes the numerics (shrunken tiles, f32 casts), and spending
    /// reference forwards while under memory pressure would deepen the
    /// pressure that triggered it.
    fn screen(
        &self,
        route: &str,
        model: &ModelKind,
        input: &Tensor,
        result: Result<Tensor>,
        metrics: &Arc<Metrics>,
        shadow: bool,
    ) -> Result<Tensor> {
        let out = match result {
            Ok(t) => t,
            err => return err,
        };
        if self.numeric_guard && integrity::non_finite(&out) {
            metrics.on_numeric_fault();
            return Err(Error::NumericFault(format!(
                "non-finite element in a '{route}' response"
            )));
        }
        if shadow {
            if let Some(verifier) = &self.verifier {
                if verifier.should_sample() {
                    let verifier = verifier.clone();
                    let model = model.clone();
                    let input = input.clone();
                    let served = out.clone();
                    let metrics = metrics.clone();
                    let route = route.to_string();
                    executor::global().spawn(move || {
                        verifier.verify(&route, &model, &input, &served, &metrics)
                    });
                }
            }
        }
        Ok(out)
    }
}

struct WorkerEvent {
    slot: usize,
    exit: WorkerExit,
}

/// Everything one worker slot needs, cloned into each of its task
/// incarnations on the shared executor. Cloning is a handful of `Arc`
/// bumps plus a channel-sender clone.
#[derive(Clone)]
struct WorkerCtx {
    slot: usize,
    queue: Arc<BatchQueue>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    policy: Arc<ServingPolicy>,
    events: mpsc::Sender<WorkerEvent>,
}

/// Why one executor-task incarnation of a worker slot returned.
enum WorkerStep {
    /// The queue was idle for a full slice: re-submit the slot so other
    /// tasks sharing the executor (other coordinators, `parallel_map`
    /// fan-outs) get the thread in between.
    Yield,
    /// The slot is done (queue drained, or recycling after a panic); the
    /// supervisor gets the exit event.
    Exit(WorkerExit),
}

/// How long an idle worker slot occupies an executor thread before
/// yielding it. Batch pickup latency is unaffected — the slot sleeps on
/// the queue condvar inside the slice and wakes the moment a batch lands.
const WORKER_IDLE_SLICE: Duration = Duration::from_millis(10);

/// Queue one incarnation of a worker slot on the process-wide executor.
/// Replaces the per-worker `std::thread::spawn`: slots are now tasks on
/// the shared pool, so a panicked slot's replacement costs a queue push,
/// not a thread spawn.
fn spawn_worker(ctx: WorkerCtx) {
    executor::global().spawn(move || worker_task(ctx));
}

/// One executor-task incarnation of a worker slot. Belt and braces: the
/// slice already catches panics at the batch boundary; this wrapper
/// catches anything that escapes it so the supervisor always receives an
/// exit event and the pool never silently shrinks.
fn worker_task(ctx: WorkerCtx) {
    match catch_unwind(AssertUnwindSafe(|| worker_slice(&ctx))) {
        Ok(WorkerStep::Yield) => spawn_worker(ctx),
        Ok(WorkerStep::Exit(exit)) => {
            let _ = ctx.events.send(WorkerEvent {
                slot: ctx.slot,
                exit,
            });
        }
        Err(_) => {
            let _ = ctx.events.send(WorkerEvent {
                slot: ctx.slot,
                exit: WorkerExit::Recycled,
            });
        }
    }
}

/// Supervise the worker pool: spawn the initial slot tasks, then respawn
/// any slot that recycled after a panic, with capped exponential backoff
/// per slot (base 5ms, cap 200ms, reset after 1s of health). Backoff is
/// tracked as a per-slot **due time** rather than an inline sleep, so one
/// slot waiting out its backoff never delays another slot's exit event or
/// respawn — the event channel keeps draining throughout. Exits when
/// every slot has exited, no respawn pends, and the drained queue means
/// none needs a replacement.
///
/// The hung-batch watchdog and the memory-pressure brownout piggyback on
/// this loop's tick (the 50ms event timeout doubles as their sweep
/// cadence) instead of costing a thread each; both are no-ops unless
/// their knobs are set.
fn supervisor_loop(
    queue: Arc<BatchQueue>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    workers: usize,
    policy: Arc<ServingPolicy>,
) {
    let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
    let ctxs: Vec<WorkerCtx> = (0..workers)
        .map(|slot| WorkerCtx {
            slot,
            queue: queue.clone(),
            registry: registry.clone(),
            metrics: metrics.clone(),
            policy: policy.clone(),
            events: event_tx.clone(),
        })
        .collect();
    // The brownout machine samples the arena's peak-since-last-tick (and
    // resets the watermark each tick): the in-use figure collapses to ~0
    // between batches, so the instantaneous reading would race the very
    // pressure it is supposed to observe. The watermark is only consumed
    // this way when `[server] arena_budget_bytes` is set.
    let mut brownout: Option<(Arc<BrownoutCtl>, Brownout)> = policy.brownout.clone().map(|ctl| {
        crate::fastmult::reset_arena_peak();
        let machine = Brownout::new(ctl.budget_bytes, ctl.allow_f32);
        (ctl, machine)
    });
    let mut restarts = vec![0u32; workers];
    let mut spawned_at: Vec<Instant> = Vec::with_capacity(workers);
    let mut respawn_due: Vec<Option<Instant>> = vec![None; workers];
    for ctx in &ctxs {
        spawn_worker(ctx.clone());
        spawned_at.push(Instant::now());
    }
    let mut alive = workers;
    while alive > 0 || respawn_due.iter().any(Option::is_some) {
        // Wait for the next exit event, but never past the earliest due
        // respawn (sliced so a shutdown arriving mid-backoff is honoured).
        let timeout = match respawn_due.iter().flatten().min() {
            None => Duration::from_millis(50),
            Some(due) => due
                .saturating_duration_since(Instant::now())
                .min(BACKOFF_SLICE),
        };
        match event_rx.recv_timeout(timeout) {
            Ok(event) => {
                alive -= 1;
                // `Superseded` slots were already replaced by the
                // watchdog the moment they were reaped; only a panic
                // recycle schedules a respawn here.
                if event.exit == WorkerExit::Recycled && !queue.is_drained() {
                    // A long-healthy worker's crash is fresh news, not a
                    // crash loop.
                    if spawned_at[event.slot].elapsed() >= BACKOFF_HEALTHY_RESET {
                        restarts[event.slot] = 0;
                    }
                    let backoff =
                        BACKOFF_CAP.min(BACKOFF_BASE * 2u32.pow(restarts[event.slot].min(16)));
                    restarts[event.slot] = restarts[event.slot].saturating_add(1);
                    respawn_due[event.slot] = Some(Instant::now() + backoff);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break, // unreachable: ctxs hold senders
        }
        // Watchdog sweep: supersede slots whose batch outlived the live
        // threshold, shedding every waiter with a typed `BatchStuck`.
        // The sweep runs even while the queue drains (a wedged batch
        // still owes its waiters an answer), but replacements are only
        // spawned while there is work left to serve.
        if let Some(hb) = &policy.heartbeats {
            if let Some(threshold) = integrity::watchdog_threshold(
                policy.watchdog_factor,
                metrics.batch_exec_p99_s(),
                policy.request_timeout,
            ) {
                for slot in hb.reap(threshold, &metrics) {
                    if queue.is_drained() {
                        continue;
                    }
                    metrics.on_worker_restart();
                    spawn_worker(ctxs[slot].clone());
                    spawned_at[slot] = Instant::now();
                    alive += 1;
                }
            }
        }
        // Brownout tick: feed the hysteresis machine one footprint
        // observation and publish any transition to the workers and the
        // metrics gauge. Engagements count Normal → Tiled only; a later
        // escalation to f32 deepens the same brownout.
        if let Some((ctl, machine)) = &mut brownout {
            let footprint = crate::fastmult::arena_peak_bytes();
            crate::fastmult::reset_arena_peak();
            if let Some(level) = machine.observe(footprint) {
                ctl.set_level(level);
                metrics.set_brownout_state(level as u64);
                match level {
                    BrownoutLevel::Normal => metrics.on_brownout_recovered(),
                    BrownoutLevel::Tiled => metrics.on_brownout_engaged(),
                    BrownoutLevel::TiledF32 => {}
                }
            }
        }
        if queue.is_drained() {
            // Shutdown: pending respawns are moot, nothing to execute.
            for due in &mut respawn_due {
                *due = None;
            }
            continue;
        }
        let now = Instant::now();
        for slot in 0..workers {
            if respawn_due[slot].is_some_and(|due| due <= now) {
                respawn_due[slot] = None;
                metrics.on_worker_restart();
                spawn_worker(ctxs[slot].clone());
                spawned_at[slot] = Instant::now();
                alive += 1;
            }
        }
    }
}

/// Registry errors fan out to every item in the batch; `ModelNotFound`
/// survives intact (it is the one registry lookup error), anything else
/// flattens with its message preserved.
fn clone_lookup_error(e: &Error) -> Error {
    match e {
        Error::ModelNotFound(name) => Error::ModelNotFound(name.clone()),
        other => Error::Coordinator(other.to_string()),
    }
}

/// Best-effort human-readable panic payload.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Pull batches off the shared queue and execute them until the queue
/// goes idle (yield the executor thread), drains (clean exit), or a batch
/// panics (recycle). Shed points and panic isolation:
/// - expired items are shed **before execution** (no wasted schedule
///   walks);
/// - the whole-batch fast path runs under `catch_unwind`; if it panics,
///   every item re-runs individually (also under `catch_unwind`), so the
///   one poisoned input gets a typed [`Error::WorkerPanic`] while its
///   batch-mates still get real responses;
/// - after a batch-level panic the worker finishes delivering outcomes and
///   then recycles itself ([`WorkerExit::Recycled`]) — a fresh slot task
///   replaces it, since state is suspect after unwinding through model
///   code.
fn worker_slice(ctx: &WorkerCtx) -> WorkerStep {
    loop {
        let batch = match ctx.queue.pop_for(WORKER_IDLE_SLICE) {
            PopWait::Batch(b) => b,
            PopWait::Idle => return WorkerStep::Yield,
            PopWait::Drained => return WorkerStep::Exit(WorkerExit::Clean),
        };
        if let Some(exit) = run_batch(batch, ctx) {
            return WorkerStep::Exit(exit);
        }
    }
}

/// Execute one batch, delivering a terminal outcome to every item.
/// `Some(exit)` means the slot must stop (recycle after a batch panic,
/// or retire quietly after the watchdog superseded it); `None` means it
/// can pull the next batch.
fn run_batch(batch: Batch, ctx: &WorkerCtx) -> Option<WorkerExit> {
    let metrics = &ctx.metrics;
    let policy = &ctx.policy;
    let items = batcher::shed_expired(batch.items, metrics, Instant::now());
    if items.is_empty() {
        return None;
    }
    let model = match ctx.registry.get(&batch.model) {
        Ok(m) => m,
        Err(e) => {
            for item in items {
                metrics.on_complete(item.enqueued.elapsed(), false);
                let _ = item.respond.send(Err(clone_lookup_error(&e)));
            }
            return None;
        }
    };
    // Brownout detour: under memory pressure, native models run per item
    // through shrunken-tile-budget schedule walks (narrowed to f32 at
    // the deepest stage) instead of the fused full-budget path.
    if let Some(ctl) = &policy.brownout {
        let level = ctl.level();
        if level != BrownoutLevel::Normal {
            if let Some((net, precision)) = model.as_net() {
                let net = net.clone();
                return run_brownout_batch(&batch.model, &net, precision, level, ctl, model, items, ctx);
            }
        }
    }
    // Heartbeat stamp: registers the waiters so the watchdog can shed
    // them if this batch wedges. One stamp per batch, only when the
    // watchdog knob is on.
    let heartbeat = policy
        .heartbeats
        .as_ref()
        .map(|hb| (hb, hb.start(ctx.slot, &items)));
    // One plan, many inputs: the whole batch is packed into contiguous
    // `[B, n^k]` BatchTensors inside the model's batched path and each
    // layer schedule is walked once per worker span — per-item errors
    // stay per-item (malformed batches fall back to per-item
    // forwards). Fused-execution stats surface in the metrics
    // snapshot (`fused_batches` / `fused_items`).
    let t0 = Instant::now();
    let outcome = {
        let inputs: Vec<&Tensor> = items.iter().map(|it| &it.input).collect();
        catch_unwind(AssertUnwindSafe(|| model.infer_batch(&inputs)))
    };
    if let Some((hb, epoch)) = heartbeat {
        if !hb.finish(ctx.slot, epoch) {
            // The watchdog superseded this incarnation mid-batch: the
            // waiters already received `BatchStuck` and a replacement
            // slot task is running — deliver nothing, count nothing,
            // retire quietly.
            return Some(WorkerExit::Superseded);
        }
    }
    match outcome {
        Ok(results) => {
            metrics.on_batch_executed(t0.elapsed());
            for (item, result) in items.into_iter().zip(results) {
                let result = policy.screen(&batch.model, model, &item.input, result, metrics, true);
                let ok = result.is_ok();
                metrics.on_complete(item.enqueued.elapsed(), ok);
                let _ = item.respond.send(result);
            }
            None
        }
        Err(_) => {
            metrics.on_batch_panic();
            // Per-item fallback: isolate the poisoned input. Deadlines
            // are re-checked per item — the fallback is serial, so a
            // generous batch's tail may expire while its head re-runs.
            for item in items {
                if item.expired(Instant::now()) {
                    metrics.on_shed_expired();
                    let _ = item.respond.send(Err(Error::DeadlineExceeded));
                    continue;
                }
                let result = match catch_unwind(AssertUnwindSafe(|| model.infer(&item.input))) {
                    Ok(r) => r,
                    Err(payload) => Err(Error::WorkerPanic(panic_message(&*payload))),
                };
                let result = policy.screen(&batch.model, model, &item.input, result, metrics, true);
                let ok = result.is_ok();
                metrics.on_complete(item.enqueued.elapsed(), ok);
                let _ = item.respond.send(result);
            }
            Some(WorkerExit::Recycled)
        }
    }
}

/// Browned-out execution of one batch: per-item forwards through the
/// route's shrunken-tile-budget schedules (compiled once, cached on the
/// [`BrownoutCtl`]), with inputs narrowed to `f32` at the deepest level.
/// Responses are still canary-screened, but skip shadow verification —
/// the brownout deliberately changes the numerics, and reference
/// forwards would deepen the memory pressure that engaged it.
#[allow(clippy::too_many_arguments)]
fn run_brownout_batch(
    route: &str,
    net: &Arc<EquivariantNet>,
    precision: Precision,
    level: BrownoutLevel,
    ctl: &Arc<BrownoutCtl>,
    model: &ModelKind,
    items: Vec<WorkItem>,
    ctx: &WorkerCtx,
) -> Option<WorkerExit> {
    let metrics = &ctx.metrics;
    let schedules = match ctl.schedules_for(route, net) {
        Ok(s) => s,
        Err(e) => {
            let msg = format!("brownout schedule compile failed: {e}");
            for item in items {
                metrics.on_complete(item.enqueued.elapsed(), false);
                let _ = item.respond.send(Err(Error::Coordinator(msg.clone())));
            }
            return None;
        }
    };
    let t0 = Instant::now();
    let mut panicked = false;
    for item in items {
        if item.expired(Instant::now()) {
            metrics.on_shed_expired();
            let _ = item.respond.send(Err(Error::DeadlineExceeded));
            continue;
        }
        let result = match catch_unwind(AssertUnwindSafe(|| {
            integrity::brownout_infer(net, precision, level, &schedules, &item.input)
        })) {
            Ok(r) => r,
            Err(payload) => {
                panicked = true;
                Err(Error::WorkerPanic(panic_message(&*payload)))
            }
        };
        let result = ctx.policy.screen(route, model, &item.input, result, metrics, false);
        let ok = result.is_ok();
        metrics.on_complete(item.enqueued.elapsed(), ok);
        let _ = item.respond.send(result);
    }
    metrics.on_batch_executed(t0.elapsed());
    if panicked {
        Some(WorkerExit::Recycled)
    } else {
        None
    }
}

/// Client handle to a running coordinator.
pub struct CoordinatorHandle {
    sender: Option<SyncSender<WorkItem>>,
    metrics: Arc<Metrics>,
    registry: Arc<Registry>,
    admission: Option<Admission>,
    request_timeout: Option<Duration>,
    threads: Vec<JoinHandle<()>>,
    /// Fan-out cap in force before this coordinator started; restored on
    /// drop so the process regains whatever parallelism policy it had.
    prior_thread_budget: usize,
}

impl CoordinatorHandle {
    /// Submit a request; returns a receiver for the response. Rejections
    /// happen at the door, each with a typed error: unknown route
    /// ([`Error::ModelNotFound`]), tensor shape not matching the
    /// registered model ([`Error::BadRequest`]), route at its inflight
    /// limit ([`Error::Overloaded`]), or queue full (backpressure). An
    /// accepted request is stamped with its deadline (when
    /// `[server] request_timeout_ms` is set) and is guaranteed exactly one
    /// terminal outcome on the returned receiver — a response, a typed
    /// error, or a deadline shed.
    pub fn submit(&self, model: &str, input: Tensor) -> Result<Receiver<Result<Tensor>>> {
        let sender = self
            .sender
            .as_ref()
            .ok_or_else(|| Error::Coordinator("coordinator is shut down".into()))?;
        let kind = match self.registry.get(model) {
            Ok(k) => k,
            Err(e) => {
                self.metrics.on_door_reject();
                return Err(e);
            }
        };
        if let Some((n, k)) = kind.expected_shape() {
            if input.n != n || input.order != k {
                self.metrics.on_door_reject();
                return Err(Error::BadRequest(format!(
                    "model '{model}' expects order-{k} tensors over R^{n}, \
                     got order-{} over R^{}",
                    input.order, input.n
                )));
            }
        }
        let inflight = match &self.admission {
            None => None,
            Some(admission) => match admission.try_acquire(model) {
                Some(guard) => Some(guard),
                None => {
                    self.metrics.on_shed_admission();
                    return Err(Error::Overloaded {
                        model: model.to_string(),
                    });
                }
            },
        };
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let item = WorkItem {
            model: model.to_string(),
            input,
            enqueued: now,
            deadline: self.request_timeout.map(|t| now + t),
            respond: tx,
            inflight,
        };
        match sender.try_send(item) {
            Ok(()) => {
                self.metrics.on_accept();
                Ok(rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.on_reject();
                Err(Error::Coordinator("queue full (backpressure)".into()))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(Error::Coordinator("coordinator is shut down".into()))
            }
        }
    }

    /// Blocking inference: submit and wait. With a configured request
    /// timeout this is a **bounded** wait: it waits out the deadline plus
    /// a small grace (preferring whatever typed outcome the server
    /// delivers) and then returns [`Error::DeadlineExceeded`] — a client
    /// can no longer hang on a response that will never come.
    pub fn infer(&self, model: &str, input: Tensor) -> Result<Tensor> {
        let deadline = self.request_timeout.map(|t| Instant::now() + t);
        let rx = self.submit(model, input)?;
        match deadline {
            None => rx
                .recv()
                .map_err(|_| Error::Coordinator("worker dropped the response".into()))?,
            Some(d) => {
                let wait = d.saturating_duration_since(Instant::now()) + DEADLINE_GRACE;
                match rx.recv_timeout(wait) {
                    Ok(result) => result,
                    Err(RecvTimeoutError::Timeout) => Err(Error::DeadlineExceeded),
                    Err(RecvTimeoutError::Disconnected) => {
                        Err(Error::Coordinator("worker dropped the response".into()))
                    }
                }
            }
        }
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: close the queue and join all threads. Any
    /// chaos plans wrapped around registered models are cancelled first,
    /// so an in-progress injected stall cuts its sleep short instead of
    /// delaying the join.
    pub fn shutdown(mut self) {
        self.sender.take(); // close the channel -> batcher + workers exit
        self.registry.cancel_chaos();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.sender.take();
        self.registry.cancel_chaos();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Restore the fan-out cap that was in force before this
        // coordinator started, so later work in the process (training,
        // standalone forward_batch, an embedder-set cap) regains its prior
        // parallelism policy. (With overlapping coordinators the last
        // change wins — the budget is process-global by design.)
        crate::util::parallel::set_thread_budget(self.prior_thread_budget);
    }
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]

    use super::*;
    use crate::fastmult::Group;
    use crate::layer::Init;
    use crate::nn::{Activation, EquivariantNet};
    use crate::util::Rng;

    fn test_net(rng: &mut Rng) -> EquivariantNet {
        EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            rng,
        )
        .unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let mut rng = Rng::new(501);
        let net = test_net(&mut rng);
        let reference = net.clone();
        let mut coord = Coordinator::new(ServerConfig {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(100),
            queue_capacity: 64,
            ..ServerConfig::default()
        });
        coord.register("m", ModelKind::net(net));
        let handle = coord.start();
        for _ in 0..20 {
            let v = Tensor::random(3, 2, &mut rng);
            let got = handle.infer("m", v.clone()).unwrap();
            let want = reference.forward(&v).unwrap();
            assert!(got.allclose(&want, 1e-12));
        }
        let snap = handle.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        // End-to-end latency percentiles are live and ordered.
        assert!(snap.p50_latency_s > 0.0);
        assert!(snap.p50_latency_s <= snap.p95_latency_s);
        assert!(snap.p95_latency_s <= snap.p99_latency_s);
        handle.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let mut rng = Rng::new(502);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(test_net(&mut rng)));
        let handle = coord.start();
        let err = handle.infer("nope", Tensor::zeros(3, 2));
        assert!(matches!(err, Err(Error::ModelNotFound(ref name)) if name == "nope"));
        let snap = handle.metrics();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.requests, 0, "door rejection must not count as accepted");
        handle.shutdown();
    }

    #[test]
    fn bad_shape_rejected_at_door() {
        let mut rng = Rng::new(505);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(test_net(&mut rng))); // expects (3, 2)
        let handle = coord.start();
        // Wrong order.
        let err = handle.infer("m", Tensor::zeros(3, 1));
        assert!(matches!(err, Err(Error::BadRequest(_))), "got {err:?}");
        // Wrong n.
        let err = handle.infer("m", Tensor::zeros(4, 2));
        assert!(matches!(err, Err(Error::BadRequest(_))), "got {err:?}");
        let snap = handle.metrics();
        assert_eq!(snap.failed, 2);
        assert_eq!(snap.requests, 0);
        // A correctly shaped request still flows.
        handle.infer("m", Tensor::zeros(3, 2)).unwrap();
        assert_eq!(handle.metrics().completed, 1);
        handle.shutdown();
    }

    #[test]
    fn concurrent_submissions() {
        let mut rng = Rng::new(503);
        let net = test_net(&mut rng);
        let mut coord = Coordinator::new(ServerConfig {
            workers: 4,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            queue_capacity: 256,
            ..ServerConfig::default()
        });
        coord.register("m", ModelKind::net(net));
        let handle = Arc::new(coord.start());
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(600 + t);
                for _ in 0..25 {
                    let v = Tensor::random(3, 2, &mut rng);
                    h.infer("m", v).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = handle.metrics();
        assert_eq!(snap.completed, 100);
        assert!(snap.batches >= 1);
        assert!(snap.mean_batch_size >= 1.0);
        // Every batch went through the batched execution path, and the
        // uniform batches took the fused `[B, n^k]` walk.
        assert!(snap.batch_execs >= 1);
        assert!(snap.mean_batch_exec_s >= 0.0);
        assert!(snap.fused_batches >= 1);
        assert!(snap.fused_items >= 1);
    }

    #[test]
    fn shutdown_is_clean() {
        let mut rng = Rng::new(504);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(test_net(&mut rng)));
        let handle = coord.start();
        handle.shutdown(); // must not hang
    }

    #[test]
    fn defense_knobs_default_off() {
        let mut rng = Rng::new(506);
        let net = test_net(&mut rng);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(net));
        let handle = coord.start();
        for _ in 0..5 {
            handle.infer("m", Tensor::random(3, 2, &mut rng)).unwrap();
        }
        let snap = handle.metrics();
        assert_eq!(snap.completed, 5);
        // No knob set: no canary trips, no sampling, no watchdog, and
        // the brownout gauge stays at its normal level.
        assert_eq!(snap.numeric_faults, 0);
        assert_eq!(snap.shadow_verifications, 0);
        assert_eq!(snap.integrity_mismatches, 0);
        assert_eq!(snap.watchdog_kills, 0);
        assert_eq!(snap.schedule_recompiles, 0);
        assert_eq!(snap.degraded_models, 0);
        assert_eq!(snap.brownout_state, 0);
        assert_eq!(snap.brownout_state_name(), "normal");
        assert_eq!(snap.brownout_engagements, 0);
        handle.shutdown();
    }

    #[test]
    fn admission_guard_releases_slot_on_drop() {
        let admission = Admission::new(1, &["m"]);
        let g1 = admission.try_acquire("m").expect("first slot");
        assert!(admission.try_acquire("m").is_none(), "limit is 1");
        drop(g1);
        assert!(
            admission.try_acquire("m").is_some(),
            "slot must free on guard drop"
        );
        // Unknown routes (never registered) have no slots to give.
        assert!(admission.try_acquire("ghost").is_none());
    }
}
