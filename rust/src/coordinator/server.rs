//! Coordinator assembly: queue → batcher → worker pool, plus the client
//! handle.

use super::batcher::{self, Batch, WorkItem};
use super::metrics::{Metrics, MetricsSnapshot};
use super::registry::{ModelKind, Registry};
use crate::config::ServerConfig;
use crate::error::{Error, Result};
use crate::fastmult::PlanCache;
use crate::tensor::Tensor;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Builder for the serving engine: register models, then [`Coordinator::start`].
#[derive(Debug, Default)]
pub struct Coordinator {
    config: ServerConfig,
    registry: Registry,
}

impl Coordinator {
    /// New coordinator with the given serving config.
    pub fn new(config: ServerConfig) -> Self {
        Coordinator {
            config,
            registry: Registry::default(),
        }
    }

    /// Register a model under a route name.
    pub fn register(&mut self, name: &str, model: ModelKind) {
        self.registry.insert(name, model);
    }

    /// Registered route names (for startup logging / introspection).
    pub fn routes(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Spawn the batcher and worker threads; returns the client handle.
    pub fn start(self) -> CoordinatorHandle {
        // The plan cache is process-wide, so only an explicitly configured
        // bound is applied — a coordinator started with defaults must not
        // clobber a bound another embedder chose.
        if let Some(capacity) = self.config.plan_cache_capacity {
            PlanCache::global().set_capacity(capacity);
        }
        // Workers fan batches out via parallel_map; budget the per-call
        // fan-out so `workers × fan-out` stays at one thread per core.
        // (Raw hardware parallelism, NOT max_threads(): the latter already
        // applies any budget a previous coordinator set.) The prior budget
        // is restored when the handle shuts down.
        let prior_thread_budget = crate::util::parallel::thread_budget();
        let hw = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        crate::util::parallel::set_thread_budget((hw / self.config.workers.max(1)).max(1));
        let metrics = Arc::new(Metrics::default());
        let (req_tx, req_rx) = mpsc::sync_channel::<WorkItem>(self.config.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let registry = Arc::new(self.registry);

        let mut threads: Vec<JoinHandle<()>> = Vec::new();
        {
            let metrics = metrics.clone();
            let max_batch = self.config.max_batch;
            let window = self.config.batch_window;
            threads.push(std::thread::spawn(move || {
                batcher::run(req_rx, batch_tx, metrics, max_batch, window)
            }));
        }
        for _ in 0..self.config.workers {
            let rx = batch_rx.clone();
            let reg = registry.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || worker_loop(rx, reg, metrics)));
        }

        CoordinatorHandle {
            sender: Some(req_tx),
            metrics,
            threads,
            prior_thread_budget,
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Batch>>>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // batcher gone: shutdown
            }
        };
        let model = registry.get(&batch.model);
        // One plan, many inputs: the whole batch is packed into contiguous
        // `[B, n^k]` BatchTensors inside the model's batched path and each
        // layer schedule is walked once per worker span — per-item errors
        // stay per-item (malformed batches fall back to per-item
        // forwards). Fused-execution stats surface in the metrics
        // snapshot (`fused_batches` / `fused_items`).
        let results: Vec<Result<Tensor>> = match &model {
            Ok(m) => {
                let t0 = Instant::now();
                let inputs: Vec<&Tensor> = batch.items.iter().map(|it| &it.input).collect();
                let results = m.infer_batch(&inputs);
                metrics.on_batch_executed(t0.elapsed());
                results
            }
            Err(e) => batch
                .items
                .iter()
                .map(|_| Err(Error::Coordinator(e.to_string())))
                .collect(),
        };
        for (item, result) in batch.items.into_iter().zip(results) {
            let ok = result.is_ok();
            metrics.on_complete(item.enqueued.elapsed(), ok);
            let _ = item.respond.send(result);
        }
    }
}

/// Client handle to a running coordinator.
pub struct CoordinatorHandle {
    sender: Option<SyncSender<WorkItem>>,
    metrics: Arc<Metrics>,
    threads: Vec<JoinHandle<()>>,
    /// Fan-out cap in force before this coordinator started; restored on
    /// drop so the process regains whatever parallelism policy it had.
    prior_thread_budget: usize,
}

impl CoordinatorHandle {
    /// Submit a request; returns a receiver for the response. Fails fast
    /// with a backpressure error if the queue is full.
    pub fn submit(&self, model: &str, input: Tensor) -> Result<Receiver<Result<Tensor>>> {
        let (tx, rx) = mpsc::channel();
        let item = WorkItem {
            model: model.to_string(),
            input,
            enqueued: Instant::now(),
            respond: tx,
        };
        let sender = self
            .sender
            .as_ref()
            .ok_or_else(|| Error::Coordinator("coordinator is shut down".into()))?;
        match sender.try_send(item) {
            Ok(()) => {
                self.metrics.on_accept();
                Ok(rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.on_reject();
                Err(Error::Coordinator("queue full (backpressure)".into()))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(Error::Coordinator("coordinator is shut down".into()))
            }
        }
    }

    /// Blocking inference: submit and wait.
    pub fn infer(&self, model: &str, input: Tensor) -> Result<Tensor> {
        let rx = self.submit(model, input)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("worker dropped the response".into()))?
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: close the queue and join all threads.
    pub fn shutdown(mut self) {
        self.sender.take(); // close the channel -> batcher + workers exit
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.sender.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Restore the fan-out cap that was in force before this
        // coordinator started, so later work in the process (training,
        // standalone forward_batch, an embedder-set cap) regains its prior
        // parallelism policy. (With overlapping coordinators the last
        // change wins — the budget is process-global by design.)
        crate::util::parallel::set_thread_budget(self.prior_thread_budget);
    }
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]

    use super::*;
    use crate::fastmult::Group;
    use crate::layer::Init;
    use crate::nn::{Activation, EquivariantNet};
    use crate::util::Rng;
    use std::time::Duration;

    fn test_net(rng: &mut Rng) -> EquivariantNet {
        EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            rng,
        )
        .unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let mut rng = Rng::new(501);
        let net = test_net(&mut rng);
        let reference = net.clone();
        let mut coord = Coordinator::new(ServerConfig {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(100),
            queue_capacity: 64,
            ..ServerConfig::default()
        });
        coord.register("m", ModelKind::net(net));
        let handle = coord.start();
        for _ in 0..20 {
            let v = Tensor::random(3, 2, &mut rng);
            let got = handle.infer("m", v.clone()).unwrap();
            let want = reference.forward(&v).unwrap();
            assert!(got.allclose(&want, 1e-12));
        }
        let snap = handle.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        handle.shutdown();
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let mut rng = Rng::new(502);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(test_net(&mut rng)));
        let handle = coord.start();
        let err = handle.infer("nope", Tensor::zeros(3, 2));
        assert!(err.is_err());
        assert_eq!(handle.metrics().failed, 1);
        handle.shutdown();
    }

    #[test]
    fn concurrent_submissions() {
        let mut rng = Rng::new(503);
        let net = test_net(&mut rng);
        let mut coord = Coordinator::new(ServerConfig {
            workers: 4,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            queue_capacity: 256,
            ..ServerConfig::default()
        });
        coord.register("m", ModelKind::net(net));
        let handle = Arc::new(coord.start());
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(600 + t);
                for _ in 0..25 {
                    let v = Tensor::random(3, 2, &mut rng);
                    h.infer("m", v).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = handle.metrics();
        assert_eq!(snap.completed, 100);
        assert!(snap.batches >= 1);
        assert!(snap.mean_batch_size >= 1.0);
        // Every batch went through the batched execution path, and the
        // uniform batches took the fused `[B, n^k]` walk.
        assert!(snap.batch_execs >= 1);
        assert!(snap.mean_batch_exec_s >= 0.0);
        assert!(snap.fused_batches >= 1);
        assert!(snap.fused_items >= 1);
    }

    #[test]
    fn shutdown_is_clean() {
        let mut rng = Rng::new(504);
        let mut coord = Coordinator::new(ServerConfig::default());
        coord.register("m", ModelKind::net(test_net(&mut rng)));
        let handle = coord.start();
        handle.shutdown(); // must not hang
    }
}
