//! Serving metrics: counters and latency aggregates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink updated by the batcher and workers.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    latency: Mutex<LatencyAgg>,
}

#[derive(Debug, Default)]
struct LatencyAgg {
    total_s: f64,
    max_s: f64,
    count: u64,
}

/// Point-in-time snapshot of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queue.
    pub requests: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that returned an error.
    pub failed: u64,
    /// Requests rejected by backpressure (queue full).
    pub rejected: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Mean items per batch.
    pub mean_batch_size: f64,
    /// Mean end-to-end latency (seconds).
    pub mean_latency_s: f64,
    /// Max end-to-end latency (seconds).
    pub max_latency_s: f64,
}

impl Metrics {
    /// Record an accepted request.
    pub fn on_accept(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a backpressure rejection.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a dispatched batch of `size` items.
    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }
    /// Record a completed request with its end-to-end latency.
    pub fn on_complete(&self, latency: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let mut agg = self.latency.lock().unwrap();
        let s = latency.as_secs_f64();
        agg.total_s += s;
        agg.count += 1;
        if s > agg.max_s {
            agg.max_s = s;
        }
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let agg = self.latency.lock().unwrap();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            mean_latency_s: if agg.count > 0 {
                agg.total_s / agg.count as f64
            } else {
                0.0
            },
            max_latency_s: agg.max_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.on_accept();
        m.on_accept();
        m.on_reject();
        m.on_batch(2);
        m.on_complete(Duration::from_millis(10), true);
        m.on_complete(Duration::from_millis(30), false);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((s.mean_latency_s - 0.020).abs() < 1e-6);
        assert!((s.max_latency_s - 0.030).abs() < 1e-6);
    }
}
