//! Serving metrics: counters, lock-free log-bucketed latency histograms
//! (p50/p95/p99 for request end-to-end and whole-batch execution),
//! robustness counters (sheds, worker restarts, caught panics), plan/
//! schedule-cache effectiveness and scratch-arena health.

use crate::fastmult::{arena_stats, exec_stats, ops_shared_total, planner_totals, PlanCache};
use crate::nn::fused_batch_stats;
use crate::util::executor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Sub-bucket resolution of the latency histograms: each power-of-two
/// octave is split into `2^SUB_BITS` linear sub-buckets, bounding the
/// relative quantile error at ~`1/2^SUB_BITS` (≈6% here) — the classic
/// log-linear (HdrHistogram-style) layout, sized so one histogram is a
/// few KiB of atomics.
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// Nanosecond values below this index directly (exact small-value path).
const LINEAR_MAX: u64 = 2 * SUB as u64;
/// Octaves above the linear range; covers every representable `u64` ns
/// (`2^63` ns ≈ 292 years) without saturating a real measurement.
const OCTAVES: usize = 60;
const NUM_BUCKETS: usize = LINEAR_MAX as usize + OCTAVES * SUB;

fn bucket_index(ns: u64) -> usize {
    if ns < LINEAR_MAX {
        return ns as usize;
    }
    let octave = 63 - ns.leading_zeros();
    let sub = ((ns >> (octave - SUB_BITS)) as usize) & (SUB - 1);
    let idx = LINEAR_MAX as usize + ((octave - (SUB_BITS + 1)) as usize) * SUB + sub;
    idx.min(NUM_BUCKETS - 1)
}

/// Representative value (bucket midpoint) in nanoseconds.
fn bucket_value_ns(idx: usize) -> f64 {
    if (idx as u64) < LINEAR_MAX {
        return idx as f64;
    }
    let g = idx - LINEAR_MAX as usize;
    let octave = SUB_BITS + (g / SUB) as u32 + 1;
    let sub = (g % SUB) as u64;
    let width = 1u64 << (octave - SUB_BITS);
    let lower = (1u64 << octave) + sub * width;
    lower as f64 + width as f64 / 2.0
}

/// Lock-free latency histogram: log-bucketed atomic counters plus exact
/// running mean/max. Recording is a handful of relaxed atomic ops — no
/// mutex anywhere, so a panicking recorder can never poison an unrelated
/// thread's metrics path (the old `Mutex<LatencyAgg>` could).
#[derive(Debug)]
pub(crate) struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

/// Point-in-time percentile/mean/max readout of one histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyStats {
    /// Samples recorded.
    pub count: u64,
    /// Exact mean (seconds).
    pub mean_s: f64,
    /// Exact max (seconds).
    pub max_s: f64,
    /// Median (seconds, bucket midpoint — ≈6% relative resolution).
    pub p50_s: f64,
    /// 95th percentile (seconds).
    pub p95_s: f64,
    /// 99th percentile (seconds).
    pub p99_s: f64,
}

impl LatencyHistogram {
    fn record(&self, elapsed: Duration) {
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    fn stats(&self) -> LatencyStats {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        // Sum the snapshot rather than reading `count`: concurrent
        // recorders may have bumped one but not the other, and the
        // quantile walk must be consistent with its own totals.
        let total: u64 = counts.iter().sum();
        let quantile = |q: f64| -> f64 {
            if total == 0 {
                return 0.0;
            }
            let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                if cum >= rank {
                    return bucket_value_ns(i) * 1e-9;
                }
            }
            bucket_value_ns(NUM_BUCKETS - 1) * 1e-9
        };
        let count = self.count.load(Ordering::Relaxed);
        LatencyStats {
            count,
            mean_s: if count > 0 {
                self.total_ns.load(Ordering::Relaxed) as f64 * 1e-9 / count as f64
            } else {
                0.0
            },
            max_s: self.max_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            p50_s: quantile(0.50),
            p95_s: quantile(0.95),
            p99_s: quantile(0.99),
        }
    }
}

/// Shared metrics sink updated by the batcher, the workers and the
/// supervisor. Every recording path is atomic — no mutex to poison.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    shed_expired: AtomicU64,
    shed_admission: AtomicU64,
    worker_restarts: AtomicU64,
    batch_panics: AtomicU64,
    latency: LatencyHistogram,
    /// Wall time of whole-batch model executions (the batched fast path),
    /// as opposed to `latency` which is per-request end-to-end.
    batch_exec: LatencyHistogram,
    /// Current batch window in nanoseconds — a gauge published by the
    /// batcher (fixed value, or the live value of the SLO-adaptive
    /// controller when `target_p95_ms` is set).
    batch_window_ns: AtomicU64,
    /// Configured p95 target in nanoseconds (`0` = adaptive window off).
    target_p95_ns: AtomicU64,
    /// Non-finite outputs caught by the `numeric_guard` canary.
    numeric_faults: AtomicU64,
    /// Batches shed by the hung-batch watchdog (slot respawned).
    watchdog_kills: AtomicU64,
    /// Sampled shadow verifications executed against the reference path.
    shadow_verifications: AtomicU64,
    /// Shadow verifications that disagreed with the fused answer.
    integrity_mismatches: AtomicU64,
    /// Schedules recompiled (and re-verified) after a quarantine.
    schedule_recompiles: AtomicU64,
    /// Brownout level gauge: 0 = Normal, 1 = Tiled, 2 = TiledF32.
    brownout_state: AtomicU64,
    /// Times the brownout engaged (left Normal).
    brownout_engagements: AtomicU64,
    /// Times the brownout fully recovered back to Normal.
    brownout_recoveries: AtomicU64,
    /// Models flagged degraded by the integrity verifier (gauge).
    degraded_models: AtomicU64,
}

/// Point-in-time snapshot of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queue.
    pub requests: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that returned an error (worker-side failures plus typed
    /// door rejections: unknown model, bad shape).
    pub failed: u64,
    /// Requests rejected by backpressure (queue full).
    pub rejected: u64,
    /// Requests shed because their deadline expired before execution
    /// (batcher pre-dispatch or worker pre-execution shed points).
    pub shed_expired: u64,
    /// Requests shed by per-model admission control
    /// (`max_inflight_per_model`).
    pub shed_admission: u64,
    /// Workers respawned by the supervisor after a panic recycled them.
    pub worker_restarts: u64,
    /// Batch executions whose panic was caught and fell back to per-item
    /// execution.
    pub batch_panics: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Mean items per batch.
    pub mean_batch_size: f64,
    /// Mean end-to-end latency (seconds).
    pub mean_latency_s: f64,
    /// Max end-to-end latency (seconds).
    pub max_latency_s: f64,
    /// Median end-to-end latency (seconds; log-bucketed, ≈6% resolution).
    pub p50_latency_s: f64,
    /// 95th-percentile end-to-end latency (seconds).
    pub p95_latency_s: f64,
    /// 99th-percentile end-to-end latency (seconds).
    pub p99_latency_s: f64,
    /// Batches executed by workers (the batched model path).
    pub batch_execs: u64,
    /// Mean wall time of one whole-batch execution (seconds).
    pub mean_batch_exec_s: f64,
    /// Max wall time of one whole-batch execution (seconds).
    pub max_batch_exec_s: f64,
    /// Median whole-batch execution time (seconds).
    pub p50_batch_exec_s: f64,
    /// 95th-percentile whole-batch execution time (seconds).
    pub p95_batch_exec_s: f64,
    /// 99th-percentile whole-batch execution time (seconds).
    pub p99_batch_exec_s: f64,
    /// Global plan-cache hits (process-wide, see
    /// [`crate::fastmult::PlanCache`]).
    pub plan_cache_hits: u64,
    /// Global plan-cache misses (`Factor` runs).
    pub plan_cache_misses: u64,
    /// Fraction of plan lookups served from the cache.
    pub plan_cache_hit_rate: f64,
    /// Compiled-schedule cache hits (one lookup per layer construction).
    pub schedule_cache_hits: u64,
    /// Compiled-schedule cache misses (schedule compilations).
    pub schedule_cache_misses: u64,
    /// Interior ops elided by schedule CSE (per forward pass, summed over
    /// every compiled schedule).
    pub ops_shared: u64,
    /// Interior DAG nodes actually materialised across all schedule walks
    /// (process-wide, see [`crate::fastmult::exec_stats`]).
    pub executed_nodes: u64,
    /// Folded multi-pattern scatter passes executed across all schedule
    /// walks — one per active `(node, pattern)` class per forward.
    pub scatter_passes: u64,
    /// **Measured** bytes moved by the schedule kernels across all walks —
    /// accumulated from actual element counts (active members, real batch
    /// sizes) at execution time, next to the compile-time
    /// `schedule_estimated_bytes`. Saturating.
    pub measured_bytes_moved: u64,
    /// Compile-time planner totals over every compiled schedule: distinct
    /// interior nodes after global CSE.
    pub schedule_nodes: u64,
    /// Folded `(node, pattern)` classes over every compiled schedule (the
    /// scatter-pass count of one forward through everything compiled).
    pub schedule_classes: u64,
    /// Cost-model flops of one forward walk, summed over compiled
    /// schedules.
    pub schedule_estimated_flops: u64,
    /// Cost-model bytes moved by one forward walk, summed over compiled
    /// schedules.
    pub schedule_estimated_bytes: u64,
    /// Aggregate fraction of interior ops eliminated by CSE across every
    /// compiled schedule (`1 - nodes / chain_ops`).
    pub schedule_sharing_ratio: f64,
    /// Scratch-arena buffers allocated fresh from the heap (stops growing
    /// once serving reaches steady state — the zero-allocation invariant).
    pub arena_allocations: u64,
    /// Scratch-arena acquisitions served by recycling.
    pub arena_reuses: u64,
    /// High-water mark of `f64`s held by any single scratch arena.
    pub arena_high_water_f64s: u64,
    /// Index-scratch buffers (odometer/ref-count vectors, node-slot
    /// tables) allocated fresh — stops growing at steady state, the
    /// index-scratch half of the zero-allocation invariant.
    pub arena_index_allocations: u64,
    /// Index-scratch acquisitions served by recycling.
    pub arena_index_reuses: u64,
    /// High-water mark of scratch-arena bytes resident at once across all
    /// threads (see [`crate::fastmult::arena_peak_bytes`]) — the number the
    /// tiled schedule walk exists to keep near the cache budget instead of
    /// the full `n^k` intermediate footprint.
    pub arena_peak_bytes: u64,
    /// Cache-blocked chains streamed tile-by-tile across all schedule
    /// walks (process-wide, see [`crate::fastmult::exec_stats`]).
    pub tiled_chains: u64,
    /// Whole batches executed through the batched model path — the fused
    /// `[B, n^k]` walk (one schedule walk per layer per worker span) for
    /// multi-item batches, the DAG-subtree fan-out for single-item ones
    /// (process-wide, see [`crate::nn::fused_batch_stats`]).
    pub fused_batches: u64,
    /// Items those fused batches contained.
    pub fused_items: u64,
    /// Mean items per fused batch.
    pub mean_fused_batch_size: f64,
    /// Current batch window (seconds) — the live value of the SLO-adaptive
    /// controller, or the fixed configured window.
    pub batch_window_s: f64,
    /// Configured p95 target (seconds; `0.0` = adaptive window off).
    pub target_p95_s: f64,
    /// Plans dropped by the plan cache's LRU bound.
    pub plan_cache_evictions: u64,
    /// Compiled schedules dropped by the schedule cache's LRU bound.
    pub schedule_cache_evictions: u64,
    /// Shards the process-wide plan cache splits its key space over.
    pub plan_cache_shards: u64,
    /// Per-shard plan hit rate (hits / lookups; `0.0` for an idle shard),
    /// indexed by shard — skew here means one hot key class is serialising
    /// on a single shard mutex.
    pub plan_cache_shard_hit_rates: Vec<f64>,
    /// Threads in the process-wide work-stealing executor.
    pub executor_workers: u64,
    /// Tasks stolen from another worker's deque.
    pub executor_steals: u64,
    /// Times an executor worker parked on the idle condvar.
    pub executor_parks: u64,
    /// Tasks submitted through the executor's global injector.
    pub executor_injector_pushes: u64,
    /// Total tasks the executor ran (workers plus helping callers).
    pub executor_executed: u64,
    /// Non-finite outputs caught by the `numeric_guard` canary (each
    /// converted into a typed [`crate::Error::NumericFault`] instead of a
    /// silent wrong answer).
    pub numeric_faults: u64,
    /// Batches the hung-batch watchdog shed: waiters got
    /// [`crate::Error::BatchStuck`] and the pinned worker slot respawned.
    pub watchdog_kills: u64,
    /// Sampled requests re-executed through the per-term reference path
    /// (`verify_per_mille`).
    pub shadow_verifications: u64,
    /// Shadow verifications whose reference answer disagreed with the
    /// fused one — each quarantined the suspect cached schedules.
    pub integrity_mismatches: u64,
    /// Compiled schedules evicted by integrity quarantine (process-wide,
    /// see [`crate::fastmult::CacheStats::schedule_quarantines`]).
    pub schedule_quarantines: u64,
    /// Schedules recompiled and re-verified after a quarantine.
    pub schedule_recompiles: u64,
    /// Memory-pressure brownout level: 0 = Normal, 1 = Tiled (forced
    /// shrunken-tile walks), 2 = TiledF32 (plus f32 casting where the
    /// model's policy allows).
    pub brownout_state: u64,
    /// Times the brownout engaged (left Normal).
    pub brownout_engagements: u64,
    /// Times the brownout fully recovered back to Normal.
    pub brownout_recoveries: u64,
    /// Models currently flagged degraded by the integrity verifier.
    pub degraded_models: u64,
    /// Scratch-arena bytes checked out right now (the live figure the
    /// brownout compares against `arena_budget_bytes`).
    pub arena_in_use_bytes: u64,
}

impl MetricsSnapshot {
    /// Human-readable name of the brownout level gauge.
    pub fn brownout_state_name(&self) -> &'static str {
        match self.brownout_state {
            0 => "normal",
            1 => "tiled",
            _ => "tiled-f32",
        }
    }
}

impl Metrics {
    /// Record an accepted request.
    pub fn on_accept(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a backpressure rejection.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a typed rejection at the door (unknown model, bad shape):
    /// the request never entered the queue but did fail.
    pub fn on_door_reject(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a request shed because its deadline expired.
    pub fn on_shed_expired(&self) {
        self.shed_expired.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a request shed by per-model admission control.
    pub fn on_shed_admission(&self) {
        self.shed_admission.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a worker respawn (supervisor).
    pub fn on_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a caught batch-execution panic (per-item fallback taken).
    pub fn on_batch_panic(&self) {
        self.batch_panics.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a dispatched batch of `size` items.
    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }
    /// Record one whole-batch model execution taking `elapsed`.
    pub fn on_batch_executed(&self, elapsed: Duration) {
        self.batch_exec.record(elapsed);
    }
    /// Record a completed request with its end-to-end latency.
    pub fn on_complete(&self, latency: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.record(latency);
    }
    /// Publish the current batch window (batcher gauge).
    pub fn set_batch_window(&self, window: Duration) {
        let ns = window.as_nanos().min(u64::MAX as u128) as u64;
        self.batch_window_ns.store(ns, Ordering::Relaxed);
    }
    /// Publish the configured p95 target (coordinator start-up gauge).
    pub fn set_target_p95(&self, target: Duration) {
        let ns = target.as_nanos().min(u64::MAX as u128) as u64;
        self.target_p95_ns.store(ns, Ordering::Relaxed);
    }
    /// Live end-to-end p95 in seconds (`0.0` until a request completes).
    /// Cheap enough for the adaptive-window controller's ~10 Hz polls:
    /// one pass over a few hundred relaxed atomic loads, no locks.
    pub(crate) fn latency_p95_s(&self) -> f64 {
        self.latency.stats().p95_s
    }
    /// Live p99 of whole-batch execution time in seconds (`0.0` until a
    /// batch runs) — the base the watchdog threshold multiplies.
    pub(crate) fn batch_exec_p99_s(&self) -> f64 {
        self.batch_exec.stats().p99_s
    }
    /// Record a non-finite output caught by the numeric guard.
    pub fn on_numeric_fault(&self) {
        self.numeric_faults.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a batch shed by the hung-batch watchdog.
    pub fn on_watchdog_kill(&self) {
        self.watchdog_kills.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one sampled shadow verification (clean or not).
    pub fn on_shadow_verification(&self) {
        self.shadow_verifications.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a shadow-verification mismatch (quarantine trigger).
    pub fn on_integrity_mismatch(&self) {
        self.integrity_mismatches.fetch_add(1, Ordering::Relaxed);
    }
    /// Record `count` schedules recompiled after a quarantine.
    pub fn on_schedule_recompiles(&self, count: u64) {
        self.schedule_recompiles.fetch_add(count, Ordering::Relaxed);
    }
    /// Publish the brownout level gauge (0 Normal / 1 Tiled / 2 TiledF32).
    pub fn set_brownout_state(&self, level: u64) {
        self.brownout_state.store(level, Ordering::Relaxed);
    }
    /// Record a brownout engagement (left Normal).
    pub fn on_brownout_engaged(&self) {
        self.brownout_engagements.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a full brownout recovery (back to Normal).
    pub fn on_brownout_recovered(&self) {
        self.brownout_recoveries.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a model newly flagged degraded by the verifier.
    pub fn on_model_degraded(&self) {
        self.degraded_models.fetch_add(1, Ordering::Relaxed);
    }

    /// Take a snapshot (includes the process-wide plan-cache counters).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.stats();
        let exec = self.batch_exec.stats();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let cache = PlanCache::global().stats();
        let shard_hit_rates: Vec<f64> = PlanCache::global()
            .shard_stats()
            .iter()
            .map(|s| {
                let lookups = s.hits + s.misses;
                if lookups > 0 {
                    s.hits as f64 / lookups as f64
                } else {
                    0.0
                }
            })
            .collect();
        let pool = executor::global_stats();
        let arena = arena_stats();
        let fused = fused_batch_stats();
        let sched_exec = exec_stats();
        let planner = planner_totals();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed_expired: self.shed_expired.load(Ordering::Relaxed),
            shed_admission: self.shed_admission.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            batch_panics: self.batch_panics.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            mean_latency_s: lat.mean_s,
            max_latency_s: lat.max_s,
            p50_latency_s: lat.p50_s,
            p95_latency_s: lat.p95_s,
            p99_latency_s: lat.p99_s,
            batch_execs: exec.count,
            mean_batch_exec_s: exec.mean_s,
            max_batch_exec_s: exec.max_s,
            p50_batch_exec_s: exec.p50_s,
            p95_batch_exec_s: exec.p95_s,
            p99_batch_exec_s: exec.p99_s,
            plan_cache_hits: cache.hits,
            plan_cache_misses: cache.misses,
            plan_cache_hit_rate: cache.hit_rate(),
            schedule_cache_hits: cache.schedule_hits,
            schedule_cache_misses: cache.schedule_misses,
            ops_shared: ops_shared_total(),
            executed_nodes: sched_exec.executed_nodes,
            scatter_passes: sched_exec.scatter_passes,
            measured_bytes_moved: sched_exec.bytes_moved,
            schedule_nodes: planner.nodes,
            schedule_classes: planner.classes,
            schedule_estimated_flops: planner.estimated_flops,
            schedule_estimated_bytes: planner.estimated_bytes,
            schedule_sharing_ratio: planner.sharing_ratio(),
            arena_allocations: arena.allocations,
            arena_reuses: arena.reuses,
            arena_high_water_f64s: arena.high_water_f64s as u64,
            arena_index_allocations: arena.index_allocations,
            arena_index_reuses: arena.index_reuses,
            arena_peak_bytes: arena.peak_bytes as u64,
            tiled_chains: sched_exec.tiled_chains,
            fused_batches: fused.batches,
            fused_items: fused.items,
            mean_fused_batch_size: fused.mean_batch_size(),
            batch_window_s: self.batch_window_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            target_p95_s: self.target_p95_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            plan_cache_evictions: cache.evictions,
            schedule_cache_evictions: cache.schedule_evictions,
            plan_cache_shards: cache.shards as u64,
            plan_cache_shard_hit_rates: shard_hit_rates,
            executor_workers: pool.workers as u64,
            executor_steals: pool.steals,
            executor_parks: pool.parks,
            executor_injector_pushes: pool.injector_pushes,
            executor_executed: pool.executed,
            numeric_faults: self.numeric_faults.load(Ordering::Relaxed),
            watchdog_kills: self.watchdog_kills.load(Ordering::Relaxed),
            shadow_verifications: self.shadow_verifications.load(Ordering::Relaxed),
            integrity_mismatches: self.integrity_mismatches.load(Ordering::Relaxed),
            schedule_quarantines: cache.schedule_quarantines,
            schedule_recompiles: self.schedule_recompiles.load(Ordering::Relaxed),
            brownout_state: self.brownout_state.load(Ordering::Relaxed),
            brownout_engagements: self.brownout_engagements.load(Ordering::Relaxed),
            brownout_recoveries: self.brownout_recoveries.load(Ordering::Relaxed),
            degraded_models: self.degraded_models.load(Ordering::Relaxed),
            arena_in_use_bytes: crate::fastmult::arena_in_use_bytes() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]

    use super::*;
    use crate::diagram::Diagram;
    use crate::fastmult::Group;

    #[test]
    fn bucket_index_is_monotone_and_contiguous() {
        let mut prev = bucket_index(0);
        assert_eq!(prev, 0);
        let mut jumps = 0;
        for ns in 1..100_000u64 {
            let idx = bucket_index(ns);
            assert!(idx >= prev, "index not monotone at ns={ns}");
            assert!(idx - prev <= 1, "index skipped a bucket at ns={ns}");
            if idx > prev {
                jumps += 1;
            }
            prev = idx;
        }
        assert!(jumps > 50, "suspiciously few buckets used: {jumps}");
        // The saturating tail never overruns the array.
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn bucket_value_lands_inside_its_bucket() {
        for ns in [1u64, 15, 16, 100, 1_000, 999_999, 10_000_000, 1 << 40] {
            let idx = bucket_index(ns);
            let v = bucket_value_ns(idx);
            // The representative value maps back to the same bucket.
            assert_eq!(bucket_index(v as u64), idx, "ns={ns} idx={idx} v={v}");
            // …and is within the log-linear resolution of the input.
            let rel = (v - ns as f64).abs() / ns as f64;
            assert!(rel <= 1.0 / SUB as f64, "ns={ns}: rel err {rel}");
        }
    }

    #[test]
    fn histogram_percentiles_are_ordered_and_accurate() {
        let h = LatencyHistogram::default();
        // 100 samples: 1ms ×90, 10ms ×9, 100ms ×1.
        for _ in 0..90 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..9 {
            h.record(Duration::from_millis(10));
        }
        h.record(Duration::from_millis(100));
        let s = h.stats();
        assert_eq!(s.count, 100);
        assert!(s.p50_s <= s.p95_s && s.p95_s <= s.p99_s && s.p99_s <= s.max_s * 1.0001);
        let within = |got: f64, want: f64| (got - want).abs() / want < 0.10;
        assert!(within(s.p50_s, 1e-3), "p50 {}", s.p50_s);
        assert!(within(s.p95_s, 10e-3), "p95 {}", s.p95_s);
        assert!(within(s.p99_s, 100e-3), "p99 {}", s.p99_s);
        assert!((s.max_s - 0.1).abs() < 1e-6);
        // Exact mean: (90·1 + 9·10 + 1·100) ms / 100 = 2.8 ms.
        assert!((s.mean_s - 0.0028).abs() < 1e-9, "mean {}", s.mean_s);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let s = LatencyHistogram::default().stats();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_s, 0.0);
        assert_eq!(s.p99_s, 0.0);
    }

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.on_accept();
        m.on_accept();
        m.on_reject();
        m.on_batch(2);
        m.on_complete(Duration::from_millis(10), true);
        m.on_complete(Duration::from_millis(30), false);
        m.on_batch_executed(Duration::from_millis(4));
        m.on_batch_executed(Duration::from_millis(8));
        m.on_shed_expired();
        m.on_shed_admission();
        m.on_worker_restart();
        m.on_batch_panic();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.shed_expired, 1);
        assert_eq!(s.shed_admission, 1);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.batch_panics, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((s.mean_latency_s - 0.020).abs() < 1e-6);
        assert!((s.max_latency_s - 0.030).abs() < 1e-6);
        // Percentiles come out of the log-bucketed histogram: ordered and
        // within its ~6% bucket resolution.
        assert!(s.p50_latency_s <= s.p95_latency_s);
        assert!(s.p95_latency_s <= s.p99_latency_s);
        assert!((s.p50_latency_s - 0.010).abs() / 0.010 < 0.10);
        assert!((s.p99_latency_s - 0.030).abs() / 0.030 < 0.10);
        assert_eq!(s.batch_execs, 2);
        assert!((s.mean_batch_exec_s - 0.006).abs() < 1e-6);
        assert!((s.max_batch_exec_s - 0.008).abs() < 1e-6);
        assert!(s.p50_batch_exec_s <= s.p99_batch_exec_s);
        assert!((s.p99_batch_exec_s - 0.008).abs() / 0.008 < 0.10);
        // Plan-cache counters come from the process-wide cache. Force at
        // least one miss and one hit, then assert the snapshot sees them
        // (counters are monotonic, so >= holds under concurrent tests).
        let cache = PlanCache::global();
        let d = Diagram::identity(2);
        cache.get_or_build(Group::Symmetric, &d, 9).unwrap();
        cache.get_or_build(Group::Symmetric, &d, 9).unwrap();
        let s = m.snapshot();
        assert!(s.plan_cache_misses >= 1, "miss not plumbed through");
        assert!(s.plan_cache_hits >= 1, "hit not plumbed through");
        assert!(s.plan_cache_hit_rate > 0.0 && s.plan_cache_hit_rate <= 1.0);
        // Schedule and arena counters are plumbed from the fastmult
        // globals; run one fused layer forward so they are non-trivial.
        use crate::layer::{EquivariantLinear, Init};
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(9);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Normal(0.5), &mut rng)
                .unwrap();
        layer.forward(&Tensor::random(3, 2, &mut rng)).unwrap();
        let s = m.snapshot();
        assert!(s.schedule_cache_misses >= 1, "schedule compile not counted");
        assert!(s.ops_shared > 0, "CSE sharing not plumbed through");
        assert!(s.arena_allocations >= 1, "arena counters not plumbed");
        assert!(s.arena_high_water_f64s >= 1);
        // Planner and execution counters are plumbed from the schedule
        // globals (the forward above materialised nodes and ran folded
        // scatter passes).
        assert!(s.executed_nodes >= 1, "executed-node counter not plumbed");
        assert!(s.scatter_passes >= 1, "scatter-pass counter not plumbed");
        assert!(
            s.measured_bytes_moved >= 1,
            "measured bytes-moved counter not plumbed"
        );
        assert!(
            s.arena_index_allocations >= 1,
            "index-scratch counters not plumbed"
        );
        assert!(s.arena_peak_bytes >= 1, "arena peak bytes not plumbed");
        assert!(s.schedule_nodes >= 1 && s.schedule_classes >= 1);
        assert!(s.schedule_estimated_flops > 0 && s.schedule_estimated_bytes > 0);
        // Fused-batch counters are plumbed from the nn::model globals; run
        // one batched network forward so they are non-trivial.
        use crate::nn::{Activation, EquivariantNet};
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let batch: Vec<Tensor> = (0..4).map(|_| Tensor::random(3, 2, &mut rng)).collect();
        net.forward_batch(&batch).unwrap();
        let s = m.snapshot();
        assert!(s.fused_batches >= 1, "fused-batch counter not plumbed");
        assert!(s.fused_items >= 4, "fused-item counter not plumbed");
        assert!(s.mean_fused_batch_size > 0.0);
        // Gauges: window/target publish through to the snapshot.
        m.set_batch_window(Duration::from_micros(200));
        m.set_target_p95(Duration::from_millis(40));
        let s = m.snapshot();
        assert!((s.batch_window_s - 200e-6).abs() < 1e-12);
        assert!((s.target_p95_s - 0.040).abs() < 1e-12);
        // The cheap p95 accessor agrees with the full snapshot.
        assert!((m.latency_p95_s() - s.p95_latency_s).abs() < 1e-12);
        // Sharded-cache and executor counters are plumbed through. The
        // layer/net forwards above went through `parallel_map`, which spins
        // up the global executor, and through the global plan cache.
        assert_eq!(
            s.plan_cache_shards as usize,
            PlanCache::global().shards(),
            "shard count not plumbed"
        );
        assert_eq!(
            s.plan_cache_shard_hit_rates.len(),
            s.plan_cache_shards as usize
        );
        assert!(s
            .plan_cache_shard_hit_rates
            .iter()
            .all(|r| (0.0..=1.0).contains(r)));
        assert!(s.executor_workers >= 1, "executor stats not plumbed");
        assert!(s.executor_executed >= 1, "executor task counter stuck");
        // Integrity/watchdog/brownout counters are plumbed through.
        m.on_numeric_fault();
        m.on_watchdog_kill();
        m.on_shadow_verification();
        m.on_shadow_verification();
        m.on_integrity_mismatch();
        m.on_schedule_recompiles(3);
        m.set_brownout_state(2);
        m.on_brownout_engaged();
        m.on_brownout_recovered();
        m.on_model_degraded();
        let s = m.snapshot();
        assert_eq!(s.numeric_faults, 1);
        assert_eq!(s.watchdog_kills, 1);
        assert_eq!(s.shadow_verifications, 2);
        assert_eq!(s.integrity_mismatches, 1);
        assert_eq!(s.schedule_recompiles, 3);
        assert_eq!(s.brownout_state, 2);
        assert_eq!(s.brownout_state_name(), "tiled-f32");
        assert_eq!(s.brownout_engagements, 1);
        assert_eq!(s.brownout_recoveries, 1);
        assert_eq!(s.degraded_models, 1);
        // The batch-exec p99 accessor agrees with the snapshot.
        assert!((m.batch_exec_p99_s() - s.p99_batch_exec_s).abs() < 1e-12);
        m.set_brownout_state(0);
        assert_eq!(m.snapshot().brownout_state_name(), "normal");
    }
}
