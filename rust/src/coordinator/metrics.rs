//! Serving metrics: counters, latency aggregates, per-batch execution
//! latency, plan/schedule-cache effectiveness and scratch-arena health.

use crate::fastmult::{arena_stats, exec_stats, ops_shared_total, planner_totals, PlanCache};
use crate::nn::fused_batch_stats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink updated by the batcher and workers.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    latency: Mutex<LatencyAgg>,
    /// Wall time of whole-batch model executions (the batched fast path),
    /// as opposed to `latency` which is per-request end-to-end.
    batch_exec: Mutex<LatencyAgg>,
}

#[derive(Debug, Default)]
struct LatencyAgg {
    total_s: f64,
    max_s: f64,
    count: u64,
}

/// Point-in-time snapshot of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queue.
    pub requests: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that returned an error.
    pub failed: u64,
    /// Requests rejected by backpressure (queue full).
    pub rejected: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Mean items per batch.
    pub mean_batch_size: f64,
    /// Mean end-to-end latency (seconds).
    pub mean_latency_s: f64,
    /// Max end-to-end latency (seconds).
    pub max_latency_s: f64,
    /// Batches executed by workers (the batched model path).
    pub batch_execs: u64,
    /// Mean wall time of one whole-batch execution (seconds).
    pub mean_batch_exec_s: f64,
    /// Max wall time of one whole-batch execution (seconds).
    pub max_batch_exec_s: f64,
    /// Global plan-cache hits (process-wide, see
    /// [`crate::fastmult::PlanCache`]).
    pub plan_cache_hits: u64,
    /// Global plan-cache misses (`Factor` runs).
    pub plan_cache_misses: u64,
    /// Fraction of plan lookups served from the cache.
    pub plan_cache_hit_rate: f64,
    /// Compiled-schedule cache hits (one lookup per layer construction).
    pub schedule_cache_hits: u64,
    /// Compiled-schedule cache misses (schedule compilations).
    pub schedule_cache_misses: u64,
    /// Interior ops elided by schedule CSE (per forward pass, summed over
    /// every compiled schedule).
    pub ops_shared: u64,
    /// Interior DAG nodes actually materialised across all schedule walks
    /// (process-wide, see [`crate::fastmult::exec_stats`]).
    pub executed_nodes: u64,
    /// Folded multi-pattern scatter passes executed across all schedule
    /// walks — one per active `(node, pattern)` class per forward.
    pub scatter_passes: u64,
    /// **Measured** bytes moved by the schedule kernels across all walks —
    /// accumulated from actual element counts (active members, real batch
    /// sizes) at execution time, next to the compile-time
    /// `schedule_estimated_bytes`. Saturating.
    pub measured_bytes_moved: u64,
    /// Compile-time planner totals over every compiled schedule: distinct
    /// interior nodes after global CSE.
    pub schedule_nodes: u64,
    /// Folded `(node, pattern)` classes over every compiled schedule (the
    /// scatter-pass count of one forward through everything compiled).
    pub schedule_classes: u64,
    /// Cost-model flops of one forward walk, summed over compiled
    /// schedules.
    pub schedule_estimated_flops: u64,
    /// Cost-model bytes moved by one forward walk, summed over compiled
    /// schedules.
    pub schedule_estimated_bytes: u64,
    /// Aggregate fraction of interior ops eliminated by CSE across every
    /// compiled schedule (`1 - nodes / chain_ops`).
    pub schedule_sharing_ratio: f64,
    /// Scratch-arena buffers allocated fresh from the heap (stops growing
    /// once serving reaches steady state — the zero-allocation invariant).
    pub arena_allocations: u64,
    /// Scratch-arena acquisitions served by recycling.
    pub arena_reuses: u64,
    /// High-water mark of `f64`s held by any single scratch arena.
    pub arena_high_water_f64s: u64,
    /// Index-scratch buffers (odometer/ref-count vectors, node-slot
    /// tables) allocated fresh — stops growing at steady state, the
    /// index-scratch half of the zero-allocation invariant.
    pub arena_index_allocations: u64,
    /// Index-scratch acquisitions served by recycling.
    pub arena_index_reuses: u64,
    /// Whole batches executed through the batched model path — the fused
    /// `[B, n^k]` walk (one schedule walk per layer per worker span) for
    /// multi-item batches, the DAG-subtree fan-out for single-item ones
    /// (process-wide, see [`crate::nn::fused_batch_stats`]).
    pub fused_batches: u64,
    /// Items those fused batches contained.
    pub fused_items: u64,
    /// Mean items per fused batch.
    pub mean_fused_batch_size: f64,
}

impl Metrics {
    /// Record an accepted request.
    pub fn on_accept(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a backpressure rejection.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }
    /// Record a dispatched batch of `size` items.
    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }
    /// Record one whole-batch model execution taking `elapsed`.
    pub fn on_batch_executed(&self, elapsed: Duration) {
        let mut agg = self.batch_exec.lock().unwrap();
        let s = elapsed.as_secs_f64();
        agg.total_s += s;
        agg.count += 1;
        if s > agg.max_s {
            agg.max_s = s;
        }
    }
    /// Record a completed request with its end-to-end latency.
    pub fn on_complete(&self, latency: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let mut agg = self.latency.lock().unwrap();
        let s = latency.as_secs_f64();
        agg.total_s += s;
        agg.count += 1;
        if s > agg.max_s {
            agg.max_s = s;
        }
    }

    /// Take a snapshot (includes the process-wide plan-cache counters).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let (latency_mean, latency_max) = {
            let agg = self.latency.lock().unwrap();
            (
                if agg.count > 0 {
                    agg.total_s / agg.count as f64
                } else {
                    0.0
                },
                agg.max_s,
            )
        };
        let (exec_count, exec_mean, exec_max) = {
            let agg = self.batch_exec.lock().unwrap();
            (
                agg.count,
                if agg.count > 0 {
                    agg.total_s / agg.count as f64
                } else {
                    0.0
                },
                agg.max_s,
            )
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let cache = PlanCache::global().stats();
        let arena = arena_stats();
        let fused = fused_batch_stats();
        let exec = exec_stats();
        let planner = planner_totals();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            mean_latency_s: latency_mean,
            max_latency_s: latency_max,
            batch_execs: exec_count,
            mean_batch_exec_s: exec_mean,
            max_batch_exec_s: exec_max,
            plan_cache_hits: cache.hits,
            plan_cache_misses: cache.misses,
            plan_cache_hit_rate: cache.hit_rate(),
            schedule_cache_hits: cache.schedule_hits,
            schedule_cache_misses: cache.schedule_misses,
            ops_shared: ops_shared_total(),
            executed_nodes: exec.executed_nodes,
            scatter_passes: exec.scatter_passes,
            measured_bytes_moved: exec.bytes_moved,
            schedule_nodes: planner.nodes,
            schedule_classes: planner.classes,
            schedule_estimated_flops: planner.estimated_flops,
            schedule_estimated_bytes: planner.estimated_bytes,
            schedule_sharing_ratio: planner.sharing_ratio(),
            arena_allocations: arena.allocations,
            arena_reuses: arena.reuses,
            arena_high_water_f64s: arena.high_water_f64s as u64,
            arena_index_allocations: arena.index_allocations,
            arena_index_reuses: arena.index_reuses,
            fused_batches: fused.batches,
            fused_items: fused.items,
            mean_fused_batch_size: fused.mean_batch_size(),
        }
    }
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]

    use super::*;
    use crate::diagram::Diagram;
    use crate::fastmult::Group;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.on_accept();
        m.on_accept();
        m.on_reject();
        m.on_batch(2);
        m.on_complete(Duration::from_millis(10), true);
        m.on_complete(Duration::from_millis(30), false);
        m.on_batch_executed(Duration::from_millis(4));
        m.on_batch_executed(Duration::from_millis(8));
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((s.mean_latency_s - 0.020).abs() < 1e-6);
        assert!((s.max_latency_s - 0.030).abs() < 1e-6);
        assert_eq!(s.batch_execs, 2);
        assert!((s.mean_batch_exec_s - 0.006).abs() < 1e-6);
        assert!((s.max_batch_exec_s - 0.008).abs() < 1e-6);
        // Plan-cache counters come from the process-wide cache. Force at
        // least one miss and one hit, then assert the snapshot sees them
        // (counters are monotonic, so >= holds under concurrent tests).
        let cache = PlanCache::global();
        let d = Diagram::identity(2);
        cache.get_or_build(Group::Symmetric, &d, 9).unwrap();
        cache.get_or_build(Group::Symmetric, &d, 9).unwrap();
        let s = m.snapshot();
        assert!(s.plan_cache_misses >= 1, "miss not plumbed through");
        assert!(s.plan_cache_hits >= 1, "hit not plumbed through");
        assert!(s.plan_cache_hit_rate > 0.0 && s.plan_cache_hit_rate <= 1.0);
        // Schedule and arena counters are plumbed from the fastmult
        // globals; run one fused layer forward so they are non-trivial.
        use crate::layer::{EquivariantLinear, Init};
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(9);
        let layer =
            EquivariantLinear::new(Group::Symmetric, 3, 2, 2, Init::Normal(0.5), &mut rng)
                .unwrap();
        layer.forward(&Tensor::random(3, 2, &mut rng)).unwrap();
        let s = m.snapshot();
        assert!(s.schedule_cache_misses >= 1, "schedule compile not counted");
        assert!(s.ops_shared > 0, "CSE sharing not plumbed through");
        assert!(s.arena_allocations >= 1, "arena counters not plumbed");
        assert!(s.arena_high_water_f64s >= 1);
        // Planner and execution counters are plumbed from the schedule
        // globals (the forward above materialised nodes and ran folded
        // scatter passes).
        assert!(s.executed_nodes >= 1, "executed-node counter not plumbed");
        assert!(s.scatter_passes >= 1, "scatter-pass counter not plumbed");
        assert!(
            s.measured_bytes_moved >= 1,
            "measured bytes-moved counter not plumbed"
        );
        assert!(
            s.arena_index_allocations >= 1,
            "index-scratch counters not plumbed"
        );
        assert!(s.schedule_nodes >= 1 && s.schedule_classes >= 1);
        assert!(s.schedule_estimated_flops > 0 && s.schedule_estimated_bytes > 0);
        // Fused-batch counters are plumbed from the nn::model globals; run
        // one batched network forward so they are non-trivial.
        use crate::nn::{Activation, EquivariantNet};
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let batch: Vec<Tensor> = (0..4).map(|_| Tensor::random(3, 2, &mut rng)).collect();
        net.forward_batch(&batch).unwrap();
        let s = m.snapshot();
        assert!(s.fused_batches >= 1, "fused-batch counter not plumbed");
        assert!(s.fused_items >= 4, "fused-item counter not plumbed");
        assert!(s.mean_fused_batch_size > 0.0);
    }
}
