//! L3 coordinator: a threaded, batched inference server over equivariant
//! models.
//!
//! The paper's contribution is an algorithm, so the coordinator is the
//! serving shell a practitioner would deploy it in: requests enter a
//! bounded queue (backpressure), a **batcher** groups them per model inside
//! a time window, a **worker pool** executes batches — native diagram
//! layers via the fast path, or AOT-compiled JAX/Pallas artifacts via PJRT
//! — and per-request latency/throughput **metrics** are recorded. Rust owns
//! the event loop; no python anywhere on this path.
//!
//! The serving layer is fault-tolerant (`docs/serving_robustness.md`):
//! batch execution is panic-isolated with per-item fallback, a supervisor
//! respawns crashed workers with capped backoff, requests carry optional
//! deadlines enforced at three shed points, per-model admission control
//! caps inflight load, and the metrics expose p50/p95/p99 latency
//! histograms plus shed/restart counters. A seeded [`ChaosPlan`] fault
//! injector certifies the invariants under test and bench load.
//!
//! On top of the loud-failure machinery sit the **silent-failure
//! defenses** (all off by default): numeric canaries and sampled shadow
//! verification against the per-term reference path
//! (`[server] numeric_guard` / `verify_per_mille`), a hung-batch
//! watchdog that sheds and respawns wedged slots
//! (`[server] watchdog_factor`), and a memory-pressure brownout that
//! degrades execution instead of blowing the arena budget
//! (`[server] arena_budget_bytes`).
//!
//! ```no_run
//! use equidiag::coordinator::{Coordinator, ModelKind};
//! use equidiag::config::ServerConfig;
//! # use equidiag::{fastmult::Group, layer::Init, nn::{Activation, EquivariantNet}};
//! # use equidiag::tensor::Tensor;
//! # use equidiag::util::Rng;
//! let mut rng = Rng::new(1);
//! let net = EquivariantNet::new(Group::Symmetric, 4, &[2, 2], Activation::Relu,
//!                               Init::ScaledNormal, &mut rng).unwrap();
//! let mut coord = Coordinator::new(ServerConfig::default());
//! coord.register("gnn", ModelKind::net(net));
//! let handle = coord.start();
//! let out = handle.infer("gnn", Tensor::random(4, 2, &mut rng)).unwrap();
//! assert_eq!(out.order, 2);
//! handle.shutdown();
//! ```

mod batcher;
mod chaos;
mod integrity;
mod metrics;
mod registry;
mod server;

pub use chaos::{ChaosPlan, Fault, CHAOS_PANIC_PREFIX};
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::ModelKind;
pub use server::{Coordinator, CoordinatorHandle};
