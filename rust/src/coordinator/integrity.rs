//! Silent-failure defenses for the serving path
//! (`docs/serving_robustness.md`, "Integrity, watchdog & brownout"):
//!
//! - **Numeric canaries** (`[server] numeric_guard`): a vectorizable
//!   is-finite sweep over every response at the output boundary; a NaN/Inf
//!   answer becomes a typed [`Error::NumericFault`] instead of reaching
//!   the client, while finite batch-mates are untouched.
//! - **Sampled shadow verification** (`[server] verify_per_mille`): a
//!   deterministic fraction of served responses is re-executed through
//!   the per-term reference path on executor spare capacity and compared
//!   under a tolerance scaled to the model's serving precision. A
//!   mismatch quarantines the layer schedules involved (evicting them
//!   from the [`PlanCache`]), recompiles them from the pre-factored
//!   plans, re-verifies through the fresh schedules, and flags the model
//!   degraded in the metrics snapshot.
//! - **Hung-batch watchdog** (`[server] watchdog_factor`): workers stamp
//!   a per-slot heartbeat before executing a batch; the supervisor reaps
//!   slots whose batch has outlived `watchdog_factor × live p99` (floored
//!   at the request timeout), shedding every waiter with
//!   [`Error::BatchStuck`] and respawning the slot. The wedged
//!   incarnation detects its bumped epoch when (if) it returns and goes
//!   quiet instead of double-delivering.
//! - **Memory-pressure brownout** (`[server] arena_budget_bytes`): a
//!   hysteresis-guarded state machine fed the live arena footprint;
//!   over-budget it degrades execution `Normal → Tiled → TiledF32`
//!   (shrunken-tile-budget schedule walks, then f32 casting where
//!   `[model] brownout_f32` allows) and recovers to `Normal` after a
//!   sustained under-budget window.
//!
//! Every hook is off by default; with the knobs off the serving hot path
//! is untouched — no stamping, no sampling, no extra allocation.

use super::batcher::WorkItem;
use super::metrics::Metrics;
use super::registry::ModelKind;
use crate::error::{Error, Result};
use crate::fastmult::{resolve_tile_budget, LayerSchedule, PlanCache};
use crate::layer::spanning_plans;
use crate::nn::EquivariantNet;
use crate::tensor::{Precision, Scalar, Tensor};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Recover a mutex guard even if a previous holder panicked: the
/// protected state here (waiter lists, schedule maps, degraded sets) is
/// only mutated under short, model-code-free critical sections.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Whether a served tensor contains any non-finite element. The closed
/// iterator chain compiles to a branch-free sweep; it runs only when
/// `[server] numeric_guard` is on.
pub(crate) fn non_finite(t: &Tensor) -> bool {
    t.data.iter().any(|x| !x.is_finite())
}

/// Headroom multiplier on the precision's machine tolerance: the fused
/// schedule walk reassociates the diagram-term sums, so the served and
/// per-term reference answers legitimately differ by a few ulps times
/// the summation depth — bitwise comparison would flag healthy traffic.
/// An injected exponent bit-flip moves one element by ~2×, ten orders of
/// magnitude outside this band, so detection is unaffected.
const AGREE_GUARD: f64 = 4096.0;

/// Tolerance-scaled agreement between a served answer and its per-term
/// reference, at the model's serving precision.
pub(crate) fn outputs_agree(served: &Tensor, reference: &Tensor, precision: Precision) -> bool {
    if served.n != reference.n
        || served.order != reference.order
        || served.data.len() != reference.data.len()
    {
        return false;
    }
    let eps = match precision {
        Precision::F64 => <f64 as Scalar>::TOLERANCE,
        Precision::F32 => <f32 as Scalar>::TOLERANCE,
    };
    let scale = reference
        .data
        .iter()
        .fold(1.0_f64, |m, x| m.max(x.abs()));
    let tol = AGREE_GUARD * eps * scale;
    served
        .data
        .iter()
        .zip(&reference.data)
        .all(|(a, b)| (a - b).abs() <= tol)
}

/// Sampled shadow verification: deterministic per-mille selection of
/// served responses, re-executed through [`ModelKind::infer_reference`]
/// and compared with [`outputs_agree`]. Shared by every worker of one
/// coordinator.
pub(crate) struct Verifier {
    per_mille: u64,
    seq: AtomicU64,
    /// Routes that ever failed a shadow comparison; `degraded` is sticky
    /// so the metrics snapshot keeps reporting a model that silently
    /// corrupted an answer even after its schedules were recompiled.
    degraded: Mutex<HashSet<String>>,
}

impl Verifier {
    pub fn new(per_mille: usize) -> Self {
        Verifier {
            per_mille: (per_mille as u64).min(1000),
            seq: AtomicU64::new(0),
            degraded: Mutex::new(HashSet::new()),
        }
    }

    /// Deterministic Bresenham-style sampler: response `s` is sampled iff
    /// the running count `⌊s·rate/1000⌋` steps, which spreads exactly
    /// `per_mille` samples over every 1000 responses with no RNG and no
    /// clustering. One atomic increment per served response.
    pub fn should_sample(&self) -> bool {
        if self.per_mille == 0 {
            return false;
        }
        let s = self.seq.fetch_add(1, Ordering::Relaxed);
        (s + 1) * self.per_mille / 1000 > s * self.per_mille / 1000
    }

    /// Re-execute `input` through the reference path and compare against
    /// the `served` answer; on mismatch run the quarantine → recompile →
    /// re-verify protocol. Runs on executor spare capacity, off the
    /// serving hot path.
    pub fn verify(
        &self,
        route: &str,
        model: &ModelKind,
        input: &Tensor,
        served: &Tensor,
        metrics: &Metrics,
    ) {
        // HLO artifacts have no per-term twin: nothing to verify against.
        let Ok(reference) = model.infer_reference(input) else {
            return;
        };
        let Some((net, precision)) = model.as_net() else {
            return;
        };
        metrics.on_shadow_verification();
        if outputs_agree(served, &reference, precision) {
            return;
        }
        metrics.on_integrity_mismatch();
        if lock_recover(&self.degraded).insert(route.to_string()) {
            metrics.on_model_degraded();
        }
        // Quarantine every schedule the route executes through (both
        // orientations, every tile budget), then recompile the forward
        // set from the pre-factored plans and prove the fresh copies
        // against the same reference before they serve traffic.
        let cache = PlanCache::global();
        let mut fresh: Vec<Arc<LayerSchedule>> = Vec::with_capacity(net.layers.len());
        let mut recompiled = 0u64;
        for layer in &net.layers {
            let (g, n, k, l) = (layer.group(), layer.n(), layer.k(), layer.l());
            cache.quarantine_schedule(g, n, k, l, false);
            cache.quarantine_schedule(g, n, k, l, true);
            let rebuilt = spanning_plans(g, n, k, l)
                .and_then(|plans| cache.get_or_build_schedule(g, n, k, l, false, &plans));
            match rebuilt {
                Ok(s) => {
                    recompiled += 1;
                    fresh.push(s);
                }
                Err(_) => break,
            }
        }
        metrics.on_schedule_recompiles(recompiled);
        if fresh.len() == net.layers.len() {
            // Best effort: a re-verification failure would implicate the
            // plans themselves rather than a stale compiled schedule; the
            // route stays flagged degraded either way.
            let _redo_agrees = match precision {
                Precision::F64 => net
                    .forward_with_schedules(&fresh, input)
                    .map(|redo| outputs_agree(&redo, &reference, precision)),
                Precision::F32 => net
                    .forward_with_schedules(&fresh, &input.cast::<f32>())
                    .map(|redo| outputs_agree(&redo.cast::<f64>(), &reference, precision)),
            };
        }
    }
}

/// Brownout severity, ordered by how much fidelity it trades for memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum BrownoutLevel {
    /// Full-budget execution.
    Normal = 0,
    /// Schedule walks forced through shrunken-tile-budget compilations —
    /// smaller working set per walk at some throughput cost.
    Tiled = 1,
    /// Tiled execution with inputs narrowed to `f32` — halves the
    /// bandwidth and arena footprint; entered only where
    /// `[model] brownout_f32` allows it.
    TiledF32 = 2,
}

impl BrownoutLevel {
    fn from_u64(v: u64) -> Self {
        match v {
            0 => BrownoutLevel::Normal,
            1 => BrownoutLevel::Tiled,
            _ => BrownoutLevel::TiledF32,
        }
    }
}

/// Hysteresis-guarded brownout state machine, fed one observation of the
/// live arena footprint per supervisor tick. Escalates one level after
/// `engage_ticks` consecutive over-budget observations and snaps back to
/// `Normal` after `recover_ticks` consecutive under-budget ones, so a
/// footprint oscillating around the budget cannot flap the serving mode
/// every tick. Pure and injectable: tests drive it with synthetic byte
/// counts and tick counts.
pub(crate) struct Brownout {
    budget_bytes: usize,
    allow_f32: bool,
    engage_ticks: u32,
    recover_ticks: u32,
    level: BrownoutLevel,
    over: u32,
    under: u32,
}

/// Consecutive over-budget supervisor ticks (~50ms each) before the
/// brownout escalates a level.
const ENGAGE_TICKS: u32 = 2;
/// Consecutive under-budget ticks before it recovers to `Normal` —
/// roughly a one-second sustained window at the supervisor cadence.
const RECOVER_TICKS: u32 = 20;

impl Brownout {
    pub fn new(budget_bytes: usize, allow_f32: bool) -> Self {
        Self::with_hysteresis(budget_bytes, allow_f32, ENGAGE_TICKS, RECOVER_TICKS)
    }

    /// Test hook: explicit hysteresis windows.
    pub fn with_hysteresis(
        budget_bytes: usize,
        allow_f32: bool,
        engage_ticks: u32,
        recover_ticks: u32,
    ) -> Self {
        Brownout {
            budget_bytes,
            allow_f32,
            engage_ticks: engage_ticks.max(1),
            recover_ticks: recover_ticks.max(1),
            level: BrownoutLevel::Normal,
            over: 0,
            under: 0,
        }
    }

    pub fn level(&self) -> BrownoutLevel {
        self.level
    }

    /// Feed one footprint observation; `Some(new_level)` on a transition.
    pub fn observe(&mut self, in_use_bytes: usize) -> Option<BrownoutLevel> {
        if in_use_bytes > self.budget_bytes {
            self.under = 0;
            self.over += 1;
            if self.over < self.engage_ticks {
                return None;
            }
            self.over = 0;
            let next = match self.level {
                BrownoutLevel::Normal => BrownoutLevel::Tiled,
                BrownoutLevel::Tiled if self.allow_f32 => BrownoutLevel::TiledF32,
                held => held,
            };
            if next == self.level {
                return None;
            }
            self.level = next;
            Some(next)
        } else {
            self.over = 0;
            if self.level == BrownoutLevel::Normal {
                return None;
            }
            self.under += 1;
            if self.under < self.recover_ticks {
                return None;
            }
            self.under = 0;
            self.level = BrownoutLevel::Normal;
            Some(BrownoutLevel::Normal)
        }
    }
}

/// Worker-facing side of the brownout: the supervisor publishes the
/// current level here; workers read it per batch (one relaxed load when
/// the knob is on) and, when browned out, route native models through
/// shrunken-tile-budget schedules compiled once per route.
pub(crate) struct BrownoutCtl {
    pub budget_bytes: usize,
    pub allow_f32: bool,
    level: AtomicU64,
    schedules: Mutex<HashMap<String, Arc<Vec<Arc<LayerSchedule>>>>>,
}

impl BrownoutCtl {
    pub fn new(budget_bytes: usize, allow_f32: bool) -> Self {
        BrownoutCtl {
            budget_bytes,
            allow_f32,
            level: AtomicU64::new(BrownoutLevel::Normal as u64),
            schedules: Mutex::new(HashMap::new()),
        }
    }

    pub fn level(&self) -> BrownoutLevel {
        BrownoutLevel::from_u64(self.level.load(Ordering::Relaxed))
    }

    pub fn set_level(&self, level: BrownoutLevel) {
        self.level.store(level as u64, Ordering::Relaxed);
    }

    /// The shrunken tile budget browned-out walks compile under: a
    /// quarter of the process budget, floored so degenerate probes still
    /// hold one lane.
    pub fn tile_budget(&self) -> usize {
        (resolve_tile_budget() / 4).max(4096)
    }

    /// The brownout schedule set for one route, compiled on first
    /// browned-out batch and cached for the coordinator's lifetime (the
    /// shrunken-budget entries also live in the global [`PlanCache`]
    /// keyed by their budget, coexisting with the normal ones).
    pub fn schedules_for(
        &self,
        route: &str,
        net: &EquivariantNet,
    ) -> Result<Arc<Vec<Arc<LayerSchedule>>>> {
        if let Some(s) = lock_recover(&self.schedules).get(route) {
            return Ok(s.clone());
        }
        // Compile outside the lock; a racing worker's duplicate compile
        // resolves to the same cache entries and the first insert wins.
        let budget = self.tile_budget();
        let cache = PlanCache::global();
        let mut built: Vec<Arc<LayerSchedule>> = Vec::with_capacity(net.layers.len());
        for layer in &net.layers {
            let (g, n, k, l) = (layer.group(), layer.n(), layer.k(), layer.l());
            let plans = spanning_plans(g, n, k, l)?;
            built.push(cache.get_or_build_schedule_budgeted(g, n, k, l, false, &plans, budget)?);
        }
        let built = Arc::new(built);
        Ok(lock_recover(&self.schedules)
            .entry(route.to_string())
            .or_insert(built)
            .clone())
    }
}

/// One browned-out forward: tiled walk under the shrunken budget, with
/// inputs narrowed to `f32` at the deepest level (or when the model
/// already serves at `f32`).
pub(crate) fn brownout_infer(
    net: &EquivariantNet,
    precision: Precision,
    level: BrownoutLevel,
    schedules: &[Arc<LayerSchedule>],
    input: &Tensor,
) -> Result<Tensor> {
    if precision == Precision::F32 || level == BrownoutLevel::TiledF32 {
        net.forward_with_schedules(schedules, &input.cast::<f32>())
            .map(|t| t.cast::<f64>())
    } else {
        net.forward_with_schedules(schedules, input)
    }
}

/// One worker slot's heartbeat: epoch-stamped so a wedged incarnation
/// can be *superseded* (safe Rust cannot kill its thread) — the watchdog
/// bumps the epoch, sheds the registered waiters, and respawns the slot;
/// the zombie compares epochs when it finally returns and goes quiet.
struct HeartbeatSlot {
    epoch: AtomicU64,
    /// 1 while a batch is executing on this slot.
    busy: AtomicU64,
    /// Batch start, as nanoseconds since the table's birth.
    started_ns: AtomicU64,
    /// Response channels (plus enqueue stamps for latency accounting) of
    /// the in-flight batch, registered before execution so the watchdog
    /// can deliver [`Error::BatchStuck`] without touching the items the
    /// wedged thread owns.
    waiters: Mutex<Vec<(Sender<Result<Tensor>>, Instant)>>,
}

/// Per-slot heartbeat table shared by the workers and the supervisor's
/// watchdog sweep. Allocated once at startup; stamping is two atomic
/// stores plus one short waiter-list fill per batch, and nothing here
/// runs at all unless `[server] watchdog_factor` is set.
pub(crate) struct Heartbeats {
    birth: Instant,
    slots: Vec<HeartbeatSlot>,
}

impl Heartbeats {
    pub fn new(workers: usize) -> Self {
        Heartbeats {
            birth: Instant::now(),
            slots: (0..workers.max(1))
                .map(|_| HeartbeatSlot {
                    epoch: AtomicU64::new(0),
                    busy: AtomicU64::new(0),
                    started_ns: AtomicU64::new(0),
                    waiters: Mutex::new(Vec::new()),
                })
                .collect(),
        }
    }

    /// Stamp a batch start on `slot` and register its waiters; returns
    /// the epoch the incarnation must present to [`Heartbeats::finish`].
    pub fn start(&self, slot: usize, items: &[WorkItem]) -> u64 {
        let s = &self.slots[slot % self.slots.len()];
        {
            let mut w = lock_recover(&s.waiters);
            w.clear();
            w.extend(items.iter().map(|it| (it.respond.clone(), it.enqueued)));
        }
        s.started_ns
            .store(self.birth.elapsed().as_nanos() as u64, Ordering::Release);
        s.busy.store(1, Ordering::Release);
        s.epoch.load(Ordering::Acquire)
    }

    /// Clear the stamp after a batch returns. `false` means the slot was
    /// superseded while the batch ran — its waiters were already shed
    /// with [`Error::BatchStuck`] and a replacement spawned, so the
    /// caller must deliver nothing and exit `Superseded`. A superseded
    /// finish leaves the slot state alone: it belongs to the replacement
    /// now.
    pub fn finish(&self, slot: usize, epoch_at_start: u64) -> bool {
        let s = &self.slots[slot % self.slots.len()];
        if s.epoch.load(Ordering::Acquire) != epoch_at_start {
            return false;
        }
        s.busy.store(0, Ordering::Release);
        lock_recover(&s.waiters).clear();
        true
    }

    /// Watchdog sweep: supersede every slot whose in-flight batch is
    /// older than `threshold`, shed its waiters with
    /// [`Error::BatchStuck`], and return the slot indices so the
    /// supervisor can spawn replacements. (The race where a batch
    /// finishes between the staleness read and the epoch bump is benign:
    /// the finished incarnation already cleared the waiter list, so the
    /// shed delivers nothing and the respawn briefly over-provisions one
    /// slot.)
    pub fn reap(&self, threshold: Duration, metrics: &Metrics) -> Vec<usize> {
        let now_ns = self.birth.elapsed().as_nanos() as u64;
        let mut reaped = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if s.busy.load(Ordering::Acquire) != 1 {
                continue;
            }
            let age_ns = now_ns.saturating_sub(s.started_ns.load(Ordering::Acquire));
            if Duration::from_nanos(age_ns) <= threshold {
                continue;
            }
            s.epoch.fetch_add(1, Ordering::AcqRel);
            s.busy.store(0, Ordering::Release);
            let shed: Vec<(Sender<Result<Tensor>>, Instant)> =
                lock_recover(&s.waiters).drain(..).collect();
            for (respond, enqueued) in shed {
                metrics.on_complete(enqueued.elapsed(), false);
                let _ = respond.send(Err(Error::BatchStuck));
            }
            metrics.on_watchdog_kill();
            reaped.push(i);
        }
        reaped
    }
}

/// The watchdog's staleness threshold for this tick: `factor ×` the live
/// batch-execution p99, floored at the configured request timeout.
/// `None` disables the sweep — either the knob is off or there is no
/// signal yet (no executed batch *and* no timeout to floor on), in which
/// case killing the first slow batch would be a guess, not a diagnosis.
pub(crate) fn watchdog_threshold(
    factor: f64,
    live_p99_s: f64,
    floor: Option<Duration>,
) -> Option<Duration> {
    if factor <= 0.0 {
        return None;
    }
    let scaled = Duration::from_secs_f64((live_p99_s * factor).max(0.0));
    let threshold = scaled.max(floor.unwrap_or(Duration::ZERO));
    (threshold > Duration::ZERO).then_some(threshold)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_finite_flags_nan_and_inf_only() {
        let mut t = Tensor::zeros(3, 2);
        assert!(!non_finite(&t));
        t.data[4] = f64::NAN;
        assert!(non_finite(&t));
        t.data[4] = f64::INFINITY;
        assert!(non_finite(&t));
        t.data[4] = 1e308;
        assert!(!non_finite(&t));
    }

    #[test]
    fn agreement_tolerates_reassociation_but_not_flips() {
        let mut a = Tensor::zeros(3, 2);
        for (i, x) in a.data.iter_mut().enumerate() {
            *x = (i as f64 + 1.0) * 0.25;
        }
        let mut b = a.clone();
        // A few-ulp reassociation wobble passes at both precisions.
        b.data[3] += 4.0 * f64::EPSILON * b.data[3];
        assert!(outputs_agree(&a, &b, Precision::F64));
        assert!(outputs_agree(&a, &b, Precision::F32));
        // An exponent bit-flip (2× one element) fails at both.
        let mut c = a.clone();
        c.data[5] = f64::from_bits(c.data[5].to_bits() ^ (1u64 << 52));
        assert!(!outputs_agree(&c, &a, Precision::F64));
        assert!(!outputs_agree(&c, &a, Precision::F32));
        // Shape mismatches never agree.
        assert!(!outputs_agree(&Tensor::zeros(3, 1), &a, Precision::F64));
    }

    #[test]
    fn sampler_hits_exact_fraction_deterministically() {
        let v = Verifier::new(50);
        let hits = (0..10_000).filter(|_| v.should_sample()).count();
        assert_eq!(hits, 500, "50‰ of 10k");
        let off = Verifier::new(0);
        assert!((0..1000).all(|_| !off.should_sample()));
        let all = Verifier::new(1000);
        assert!((0..1000).all(|_| all.should_sample()));
    }

    #[test]
    fn brownout_engages_escalates_and_recovers_with_hysteresis() {
        let mut b = Brownout::with_hysteresis(1000, true, 2, 3);
        // One over-budget tick is not enough (hysteresis).
        assert_eq!(b.observe(2000), None);
        assert_eq!(b.level(), BrownoutLevel::Normal);
        assert_eq!(b.observe(2000), Some(BrownoutLevel::Tiled));
        // Escalation to f32 needs its own sustained window.
        assert_eq!(b.observe(2000), None);
        assert_eq!(b.observe(2000), Some(BrownoutLevel::TiledF32));
        // Held at the deepest level, further pressure is a no-op.
        assert_eq!(b.observe(2000), None);
        assert_eq!(b.observe(2000), None);
        // A dip under budget resets only after the full recover window,
        // and an interleaved spike restarts the count.
        assert_eq!(b.observe(500), None);
        assert_eq!(b.observe(500), None);
        assert_eq!(b.observe(2000), None);
        assert_eq!(b.observe(500), None);
        assert_eq!(b.observe(500), None);
        assert_eq!(b.observe(500), Some(BrownoutLevel::Normal));
        assert_eq!(b.level(), BrownoutLevel::Normal);
    }

    #[test]
    fn brownout_without_f32_consent_stops_at_tiled() {
        let mut b = Brownout::with_hysteresis(100, false, 1, 2);
        assert_eq!(b.observe(200), Some(BrownoutLevel::Tiled));
        assert_eq!(b.observe(200), None, "f32 stage gated off");
        assert_eq!(b.level(), BrownoutLevel::Tiled);
    }

    #[test]
    fn brownout_ctl_publishes_levels() {
        let ctl = BrownoutCtl::new(1 << 20, true);
        assert_eq!(ctl.level(), BrownoutLevel::Normal);
        ctl.set_level(BrownoutLevel::TiledF32);
        assert_eq!(ctl.level(), BrownoutLevel::TiledF32);
        assert!(ctl.tile_budget() >= 4096);
        assert!(ctl.tile_budget() <= resolve_tile_budget().max(4096));
    }

    #[test]
    fn watchdog_threshold_needs_a_signal() {
        assert_eq!(watchdog_threshold(0.0, 1.0, None), None, "knob off");
        assert_eq!(watchdog_threshold(4.0, 0.0, None), None, "no signal yet");
        assert_eq!(
            watchdog_threshold(4.0, 0.5, None),
            Some(Duration::from_secs(2))
        );
        // The request timeout floors a small p99-derived threshold.
        assert_eq!(
            watchdog_threshold(4.0, 0.001, Some(Duration::from_secs(1))),
            Some(Duration::from_secs(1))
        );
        assert_eq!(
            watchdog_threshold(4.0, 0.0, Some(Duration::from_millis(250))),
            Some(Duration::from_millis(250))
        );
    }

    #[test]
    fn heartbeats_stamp_reap_and_supersede() {
        let hb = Heartbeats::new(2);
        let metrics = Metrics::default();
        // Nothing in flight: nothing to reap.
        assert!(hb.reap(Duration::ZERO, &metrics).is_empty());
        // Stamp a batch on slot 0 and reap it as stale (zero threshold).
        let (tx, rx) = std::sync::mpsc::channel();
        let items = vec![WorkItem {
            model: "m".into(),
            input: Tensor::zeros(2, 1),
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
            inflight: None,
        }];
        let epoch = hb.start(0, &items);
        std::thread::sleep(Duration::from_millis(2));
        let reaped = hb.reap(Duration::from_millis(1), &metrics);
        assert_eq!(reaped, vec![0]);
        // The waiter got a typed shed and the metrics counted the kill.
        assert!(matches!(rx.try_recv(), Ok(Err(Error::BatchStuck))));
        let snap = metrics.snapshot();
        assert_eq!(snap.watchdog_kills, 1);
        assert_eq!(snap.failed, 1);
        // The wedged incarnation is superseded: finish refuses, and a
        // second sweep finds the slot idle.
        assert!(!hb.finish(0, epoch));
        assert!(hb.reap(Duration::ZERO, &metrics).is_empty());
        // A fresh incarnation stamps the bumped epoch and finishes clean.
        let (tx2, _rx2) = std::sync::mpsc::channel();
        let items2 = vec![WorkItem {
            model: "m".into(),
            input: Tensor::zeros(2, 1),
            enqueued: Instant::now(),
            deadline: None,
            respond: tx2,
            inflight: None,
        }];
        let epoch2 = hb.start(0, &items2);
        assert_eq!(epoch2, epoch + 1);
        assert!(hb.finish(0, epoch2));
    }
}
