//! Model registry: named inference targets behind one coordinator.

use crate::error::{Error, Result};
use crate::nn::EquivariantNet;
use crate::runtime::HloService;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;

/// A servable model: a native equivariant network (runs the fast diagram
/// path) or a compiled HLO artifact (runs through the PJRT owner thread).
#[derive(Debug, Clone)]
pub enum ModelKind {
    /// In-process equivariant network.
    Net(Arc<EquivariantNet>),
    /// AOT-compiled JAX/Pallas model (expects/returns the flattened tensor;
    /// the artifact's first tuple output is used).
    Hlo(HloService),
}

impl ModelKind {
    /// Wrap a network.
    pub fn net(net: EquivariantNet) -> Self {
        ModelKind::Net(Arc::new(net))
    }
    /// Wrap an HLO service handle.
    pub fn hlo(service: HloService) -> Self {
        ModelKind::Hlo(service)
    }

    /// Run a whole batch through the model: one result per input, in
    /// order. Native networks take the batched parallel path
    /// ([`EquivariantNet::forward_batch_results`]), which already keeps
    /// shape errors per-item (malformed batches fall back to per-item
    /// forwards); HLO models run through their owner thread one by one
    /// (PJRT-CPU serialises executions anyway).
    pub fn infer_batch(&self, inputs: &[&Tensor]) -> Vec<Result<Tensor>> {
        match self {
            ModelKind::Net(net) => net.forward_batch_results(inputs),
            ModelKind::Hlo(_) => inputs.iter().map(|t| self.infer(t)).collect(),
        }
    }

    /// Run one input through the model.
    pub fn infer(&self, input: &Tensor) -> Result<Tensor> {
        match self {
            ModelKind::Net(net) => {
                if input.n != net.n() {
                    return Err(Error::ShapeMismatch {
                        expected: format!("tensors over R^{}", net.n()),
                        got: format!("R^{}", input.n),
                    });
                }
                net.forward(input)
            }
            ModelKind::Hlo(service) => {
                // f64 tensor -> f32 PJRT literal, cube shape [n; order].
                let dims: Vec<usize> = vec![input.n; input.order];
                let data: Vec<f32> = input.data.iter().map(|&x| x as f32).collect();
                let outs = service.run_f32(vec![(data, dims)])?;
                let first = outs
                    .into_iter()
                    .next()
                    .ok_or_else(|| Error::Runtime("artifact returned no outputs".into()))?;
                // Infer the output order from the element count.
                let len = first.len();
                let mut order = 0usize;
                let mut size = 1usize;
                while size < len {
                    size *= input.n;
                    order += 1;
                }
                if size != len {
                    return Err(Error::Runtime(format!(
                        "artifact output length {len} is not a power of n={}",
                        input.n
                    )));
                }
                Tensor::from_vec(input.n, order, first.into_iter().map(f64::from).collect())
            }
        }
    }
}

/// Named model registry shared across workers.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    models: HashMap<String, ModelKind>,
}

impl Registry {
    /// Register (or replace) a model under `name`.
    pub fn insert(&mut self, name: &str, model: ModelKind) {
        self.models.insert(name.to_string(), model);
    }

    /// Look up a model.
    pub fn get(&self, name: &str) -> Result<&ModelKind> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{name}'")))
    }

    /// Registered model names.
    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastmult::Group;
    use crate::layer::Init;
    use crate::nn::Activation;
    use crate::util::Rng;

    #[test]
    fn registry_lookup() {
        let mut rng = Rng::new(401);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let mut reg = Registry::default();
        reg.insert("m", ModelKind::net(net));
        assert!(reg.get("m").is_ok());
        assert!(reg.get("absent").is_err());
        assert_eq!(reg.names(), vec!["m"]);
    }

    #[test]
    fn net_infer_shape_check() {
        let mut rng = Rng::new(402);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let kind = ModelKind::net(net);
        assert!(kind.infer(&Tensor::zeros(4, 1)).is_err()); // wrong n
        assert!(kind.infer(&Tensor::zeros(3, 1)).is_ok());
    }
}
