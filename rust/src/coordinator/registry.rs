//! Model registry: named inference targets behind one coordinator.

use super::chaos::{ChaosPlan, Fault, CHAOS_PANIC_PREFIX};
use crate::error::{Error, Result};
use crate::nn::EquivariantNet;
use crate::runtime::HloService;
use crate::tensor::{Precision, Tensor, TensorOf};
use std::collections::HashMap;
use std::sync::Arc;

/// A servable model: a native equivariant network (runs the fast diagram
/// path) or a compiled HLO artifact (runs through the PJRT owner thread).
#[derive(Debug, Clone)]
pub enum ModelKind {
    /// In-process equivariant network, executed at the given precision.
    /// Requests arrive and return as `f64` tensors either way; with
    /// [`Precision::F32`] the inputs are narrowed once at the boundary,
    /// the whole network runs in `f32` (half the memory traffic on the
    /// bandwidth-bound schedule walks), and the outputs widen back.
    Net(Arc<EquivariantNet>, Precision),
    /// AOT-compiled JAX/Pallas model (expects/returns the flattened tensor;
    /// the artifact's first tuple output is used).
    Hlo(HloService),
    /// Fault-injection wrapper (tests and benches only): consults the
    /// seeded [`ChaosPlan`] before every call and panics/stalls/errors on
    /// its schedule, otherwise delegates to the inner model. Faults fire
    /// *before* the inner model runs, so an injected panic can never
    /// corrupt the wrapped model's state.
    Chaos(Box<ModelKind>, Arc<ChaosPlan>),
}

impl ModelKind {
    /// Wrap a network, serving at the default `f64` precision.
    pub fn net(net: EquivariantNet) -> Self {
        ModelKind::Net(Arc::new(net), Precision::F64)
    }
    /// Wrap a network, serving at the given precision
    /// (`[model] precision` in the config).
    pub fn net_with_precision(net: EquivariantNet, precision: Precision) -> Self {
        ModelKind::Net(Arc::new(net), precision)
    }
    /// Wrap an HLO service handle.
    pub fn hlo(service: HloService) -> Self {
        ModelKind::Hlo(service)
    }
    /// Wrap any model in the fault-injection harness (tests and benches
    /// only — see [`ChaosPlan`]).
    pub fn chaos(inner: ModelKind, plan: Arc<ChaosPlan>) -> Self {
        ModelKind::Chaos(Box::new(inner), plan)
    }

    /// The exact `(n, k)` input shape this model accepts, when it is
    /// statically known: native networks expose it (`R^n`, order
    /// `orders[0]`), HLO artifacts don't declare one. The serving door
    /// uses this to reject malformed tensors with a typed
    /// [`Error::BadRequest`] before they enter a packed batch.
    pub fn expected_shape(&self) -> Option<(usize, usize)> {
        match self {
            ModelKind::Net(net, _) => Some((net.n(), net.input_order())),
            ModelKind::Hlo(_) => None,
            ModelKind::Chaos(inner, _) => inner.expected_shape(),
        }
    }

    /// The wrapped native network and its serving precision, seen through
    /// any chaos wrapper. The shadow verifier uses this to reach the
    /// per-term reference path and the layer shapes it quarantines by;
    /// HLO artifacts have no reference twin and return `None`.
    pub fn as_net(&self) -> Option<(&Arc<EquivariantNet>, Precision)> {
        match self {
            ModelKind::Net(net, precision) => Some((net, *precision)),
            ModelKind::Hlo(_) => None,
            ModelKind::Chaos(inner, _) => inner.as_net(),
        }
    }

    /// Cancel any chaos plan wrapped around this model (see
    /// [`ChaosPlan::cancel`]): in-progress injected stalls cut their sleep
    /// short. Called by the coordinator at shutdown.
    pub fn cancel_chaos(&self) {
        if let ModelKind::Chaos(inner, plan) = self {
            plan.cancel();
            inner.cancel_chaos();
        }
    }

    /// Act on the chaos plan's next roll; returns the inner model to
    /// delegate to on the healthy/stall paths (plus whether to corrupt
    /// the output afterwards), or the injected error.
    fn chaos_gate<'a>(inner: &'a ModelKind, plan: &ChaosPlan) -> Result<(&'a ModelKind, bool)> {
        match plan.next_fault() {
            Fault::Panic => panic!("{CHAOS_PANIC_PREFIX} injected panic"),
            Fault::Stall => {
                sliced_sleep(plan.stall_duration(), plan);
                Ok((inner, false))
            }
            Fault::LongStall => {
                sliced_sleep(plan.long_stall_duration(), plan);
                Ok((inner, false))
            }
            Fault::Error => Err(Error::Coordinator("chaos: injected error".into())),
            Fault::BitFlip => Ok((inner, true)),
            Fault::None => Ok((inner, false)),
        }
    }

    /// Run a whole batch through the model: one result per input, in
    /// order. Native networks take the batched parallel path
    /// ([`EquivariantNet::apply_results`]), which keeps shape errors
    /// per-item — malformed batches fall back to per-item forwards with
    /// each failure wrapped in [`Error::BatchItem`], so errors carry the
    /// failing input's index; HLO models run through their owner thread
    /// one by one (PJRT-CPU serialises executions anyway).
    pub fn infer_batch(&self, inputs: &[&Tensor]) -> Vec<Result<Tensor>> {
        match self {
            ModelKind::Net(net, Precision::F64) => net.apply_results(inputs),
            ModelKind::Net(net, Precision::F32) => {
                let narrowed: Vec<TensorOf<f32>> = inputs.iter().map(|t| t.cast()).collect();
                let refs: Vec<&TensorOf<f32>> = narrowed.iter().collect();
                net.apply_results(&refs)
                    .into_iter()
                    .map(|r| r.map(|t| t.cast::<f64>()))
                    .collect()
            }
            ModelKind::Hlo(_) => inputs.iter().map(|t| self.infer(t)).collect(),
            ModelKind::Chaos(inner, plan) => match Self::chaos_gate(inner, plan) {
                // One roll per batch call: a batch-level panic exercises
                // the worker's per-item fallback, where each retried item
                // rolls again via `infer`. A bit-flip roll corrupts one
                // element of the first successful item's output.
                Ok((m, flip)) => {
                    let mut results = m.infer_batch(inputs);
                    if flip {
                        if let Some(out) = results.iter_mut().find_map(|r| r.as_mut().ok()) {
                            flip_one_element(out);
                        }
                    }
                    results
                }
                Err(e) => {
                    let msg = match &e {
                        Error::Coordinator(m) => m.clone(),
                        other => other.to_string(),
                    };
                    inputs
                        .iter()
                        .map(|_| Err(Error::Coordinator(msg.clone())))
                        .collect()
                }
            },
        }
    }

    /// Run one input through the model.
    pub fn infer(&self, input: &Tensor) -> Result<Tensor> {
        match self {
            ModelKind::Net(net, precision) => {
                if input.n != net.n() {
                    return Err(Error::ShapeMismatch {
                        expected: format!("tensors over R^{}", net.n()),
                        got: format!("R^{}", input.n),
                    });
                }
                match precision {
                    Precision::F64 => Ok(net
                        .apply(input)?
                        .into_single()
                        .expect("single input yields single output")),
                    Precision::F32 => Ok(net
                        .apply(&input.cast::<f32>())?
                        .into_single()
                        .expect("single input yields single output")
                        .cast::<f64>()),
                }
            }
            ModelKind::Hlo(service) => {
                // f64 tensor -> f32 PJRT literal, cube shape [n; order].
                let dims: Vec<usize> = vec![input.n; input.order];
                let data: Vec<f32> = input.data.iter().map(|&x| x as f32).collect();
                let outs = service.run_f32(vec![(data, dims)])?;
                let first = outs
                    .into_iter()
                    .next()
                    .ok_or_else(|| Error::Runtime("artifact returned no outputs".into()))?;
                // Infer the output order from the element count.
                let len = first.len();
                let mut order = 0usize;
                let mut size = 1usize;
                while size < len {
                    size *= input.n;
                    order += 1;
                }
                if size != len {
                    return Err(Error::Runtime(format!(
                        "artifact output length {len} is not a power of n={}",
                        input.n
                    )));
                }
                Tensor::from_vec(input.n, order, first.into_iter().map(f64::from).collect())
            }
            ModelKind::Chaos(inner, plan) => {
                let (m, flip) = Self::chaos_gate(inner, plan)?;
                let mut out = m.infer(input)?;
                if flip {
                    flip_one_element(&mut out);
                }
                Ok(out)
            }
        }
    }

    /// Run one input through the per-term **reference** path — the
    /// integrity oracle the shadow verifier compares the fused serving
    /// answer against. Executes at the model's serving precision (so an
    /// `f32` model is compared against an `f32` reference, isolating
    /// schedule corruption from precision loss). Chaos wrappers are
    /// transparent and roll **no** fault: the oracle must stay clean. HLO
    /// artifacts have no reference twin and report a typed error.
    pub fn infer_reference(&self, input: &Tensor) -> Result<Tensor> {
        match self {
            ModelKind::Net(net, Precision::F64) => net.forward_reference(input),
            ModelKind::Net(net, Precision::F32) => Ok(net
                .forward_reference(&input.cast::<f32>())?
                .cast::<f64>()),
            ModelKind::Hlo(_) => Err(Error::Coordinator(
                "no per-term reference path for HLO artifacts".into(),
            )),
            ModelKind::Chaos(inner, _) => inner.infer_reference(input),
        }
    }
}

/// Sleep for `total` in shutdown-aware 5ms slices (mirroring the
/// supervisor's sliced backoff sleeps): a cancelled plan cuts the sleep
/// short, so a wedged injected stall cannot delay coordinator drop.
fn sliced_sleep(total: std::time::Duration, plan: &ChaosPlan) {
    const SLICE: std::time::Duration = std::time::Duration::from_millis(5);
    let deadline = std::time::Instant::now() + total;
    while !plan.is_cancelled() {
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep(SLICE.min(deadline - now));
    }
}

/// Corrupt one element of `t` — the largest-magnitude one — by flipping
/// the LSB of its exponent (bit 52), doubling or halving it: a
/// wrong-but-plausible, always-finite answer sized far outside any
/// legitimate rounding tolerance. All-zero or non-finite outputs get the
/// first element set to 1.0 instead so the corruption never disappears.
fn flip_one_element(t: &mut Tensor) {
    let Some(idx) = t
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
        .map(|(i, _)| i)
    else {
        return;
    };
    let v = t.data[idx];
    t.data[idx] = if v == 0.0 || !v.is_finite() {
        1.0
    } else {
        f64::from_bits(v.to_bits() ^ (1u64 << 52))
    };
}

/// Named model registry shared across workers.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    models: HashMap<String, ModelKind>,
}

impl Registry {
    /// Register (or replace) a model under `name`.
    pub fn insert(&mut self, name: &str, model: ModelKind) {
        self.models.insert(name.to_string(), model);
    }

    /// Look up a model; fails with the typed [`Error::ModelNotFound`],
    /// which the serving path delivers to clients intact.
    pub fn get(&self, name: &str) -> Result<&ModelKind> {
        self.models
            .get(name)
            .ok_or_else(|| Error::ModelNotFound(name.to_string()))
    }

    /// Registered model names.
    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    /// Cancel every registered model's chaos plan (no-op for unwrapped
    /// models) — called at coordinator shutdown so injected stalls stop
    /// sleeping promptly.
    pub fn cancel_chaos(&self) {
        for model in self.models.values() {
            model.cancel_chaos();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastmult::Group;
    use crate::layer::Init;
    use crate::nn::Activation;
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn registry_lookup() {
        let mut rng = Rng::new(401);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let mut reg = Registry::default();
        reg.insert("m", ModelKind::net(net));
        assert!(reg.get("m").is_ok());
        assert!(matches!(
            reg.get("absent"),
            Err(Error::ModelNotFound(ref name)) if name == "absent"
        ));
        assert_eq!(reg.names(), vec!["m"]);
    }

    #[test]
    fn expected_shape_reports_net_shape() {
        let mut rng = Rng::new(404);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let kind = ModelKind::net(net);
        assert_eq!(kind.expected_shape(), Some((3, 2)));
        // The chaos wrapper is shape-transparent.
        let wrapped = ModelKind::chaos(kind, Arc::new(super::ChaosPlan::new(1)));
        assert_eq!(wrapped.expected_shape(), Some((3, 2)));
    }

    #[test]
    fn chaos_wrapper_delegates_and_injects() {
        let mut rng = Rng::new(405);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 1, &mut rng);
        let plain = ModelKind::net(net.clone());
        let want = plain.infer(&v).unwrap();
        // Zero rates: pure delegation.
        let healthy = ModelKind::chaos(plain.clone(), Arc::new(super::ChaosPlan::new(2)));
        assert!(healthy.infer(&v).unwrap().allclose(&want, 1e-12));
        // Always-error: typed error, inner model untouched.
        let erroring = ModelKind::chaos(
            plain.clone(),
            Arc::new(super::ChaosPlan::new(3).with_errors(1000)),
        );
        let err = erroring.infer(&v).unwrap_err();
        assert!(err.to_string().contains("chaos: injected error"), "{err}");
        let batch = erroring.infer_batch(&[&v, &v]);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.is_err()));
        // Always-panic: the payload carries the chaos prefix so harness
        // panic hooks can tell injected noise from real failures.
        let panicking = ModelKind::chaos(
            plain,
            Arc::new(super::ChaosPlan::new(4).with_panics(1000)),
        );
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            panicking.infer(&v)
        }))
        .unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.starts_with(CHAOS_PANIC_PREFIX), "payload: {msg}");
    }

    #[test]
    fn bit_flip_band_corrupts_exactly_one_element() {
        let mut rng = Rng::new(406);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 1, &mut rng);
        let plain = ModelKind::net(net);
        let want = plain.infer(&v).unwrap();
        let flipping = ModelKind::chaos(
            plain,
            Arc::new(super::ChaosPlan::new(5).with_bit_flips(1000)),
        );
        let got = flipping.infer(&v).unwrap();
        let differing = want
            .data
            .iter()
            .zip(&got.data)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(differing, 1, "exactly one element must be corrupted");
        assert!(got.data.iter().all(|x| x.is_finite()), "flips stay finite");
        // The corruption lands far outside rounding tolerance.
        assert!(got.max_abs_diff(&want) > 1e-6);
        // Batched: one flip per batch call, in the first successful item.
        let batch = flipping.infer_batch(&[&v, &v]);
        assert!(batch[0].as_ref().unwrap().max_abs_diff(&want) > 1e-6);
        assert!(batch[1].as_ref().unwrap().allclose(&want, 0.0));
    }

    #[test]
    fn reference_path_sees_through_chaos_and_skips_faults() {
        let mut rng = Rng::new(407);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 1],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let plain = ModelKind::net(net.clone());
        let want = plain.infer(&v).unwrap();
        // The oracle agrees with the fused path to rounding error.
        let oracle = plain.infer_reference(&v).unwrap();
        assert!(oracle.allclose(&want, 1e-12), "{}", oracle.max_abs_diff(&want));
        // Through an always-faulting chaos wrapper the oracle stays clean:
        // no roll is drawn, no corruption applied.
        let wrapped = ModelKind::chaos(
            plain,
            Arc::new(super::ChaosPlan::new(6).with_bit_flips(1000)),
        );
        let calls_before = match &wrapped {
            ModelKind::Chaos(_, plan) => plan.calls(),
            _ => unreachable!(),
        };
        let through = wrapped.infer_reference(&v).unwrap();
        assert!(through.allclose(&want, 1e-12));
        if let ModelKind::Chaos(_, plan) = &wrapped {
            assert_eq!(plan.calls(), calls_before, "oracle must not roll faults");
        }
        // as_net is chaos-transparent; precision rides along.
        let (seen, precision) = wrapped.as_net().unwrap();
        assert_eq!(seen.n(), 3);
        assert_eq!(precision, Precision::F64);
    }

    #[test]
    fn cancelled_long_stall_returns_promptly() {
        let mut rng = Rng::new(408);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 1, &mut rng);
        let plan = Arc::new(
            super::ChaosPlan::new(7).with_long_stalls(1000, Duration::from_secs(30)),
        );
        let wrapped = ModelKind::chaos(ModelKind::net(net), Arc::clone(&plan));
        // Pre-cancelled: the sliced sleep exits on its first poll instead
        // of serving the 30s stall.
        wrapped.cancel_chaos();
        let t0 = std::time::Instant::now();
        assert!(wrapped.infer(&v).is_ok());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "cancelled stall must not sleep out its full duration"
        );
        let mut reg = Registry::default();
        reg.insert("m", ModelKind::chaos(
            ModelKind::net(
                EquivariantNet::new(
                    Group::Symmetric,
                    3,
                    &[1, 1],
                    Activation::Identity,
                    Init::ScaledNormal,
                    &mut rng,
                )
                .unwrap(),
            ),
            Arc::new(super::ChaosPlan::new(9)),
        ));
        // Registry-wide cancellation reaches every wrapped plan.
        reg.cancel_chaos();
        if let ModelKind::Chaos(_, p) = reg.get("m").unwrap() {
            assert!(p.is_cancelled());
        }
    }

    #[test]
    fn net_infer_shape_check() {
        let mut rng = Rng::new(402);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[1, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let kind = ModelKind::net(net);
        assert!(kind.infer(&Tensor::zeros(4, 1)).is_err()); // wrong n
        assert!(kind.infer(&Tensor::zeros(3, 1)).is_ok());
    }

    #[test]
    fn f32_precision_serves_within_tolerance() {
        let mut rng = Rng::new(403);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 1],
            Activation::Identity,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let f64_kind = ModelKind::net(net.clone());
        let f32_kind = ModelKind::net_with_precision(net, Precision::F32);
        let want = f64_kind.infer(&v).unwrap();
        let got = f32_kind.infer(&v).unwrap();
        assert!(got.allclose(&want, 1e-4), "diff {}", got.max_abs_diff(&want));
        // The batched serving path narrows and widens the same way.
        let results = f32_kind.infer_batch(&[&v]);
        assert!(results[0].as_ref().unwrap().allclose(&want, 1e-4));
    }
}
