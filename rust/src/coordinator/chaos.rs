//! Seeded fault injection for the serving stack ("chaos harness").
//!
//! A [`ChaosPlan`] decides, per model call, whether to inject a panic, a
//! stall, a typed error, a silent bit-flip, or a long stall — on a
//! schedule that is a pure function of `(seed, call sequence number)`, so
//! a failing run replays exactly. The plan is consumed through
//! [`super::ModelKind::chaos`], which wraps any servable model; faults are
//! injected **at the wrapper**, before the inner model runs (bit-flips
//! after, since they corrupt outputs), so an injected panic unwinds
//! through coordinator code only and can never corrupt the inner model's
//! shared state.
//!
//! This is a test/bench harness — the stress suite and
//! `benches/coordinator_throughput.rs` drive it to certify the
//! fault-tolerance invariants (`docs/serving_robustness.md`). It has no
//! place in a production route.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

/// Injected panic payloads start with this prefix so test panic hooks can
/// keep expected chaos noise off stderr while real panics still print.
pub const CHAOS_PANIC_PREFIX: &str = "chaos:";

/// What the plan injects for one model call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Execute normally.
    None,
    /// Panic before touching the inner model.
    Panic,
    /// Sleep for the plan's stall duration, then execute normally —
    /// models a wedged dependency; with a request timeout configured the
    /// deadline machinery sheds around it.
    Stall,
    /// Return a typed error without executing.
    Error,
    /// Execute normally, then silently corrupt one element of the output —
    /// models a wrong-but-plausible answer (stale schedule, memory fault).
    /// Only the shadow-verification oracle can catch this.
    BitFlip,
    /// Sleep for the plan's long-stall duration, then execute normally —
    /// long enough to trip the hung-batch watchdog rather than merely the
    /// request deadline.
    LongStall,
}

/// A seeded fault schedule shared by every worker serving the wrapped
/// model. Call-site agnostic: the `k`-th model call (batch or single)
/// draws the `k`-th roll regardless of which thread makes it, so a given
/// `(seed, rates)` pair always injects the same fault multiset.
#[derive(Debug)]
pub struct ChaosPlan {
    seed: u64,
    panic_per_mille: u64,
    stall_per_mille: u64,
    error_per_mille: u64,
    bit_flip_per_mille: u64,
    long_stall_per_mille: u64,
    stall_for: Duration,
    long_stall_for: Duration,
    calls: AtomicU64,
    injected_panics: AtomicU64,
    injected_stalls: AtomicU64,
    injected_errors: AtomicU64,
    injected_bit_flips: AtomicU64,
    injected_long_stalls: AtomicU64,
    /// Set at coordinator shutdown so in-progress injected stalls cut
    /// their sleep short instead of delaying drop.
    cancelled: AtomicBool,
}

/// SplitMix64 finaliser: a well-mixed bijection on `u64`, enough to turn
/// `(seed, sequence)` into an independent-looking roll.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl ChaosPlan {
    /// A plan that injects nothing until rates are added.
    pub fn new(seed: u64) -> Self {
        ChaosPlan {
            seed,
            panic_per_mille: 0,
            stall_per_mille: 0,
            error_per_mille: 0,
            bit_flip_per_mille: 0,
            long_stall_per_mille: 0,
            stall_for: Duration::from_millis(1),
            long_stall_for: Duration::from_millis(100),
            calls: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            injected_stalls: AtomicU64::new(0),
            injected_errors: AtomicU64::new(0),
            injected_bit_flips: AtomicU64::new(0),
            injected_long_stalls: AtomicU64::new(0),
            cancelled: AtomicBool::new(false),
        }
    }

    /// Inject a panic on `per_mille`/1000 of calls (clamped to 1000).
    pub fn with_panics(mut self, per_mille: u64) -> Self {
        self.panic_per_mille = per_mille.min(1000);
        self
    }

    /// Inject a stall of `stall_for` on `per_mille`/1000 of calls.
    pub fn with_stalls(mut self, per_mille: u64, stall_for: Duration) -> Self {
        self.stall_per_mille = per_mille.min(1000);
        self.stall_for = stall_for;
        self
    }

    /// Inject a typed error on `per_mille`/1000 of calls.
    pub fn with_errors(mut self, per_mille: u64) -> Self {
        self.error_per_mille = per_mille.min(1000);
        self
    }

    /// Silently corrupt one output element on `per_mille`/1000 of calls.
    pub fn with_bit_flips(mut self, per_mille: u64) -> Self {
        self.bit_flip_per_mille = per_mille.min(1000);
        self
    }

    /// Inject a stall of `stall_for` — sized to exceed the watchdog
    /// threshold — on `per_mille`/1000 of calls.
    pub fn with_long_stalls(mut self, per_mille: u64, stall_for: Duration) -> Self {
        self.long_stall_per_mille = per_mille.min(1000);
        self.long_stall_for = stall_for;
        self
    }

    /// How long an injected stall sleeps.
    pub fn stall_duration(&self) -> Duration {
        self.stall_for
    }

    /// How long an injected long stall sleeps.
    pub fn long_stall_duration(&self) -> Duration {
        self.long_stall_for
    }

    /// Cut every in-progress and future injected stall short: the sliced
    /// chaos sleeps poll this between 5ms chunks. Called at coordinator
    /// shutdown so a wedged injected call cannot delay drop.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Whether [`ChaosPlan::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Draw the fault for the next model call. The roll partitions
    /// `[0, 1000)` into panic | stall | error | bit-flip | long-stall |
    /// healthy bands, so the rates are exact long-run frequencies
    /// (per mille).
    pub fn next_fault(&self) -> Fault {
        let seq = self.calls.fetch_add(1, Ordering::Relaxed);
        let roll = mix(self.seed ^ seq.wrapping_mul(0xA076_1D64_78BD_642F)) % 1000;
        let mut edge = self.panic_per_mille;
        if roll < edge {
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
            return Fault::Panic;
        }
        edge += self.stall_per_mille;
        if roll < edge {
            self.injected_stalls.fetch_add(1, Ordering::Relaxed);
            return Fault::Stall;
        }
        edge += self.error_per_mille;
        if roll < edge {
            self.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Fault::Error;
        }
        edge += self.bit_flip_per_mille;
        if roll < edge {
            self.injected_bit_flips.fetch_add(1, Ordering::Relaxed);
            return Fault::BitFlip;
        }
        edge += self.long_stall_per_mille;
        if roll < edge {
            self.injected_long_stalls.fetch_add(1, Ordering::Relaxed);
            return Fault::LongStall;
        }
        Fault::None
    }

    /// `(panics, stalls, errors)` injected so far — the harness reports
    /// these next to the coordinator's own robustness counters.
    pub fn injected(&self) -> (u64, u64, u64) {
        (
            self.injected_panics.load(Ordering::Relaxed),
            self.injected_stalls.load(Ordering::Relaxed),
            self.injected_errors.load(Ordering::Relaxed),
        )
    }

    /// `(bit_flips, long_stalls)` injected so far — the silent-failure
    /// bands, reported by the integrity bench next to detection counts.
    pub fn injected_silent(&self) -> (u64, u64) {
        (
            self.injected_bit_flips.load(Ordering::Relaxed),
            self.injected_long_stalls.load(Ordering::Relaxed),
        )
    }

    /// Model calls observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(plan: &ChaosPlan, n: usize) -> Vec<Fault> {
        (0..n).map(|_| plan.next_fault()).collect()
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = ChaosPlan::new(7).with_panics(100).with_errors(100);
        let b = ChaosPlan::new(7).with_panics(100).with_errors(100);
        assert_eq!(drain(&a, 500), drain(&b, 500));
    }

    #[test]
    fn different_seeds_differ() {
        let a = ChaosPlan::new(1).with_panics(500);
        let b = ChaosPlan::new(2).with_panics(500);
        assert_ne!(drain(&a, 200), drain(&b, 200));
    }

    #[test]
    fn zero_rates_inject_nothing() {
        let plan = ChaosPlan::new(3);
        assert!(drain(&plan, 300).iter().all(|f| *f == Fault::None));
        assert_eq!(plan.injected(), (0, 0, 0));
        assert_eq!(plan.injected_silent(), (0, 0));
        assert_eq!(plan.calls(), 300);
    }

    #[test]
    fn full_rate_always_faults() {
        let plan = ChaosPlan::new(4).with_panics(1000);
        assert!(drain(&plan, 100).iter().all(|f| *f == Fault::Panic));
        assert_eq!(plan.injected().0, 100);
    }

    #[test]
    fn rates_partition_without_overlap() {
        let plan = ChaosPlan::new(5)
            .with_panics(300)
            .with_stalls(300, Duration::from_millis(1))
            .with_errors(400);
        let faults = drain(&plan, 2000);
        assert!(faults.iter().all(|f| *f != Fault::None), "bands sum to 1000");
        let (p, s, e) = plan.injected();
        assert_eq!(p + s + e, 2000);
        // Each band's empirical frequency lands near its rate.
        let near = |got: u64, want: f64| (got as f64 / 2000.0 - want).abs() < 0.05;
        assert!(near(p, 0.3), "panics {p}");
        assert!(near(s, 0.3), "stalls {s}");
        assert!(near(e, 0.4), "errors {e}");
    }

    #[test]
    fn silent_bands_partition_after_loud_ones() {
        let plan = ChaosPlan::new(6)
            .with_errors(200)
            .with_bit_flips(400)
            .with_long_stalls(400, Duration::from_millis(50));
        let faults = drain(&plan, 2000);
        assert!(faults.iter().all(|f| *f != Fault::None), "bands sum to 1000");
        let (flips, longs) = plan.injected_silent();
        assert_eq!(flips + longs + plan.injected().2, 2000);
        let near = |got: u64, want: f64| (got as f64 / 2000.0 - want).abs() < 0.05;
        assert!(near(flips, 0.4), "bit flips {flips}");
        assert!(near(longs, 0.4), "long stalls {longs}");
        // Determinism holds for the new bands too.
        let twin = ChaosPlan::new(6)
            .with_errors(200)
            .with_bit_flips(400)
            .with_long_stalls(400, Duration::from_millis(50));
        assert_eq!(faults, drain(&twin, 2000));
    }

    #[test]
    fn cancellation_flag_flips_once() {
        let plan = ChaosPlan::new(8).with_stalls(1000, Duration::from_millis(500));
        assert!(!plan.is_cancelled());
        plan.cancel();
        assert!(plan.is_cancelled());
    }
}
