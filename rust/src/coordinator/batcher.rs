//! The batching loop: drain the request queue into per-model batches
//! bounded by `max_batch` and `batch_window`, then hand batches to the
//! worker pool.

use super::metrics::Metrics;
use crate::error::Result;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One enqueued request.
pub(crate) struct WorkItem {
    pub model: String,
    pub input: Tensor,
    pub enqueued: Instant,
    pub respond: Sender<Result<Tensor>>,
}

/// A batch of same-model requests handed to a worker.
pub(crate) struct Batch {
    pub model: String,
    pub items: Vec<WorkItem>,
}

/// Flush every pending group whose *own* oldest item has waited out the
/// window — younger models keep accumulating until their turn. A group's
/// oldest item is found by min, not `first()`: submitters stamp `enqueued`
/// before sending, so arrival order need not match stamp order. Returns
/// the recomputed window anchor (min enqueue over what remains pending),
/// or `None` in the outer `Option` if the dispatch channel closed.
fn flush_expired(
    pending: &mut HashMap<String, Vec<WorkItem>>,
    dispatch: &Sender<Batch>,
    metrics: &Metrics,
    window: Duration,
) -> Option<Option<Instant>> {
    let expired: Vec<String> = pending
        .iter()
        .filter(|(_, g)| {
            g.iter()
                .map(|it| it.enqueued)
                .min()
                .is_some_and(|t| t.elapsed() >= window)
        })
        .map(|(model, _)| model.clone())
        .collect();
    for model in expired {
        if let Some(items) = pending.remove(&model) {
            metrics.on_batch(items.len());
            if dispatch.send(Batch { model, items }).is_err() {
                return None;
            }
        }
    }
    Some(
        pending
            .values()
            .flat_map(|g| g.iter().map(|it| it.enqueued))
            .min(),
    )
}

/// Run the batching loop until the request channel closes. Flushes
/// per-model groups when either `max_batch` is reached or the oldest item
/// in the group exceeds `window`.
pub(crate) fn run(
    rx: Receiver<WorkItem>,
    dispatch: Sender<Batch>,
    metrics: Arc<Metrics>,
    max_batch: usize,
    window: Duration,
) {
    let mut pending: HashMap<String, Vec<WorkItem>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        // Pick a receive timeout: the remaining window if anything pends.
        let timeout = match oldest {
            None => Duration::from_millis(50),
            Some(t0) => window.saturating_sub(t0.elapsed()),
        };
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let model = item.model.clone();
                // Keep `oldest` = min enqueue over everything pending:
                // submitters stamp `enqueued` before sending, so an
                // arriving item can carry an earlier stamp than the
                // current anchor.
                oldest = Some(oldest.map_or(item.enqueued, |o| o.min(item.enqueued)));
                let group = pending.entry(model.clone()).or_default();
                group.push(item);
                if group.len() >= max_batch {
                    let items = pending.remove(&model).unwrap();
                    metrics.on_batch(items.len());
                    if dispatch.send(Batch { model, items }).is_err() {
                        return;
                    }
                    // Recompute the window anchor from what is still
                    // pending: the flushed group's enqueue times must not
                    // keep counting down the other models' windows (a
                    // stale `oldest` fired them early).
                    oldest = pending
                        .values()
                        .flat_map(|g| g.iter().map(|it| it.enqueued))
                        .min();
                }
                // Under sustained traffic `recv_timeout` keeps returning
                // Ok, so the Timeout arm below may never run — sweep
                // expired windows here too, or a quiet model's partial
                // batch would starve behind a busy model's stream.
                if oldest.is_some_and(|t| t.elapsed() >= window) {
                    match flush_expired(&mut pending, &dispatch, &metrics, window) {
                        Some(o) => oldest = o,
                        None => return,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // Window expired (or idle poll): the timeout arm has the
                // same stale-anchor hazard as the max_batch arm — the
                // global `oldest` belongs to one group — so only the
                // groups whose own window expired are flushed.
                match flush_expired(&mut pending, &dispatch, &metrics, window) {
                    Some(o) => oldest = o,
                    None => return,
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Shutdown: flush and exit.
                for (model, items) in pending.drain() {
                    metrics.on_batch(items.len());
                    let _ = dispatch.send(Batch { model, items });
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    fn item(model: &str) -> (WorkItem, Receiver<Result<Tensor>>) {
        let (tx, rx) = mpsc::channel();
        (
            WorkItem {
                model: model.into(),
                input: Tensor::zeros(2, 1),
                enqueued: Instant::now(),
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let h = thread::spawn(move || run(rx, dtx, m2, 2, Duration::from_millis(100)));
        let (a, _ra) = item("m");
        let (b, _rb) = item("m");
        let (c, _rc) = item("m");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        tx.send(c).unwrap();
        // First two flush at max_batch = 2.
        let batch = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 2);
        drop(tx); // shutdown flushes the remainder
        let tail = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(tail.items.len(), 1);
        h.join().unwrap();
        assert_eq!(metrics.snapshot().batches, 2);
    }

    #[test]
    fn window_flushes_partial_batches() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 100, Duration::from_millis(5)));
        let (a, _ra) = item("m");
        tx.send(a).unwrap();
        let batch = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    /// Regression: after a `max_batch` flush of one model, the window
    /// anchor must be recomputed from the *remaining* pending items. The
    /// old code left `oldest` pointing at the flushed model's first
    /// enqueue time, firing other models' windows early.
    #[test]
    fn max_batch_flush_resets_window_anchor_for_other_models() {
        // Margins: a1 ages 450ms of a 900ms window before the flush, so
        // the stale anchor would fire b ~450ms after its enqueue while the
        // fix waits the full 900ms — the 675ms probe sits 225ms clear of
        // both, tolerating CI scheduler jitter.
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 2, Duration::from_millis(900)));
        // a1 arrives, ages for half the window…
        let (a1, _r1) = item("a");
        tx.send(a1).unwrap();
        thread::sleep(Duration::from_millis(450));
        // …then b1 (fresh) and a2 (which completes model a's max_batch).
        let (b1, _r2) = item("b");
        tx.send(b1).unwrap();
        let (a2, _r3) = item("a");
        tx.send(a2).unwrap();
        let first = drx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.model, "a");
        assert_eq!(first.items.len(), 2);
        // With the stale anchor, b's window inherited a1's age and fired
        // ~450ms after b was enqueued; it must wait out its own 900ms.
        assert!(
            drx.recv_timeout(Duration::from_millis(675)).is_err(),
            "model-b batch flushed before its own window expired"
        );
        let late = drx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(late.model, "b");
        assert_eq!(late.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    /// The timeout arm must flush only the groups whose own window
    /// expired — a younger model pending alongside the expiring one keeps
    /// accumulating until its own deadline.
    #[test]
    fn timeout_flushes_only_expired_groups() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 100, Duration::from_millis(900)));
        // a ages for half the window, then b arrives.
        let (a1, _r1) = item("a");
        tx.send(a1).unwrap();
        thread::sleep(Duration::from_millis(450));
        let (b1, _r2) = item("b");
        tx.send(b1).unwrap();
        // a's window expires first: a flushes alone, b stays pending.
        let first = drx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.model, "a");
        assert_eq!(first.items.len(), 1);
        // b is ~450ms into its 900ms window at a's flush, so it fires
        // ~450ms later; the 225ms probe sits 225ms clear of that deadline
        // (and a buggy full drain would land b's batch inside it).
        assert!(
            drx.recv_timeout(Duration::from_millis(225)).is_err(),
            "model-b flushed on model-a's deadline"
        );
        let late = drx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(late.model, "b");
        assert_eq!(late.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_model() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 10, Duration::from_millis(5)));
        let (a, _ra) = item("x");
        let (b, _rb) = item("y");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        let b1 = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b2 = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        let mut models = vec![b1.model, b2.model];
        models.sort();
        assert_eq!(models, vec!["x".to_string(), "y".to_string()]);
        assert_eq!(b1.items.len() + b2.items.len(), 2);
        drop(tx);
        h.join().unwrap();
    }
}
