//! The batching loop: drain the request queue into per-model batches
//! bounded by `max_batch` and `batch_window`, then hand batches to the
//! worker pool through a poison-proof [`BatchQueue`].
//!
//! Robustness duties on this thread (see `docs/serving_robustness.md`):
//! items whose deadline already passed are **shed before dispatch** — the
//! waiter gets a typed [`Error::DeadlineExceeded`] immediately instead of
//! wasting a worker's schedule walk — and dispatch goes through a shared
//! injector queue rather than an `Arc<Mutex<Receiver>>`, so a panicking
//! worker can never poison the fan-out path for its siblings.

use super::metrics::Metrics;
use super::server::InflightGuard;
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// One enqueued request.
pub(crate) struct WorkItem {
    pub model: String,
    pub input: Tensor,
    pub enqueued: Instant,
    /// Absolute deadline stamped at submit (`[server] request_timeout_ms`);
    /// `None` means the request never expires server-side.
    pub deadline: Option<Instant>,
    pub respond: std::sync::mpsc::Sender<Result<Tensor>>,
    /// Releases the per-model admission slot when the item reaches any
    /// terminal outcome (response sent, typed error sent, or shed) — the
    /// guard drops with the item, so no path can leak an inflight count.
    pub inflight: Option<InflightGuard>,
}

impl WorkItem {
    /// Whether the item's deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// A batch of same-model requests handed to a worker.
pub(crate) struct Batch {
    pub model: String,
    pub items: Vec<WorkItem>,
}

/// Outcome of a bounded [`BatchQueue::pop_for`]: workers run as tasks on
/// the shared executor, so "nothing yet" (yield the pool thread) must be
/// distinguishable from "closed and drained" (exit the slot).
pub(crate) enum PopWait {
    Batch(Batch),
    Idle,
    Drained,
}

/// Recover a mutex guard even if a previous holder panicked. The queue's
/// critical sections never run model code, so the protected state is
/// always consistent; recovering (instead of unwrapping) means one
/// panicked thread can never wedge the rest of the pool.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct QueueInner {
    queue: VecDeque<Batch>,
    closed: bool,
}

/// Poison-proof multi-consumer batch injector: the batcher pushes, workers
/// pop. Replaces the old `Arc<Mutex<Receiver<Batch>>>` fan-out whose
/// poisoning cascaded a single worker panic through the whole pool.
pub(crate) struct BatchQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
}

impl BatchQueue {
    pub fn new() -> Arc<Self> {
        Arc::new(BatchQueue {
            inner: Mutex::new(QueueInner {
                queue: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        })
    }

    /// Enqueue a batch; returns `false` if the queue has been closed.
    pub fn push(&self, batch: Batch) -> bool {
        let mut g = lock_recover(&self.inner);
        if g.closed {
            return false;
        }
        g.queue.push_back(batch);
        drop(g);
        self.ready.notify_one();
        true
    }

    /// Blocking pop; `None` means the queue is closed **and** drained, so
    /// the worker should exit.
    pub fn pop(&self) -> Option<Batch> {
        let mut g = lock_recover(&self.inner);
        loop {
            if let Some(b) = g.queue.pop_front() {
                return Some(b);
            }
            if g.closed {
                return None;
            }
            g = self
                .ready
                .wait(g)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Bounded pop for the executor-run workers: a worker slot must not
    /// camp on a pool thread while the queue is idle, so it pops with a
    /// timeout and yields its thread on [`PopWait::Idle`].
    pub fn pop_for(&self, timeout: Duration) -> PopWait {
        let deadline = Instant::now() + timeout;
        let mut g = lock_recover(&self.inner);
        loop {
            if let Some(b) = g.queue.pop_front() {
                return PopWait::Batch(b);
            }
            if g.closed {
                return PopWait::Drained;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopWait::Idle;
            }
            let (guard, _) = self
                .ready
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
    }

    /// Pop with a timeout (used by tests); `None` means nothing arrived
    /// within `timeout` (or the queue is closed and drained).
    #[cfg(test)]
    pub fn try_pop_for(&self, timeout: Duration) -> Option<Batch> {
        match self.pop_for(timeout) {
            PopWait::Batch(b) => Some(b),
            PopWait::Idle | PopWait::Drained => None,
        }
    }

    /// Close the queue: no further pushes are accepted; blocked poppers
    /// drain what remains and then see `None`.
    pub fn close(&self) {
        let mut g = lock_recover(&self.inner);
        g.closed = true;
        drop(g);
        self.ready.notify_all();
    }

    /// Closed and empty — nothing will ever come out again. Stable once
    /// true (closing forbids pushes), which the supervisor relies on when
    /// deciding whether a dead worker still needs a replacement.
    pub fn is_drained(&self) -> bool {
        let g = lock_recover(&self.inner);
        g.closed && g.queue.is_empty()
    }
}

/// Shed every expired item from `items`, delivering the typed
/// [`Error::DeadlineExceeded`] terminal outcome to each waiter; returns
/// the still-live remainder in order. Shared by the batcher (shed before
/// dispatch) and the workers (shed before execution).
pub(crate) fn shed_expired(
    items: Vec<WorkItem>,
    metrics: &Metrics,
    now: Instant,
) -> Vec<WorkItem> {
    let mut live = Vec::with_capacity(items.len());
    for item in items {
        if item.expired(now) {
            metrics.on_shed_expired();
            let _ = item.respond.send(Err(Error::DeadlineExceeded));
        } else {
            live.push(item);
        }
    }
    live
}

/// Shed expired items, then dispatch whatever remains (skipping batches
/// shed down to nothing). Returns `false` if the dispatch queue closed.
fn dispatch_batch(
    model: String,
    items: Vec<WorkItem>,
    dispatch: &BatchQueue,
    metrics: &Metrics,
) -> bool {
    let items = shed_expired(items, metrics, Instant::now());
    if items.is_empty() {
        return true;
    }
    metrics.on_batch(items.len());
    dispatch.push(Batch { model, items })
}

/// Flush every pending group whose *own* oldest item has waited out the
/// window — younger models keep accumulating until their turn. A group's
/// oldest item is found by min, not `first()`: submitters stamp `enqueued`
/// before sending, so arrival order need not match stamp order. Returns
/// the recomputed window anchor (min enqueue over what remains pending),
/// or `None` in the outer `Option` if the dispatch queue closed.
fn flush_expired(
    pending: &mut HashMap<String, Vec<WorkItem>>,
    dispatch: &BatchQueue,
    metrics: &Metrics,
    window: Duration,
) -> Option<Option<Instant>> {
    let expired: Vec<String> = pending
        .iter()
        .filter(|(_, g)| {
            g.iter()
                .map(|it| it.enqueued)
                .min()
                .is_some_and(|t| t.elapsed() >= window)
        })
        .map(|(model, _)| model.clone())
        .collect();
    for model in expired {
        if let Some(items) = pending.remove(&model) {
            if !dispatch_batch(model, items, dispatch, metrics) {
                return None;
            }
        }
    }
    Some(
        pending
            .values()
            .flat_map(|g| g.iter().map(|it| it.enqueued))
            .min(),
    )
}

/// SLO feedback controller for the batch window (`[server]
/// target_p95_ms`). Every `ADJUST_PERIOD` it compares the live p95 against
/// the target: over target → narrow the window (trade batching efficiency
/// for latency); under half the target → widen it (recover throughput).
/// The window is clamped to `[base/8, base×16]` so a transient spike can
/// never collapse batching entirely or stall requests indefinitely.
struct AdaptiveWindow {
    target_s: f64,
    lo: Duration,
    hi: Duration,
    current: Duration,
    last_adjust: Instant,
}

impl AdaptiveWindow {
    const ADJUST_PERIOD: Duration = Duration::from_millis(100);

    fn new(base: Duration, target: Duration) -> AdaptiveWindow {
        // A zero configured window still needs non-degenerate bounds to
        // adapt within; 100µs is the documented fallback base.
        let base = if base.is_zero() {
            Duration::from_micros(100)
        } else {
            base
        };
        AdaptiveWindow {
            target_s: target.as_secs_f64(),
            lo: (base / 8).max(Duration::from_micros(1)),
            hi: base * 16,
            current: base,
            last_adjust: Instant::now(),
        }
    }

    /// Current window, re-evaluated at most once per `ADJUST_PERIOD`.
    fn window(&mut self, metrics: &Metrics) -> Duration {
        if self.last_adjust.elapsed() < Self::ADJUST_PERIOD {
            return self.current;
        }
        self.last_adjust = Instant::now();
        let p95 = metrics.latency_p95_s();
        if p95 <= 0.0 {
            return self.current; // no completed requests yet
        }
        let next = if p95 > self.target_s {
            self.current.mul_f64(0.75)
        } else if p95 < 0.5 * self.target_s {
            self.current.mul_f64(1.25)
        } else {
            self.current
        };
        let next = next.clamp(self.lo, self.hi);
        if next != self.current {
            self.current = next;
            metrics.set_batch_window(next);
        }
        self.current
    }
}

/// Run the batching loop until the request channel closes, then close the
/// dispatch queue so the worker pool drains and exits. Flushes per-model
/// groups when either `max_batch` is reached or the oldest item in the
/// group exceeds the window (fixed at `window`, or SLO-adaptive around it
/// when `target_p95` is set).
pub(crate) fn run(
    rx: Receiver<WorkItem>,
    dispatch: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    max_batch: usize,
    window: Duration,
    target_p95: Option<Duration>,
) {
    run_inner(rx, &dispatch, &metrics, max_batch, window, target_p95);
    dispatch.close();
}

fn run_inner(
    rx: Receiver<WorkItem>,
    dispatch: &BatchQueue,
    metrics: &Metrics,
    max_batch: usize,
    base_window: Duration,
    target_p95: Option<Duration>,
) {
    let mut adaptive = target_p95.map(|t| AdaptiveWindow::new(base_window, t));
    metrics.set_batch_window(adaptive.as_ref().map_or(base_window, |a| a.current));
    let mut pending: HashMap<String, Vec<WorkItem>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        let window = adaptive.as_mut().map_or(base_window, |a| a.window(metrics));
        // Pick a receive timeout: the remaining window if anything pends.
        let timeout = match oldest {
            None => Duration::from_millis(50),
            Some(t0) => window.saturating_sub(t0.elapsed()),
        };
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let model = item.model.clone();
                // Keep `oldest` = min enqueue over everything pending:
                // submitters stamp `enqueued` before sending, so an
                // arriving item can carry an earlier stamp than the
                // current anchor.
                oldest = Some(oldest.map_or(item.enqueued, |o| o.min(item.enqueued)));
                let group = pending.entry(model.clone()).or_default();
                group.push(item);
                if group.len() >= max_batch {
                    let items = pending.remove(&model).unwrap();
                    if !dispatch_batch(model, items, dispatch, metrics) {
                        return;
                    }
                    // Recompute the window anchor from what is still
                    // pending: the flushed group's enqueue times must not
                    // keep counting down the other models' windows (a
                    // stale `oldest` fired them early).
                    oldest = pending
                        .values()
                        .flat_map(|g| g.iter().map(|it| it.enqueued))
                        .min();
                }
                // Under sustained traffic `recv_timeout` keeps returning
                // Ok, so the Timeout arm below may never run — sweep
                // expired windows here too, or a quiet model's partial
                // batch would starve behind a busy model's stream.
                if oldest.is_some_and(|t| t.elapsed() >= window) {
                    match flush_expired(&mut pending, dispatch, metrics, window) {
                        Some(o) => oldest = o,
                        None => return,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // Window expired (or idle poll): the timeout arm has the
                // same stale-anchor hazard as the max_batch arm — the
                // global `oldest` belongs to one group — so only the
                // groups whose own window expired are flushed.
                match flush_expired(&mut pending, dispatch, metrics, window) {
                    Some(o) => oldest = o,
                    None => return,
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Shutdown: flush and exit (expired items still shed, so
                // every waiter gets its terminal outcome before the close).
                for (model, items) in pending.drain() {
                    if !dispatch_batch(model, items, dispatch, metrics) {
                        return;
                    }
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    fn item(model: &str) -> (WorkItem, Receiver<Result<Tensor>>) {
        let (tx, rx) = mpsc::channel();
        (
            WorkItem {
                model: model.into(),
                input: Tensor::zeros(2, 1),
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
                inflight: None,
            },
            rx,
        )
    }

    fn expired_item(model: &str) -> (WorkItem, Receiver<Result<Tensor>>) {
        let (mut it, rx) = item(model);
        it.deadline = Some(Instant::now() - Duration::from_millis(1));
        (it, rx)
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, m2, 2, Duration::from_millis(100), None));
        let (a, _ra) = item("m");
        let (b, _rb) = item("m");
        let (c, _rc) = item("m");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        tx.send(c).unwrap();
        // First two flush at max_batch = 2.
        let batch = q.try_pop_for(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 2);
        drop(tx); // shutdown flushes the remainder
        let tail = q.try_pop_for(Duration::from_secs(1)).unwrap();
        assert_eq!(tail.items.len(), 1);
        h.join().unwrap();
        assert_eq!(metrics.snapshot().batches, 2);
        assert!(q.is_drained(), "batcher must close the queue on exit");
    }

    #[test]
    fn window_flushes_partial_batches() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, metrics, 100, Duration::from_millis(5), None));
        let (a, _ra) = item("m");
        tx.send(a).unwrap();
        let batch = q.try_pop_for(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    /// Regression: after a `max_batch` flush of one model, the window
    /// anchor must be recomputed from the *remaining* pending items. The
    /// old code left `oldest` pointing at the flushed model's first
    /// enqueue time, firing other models' windows early.
    #[test]
    fn max_batch_flush_resets_window_anchor_for_other_models() {
        // Margins: a1 ages 450ms of a 900ms window before the flush, so
        // the stale anchor would fire b ~450ms after its enqueue while the
        // fix waits the full 900ms — the 675ms probe sits 225ms clear of
        // both, tolerating CI scheduler jitter.
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, metrics, 2, Duration::from_millis(900), None));
        // a1 arrives, ages for half the window…
        let (a1, _r1) = item("a");
        tx.send(a1).unwrap();
        thread::sleep(Duration::from_millis(450));
        // …then b1 (fresh) and a2 (which completes model a's max_batch).
        let (b1, _r2) = item("b");
        tx.send(b1).unwrap();
        let (a2, _r3) = item("a");
        tx.send(a2).unwrap();
        let first = q.try_pop_for(Duration::from_secs(5)).unwrap();
        assert_eq!(first.model, "a");
        assert_eq!(first.items.len(), 2);
        // With the stale anchor, b's window inherited a1's age and fired
        // ~450ms after b was enqueued; it must wait out its own 900ms.
        assert!(
            q.try_pop_for(Duration::from_millis(675)).is_none(),
            "model-b batch flushed before its own window expired"
        );
        let late = q.try_pop_for(Duration::from_secs(5)).unwrap();
        assert_eq!(late.model, "b");
        assert_eq!(late.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    /// The timeout arm must flush only the groups whose own window
    /// expired — a younger model pending alongside the expiring one keeps
    /// accumulating until its own deadline.
    #[test]
    fn timeout_flushes_only_expired_groups() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, metrics, 100, Duration::from_millis(900), None));
        // a ages for half the window, then b arrives.
        let (a1, _r1) = item("a");
        tx.send(a1).unwrap();
        thread::sleep(Duration::from_millis(450));
        let (b1, _r2) = item("b");
        tx.send(b1).unwrap();
        // a's window expires first: a flushes alone, b stays pending.
        let first = q.try_pop_for(Duration::from_secs(5)).unwrap();
        assert_eq!(first.model, "a");
        assert_eq!(first.items.len(), 1);
        // b is ~450ms into its 900ms window at a's flush, so it fires
        // ~450ms later; the 225ms probe sits 225ms clear of that deadline
        // (and a buggy full drain would land b's batch inside it).
        assert!(
            q.try_pop_for(Duration::from_millis(225)).is_none(),
            "model-b flushed on model-a's deadline"
        );
        let late = q.try_pop_for(Duration::from_secs(5)).unwrap();
        assert_eq!(late.model, "b");
        assert_eq!(late.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_model() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, metrics, 10, Duration::from_millis(5), None));
        let (a, _ra) = item("x");
        let (b, _rb) = item("y");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        let b1 = q.try_pop_for(Duration::from_secs(1)).unwrap();
        let b2 = q.try_pop_for(Duration::from_secs(1)).unwrap();
        let mut models = vec![b1.model, b2.model];
        models.sort();
        assert_eq!(models, vec!["x".to_string(), "y".to_string()]);
        assert_eq!(b1.items.len() + b2.items.len(), 2);
        drop(tx);
        h.join().unwrap();
    }

    /// Expired items are shed before dispatch: the waiter gets the typed
    /// deadline error, the live batch-mate still flows through, and the
    /// dispatched batch size (and `mean_batch_size`) excludes the shed
    /// item.
    #[test]
    fn expired_items_are_shed_before_dispatch() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, m2, 2, Duration::from_millis(50), None));
        let (dead, dead_rx) = expired_item("m");
        let (live, _live_rx) = item("m");
        tx.send(dead).unwrap();
        tx.send(live).unwrap();
        let batch = q.try_pop_for(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 1, "expired item must not be dispatched");
        assert!(matches!(
            dead_rx.recv_timeout(Duration::from_secs(1)).unwrap(),
            Err(Error::DeadlineExceeded)
        ));
        drop(tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.shed_expired, 1);
        assert_eq!(snap.batches, 1);
        assert!((snap.mean_batch_size - 1.0).abs() < 1e-12);
    }

    /// A group shed down to nothing must not dispatch an empty batch.
    #[test]
    fn fully_expired_group_dispatches_nothing() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let q = BatchQueue::new();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let q2 = q.clone();
        let h = thread::spawn(move || run(rx, q2, m2, 2, Duration::from_millis(5), None));
        let (d1, r1) = expired_item("m");
        let (d2, r2) = expired_item("m");
        tx.send(d1).unwrap();
        tx.send(d2).unwrap();
        assert!(q.try_pop_for(Duration::from_millis(200)).is_none());
        for r in [r1, r2] {
            assert!(matches!(
                r.recv_timeout(Duration::from_secs(1)).unwrap(),
                Err(Error::DeadlineExceeded)
            ));
        }
        drop(tx);
        h.join().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.shed_expired, 2);
        assert_eq!(snap.batches, 0);
    }

    /// The queue recovers its mutex even after a panic poisoned it: a
    /// holder panicking mid-push must not wedge later pushes or pops.
    #[test]
    fn batch_queue_survives_poisoning() {
        let q = BatchQueue::new();
        let q2 = q.clone();
        let _ = thread::spawn(move || {
            let _g = q2.inner.lock().unwrap();
            panic!("poison the queue mutex");
        })
        .join();
        // The mutex is now poisoned; every operation must still work.
        let (it, _rx) = item("m");
        assert!(q.push(Batch {
            model: "m".into(),
            items: vec![it],
        }));
        assert!(q.try_pop_for(Duration::from_millis(100)).is_some());
        q.close();
        assert!(q.pop().is_none());
        assert!(q.is_drained());
    }
}
