//! The batching loop: drain the request queue into per-model batches
//! bounded by `max_batch` and `batch_window`, then hand batches to the
//! worker pool.

use super::metrics::Metrics;
use crate::error::Result;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One enqueued request.
pub(crate) struct WorkItem {
    pub model: String,
    pub input: Tensor,
    pub enqueued: Instant,
    pub respond: Sender<Result<Tensor>>,
}

/// A batch of same-model requests handed to a worker.
pub(crate) struct Batch {
    pub model: String,
    pub items: Vec<WorkItem>,
}

/// Run the batching loop until the request channel closes. Flushes
/// per-model groups when either `max_batch` is reached or the oldest item
/// in the group exceeds `window`.
pub(crate) fn run(
    rx: Receiver<WorkItem>,
    dispatch: Sender<Batch>,
    metrics: Arc<Metrics>,
    max_batch: usize,
    window: Duration,
) {
    let mut pending: HashMap<String, Vec<WorkItem>> = HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        // Pick a receive timeout: the remaining window if anything pends.
        let timeout = match oldest {
            None => Duration::from_millis(50),
            Some(t0) => window.saturating_sub(t0.elapsed()),
        };
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                let model = item.model.clone();
                if oldest.is_none() {
                    oldest = Some(item.enqueued);
                }
                let group = pending.entry(model.clone()).or_default();
                group.push(item);
                if group.len() >= max_batch {
                    let items = pending.remove(&model).unwrap();
                    metrics.on_batch(items.len());
                    if dispatch.send(Batch { model, items }).is_err() {
                        return;
                    }
                    if pending.is_empty() {
                        oldest = None;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // Window expired (or idle poll): flush everything pending.
                if !pending.is_empty() {
                    for (model, items) in pending.drain() {
                        metrics.on_batch(items.len());
                        if dispatch.send(Batch { model, items }).is_err() {
                            return;
                        }
                    }
                    oldest = None;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Shutdown: flush and exit.
                for (model, items) in pending.drain() {
                    metrics.on_batch(items.len());
                    let _ = dispatch.send(Batch { model, items });
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    fn item(model: &str) -> (WorkItem, Receiver<Result<Tensor>>) {
        let (tx, rx) = mpsc::channel();
        (
            WorkItem {
                model: model.into(),
                input: Tensor::zeros(2, 1),
                enqueued: Instant::now(),
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let h = thread::spawn(move || run(rx, dtx, m2, 2, Duration::from_millis(100)));
        let (a, _ra) = item("m");
        let (b, _rb) = item("m");
        let (c, _rc) = item("m");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        tx.send(c).unwrap();
        // First two flush at max_batch = 2.
        let batch = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 2);
        drop(tx); // shutdown flushes the remainder
        let tail = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(tail.items.len(), 1);
        h.join().unwrap();
        assert_eq!(metrics.snapshot().batches, 2);
    }

    #[test]
    fn window_flushes_partial_batches() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 100, Duration::from_millis(5)));
        let (a, _ra) = item("m");
        tx.send(a).unwrap();
        let batch = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.items.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_model() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (dtx, drx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let h = thread::spawn(move || run(rx, dtx, metrics, 10, Duration::from_millis(5)));
        let (a, _ra) = item("x");
        let (b, _rb) = item("y");
        tx.send(a).unwrap();
        tx.send(b).unwrap();
        let b1 = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b2 = drx.recv_timeout(Duration::from_secs(1)).unwrap();
        let mut models = vec![b1.model, b2.model];
        models.sort();
        assert_eq!(models, vec!["x".to_string(), "y".to_string()]);
        assert_eq!(b1.items.len() + b2.items.len(), 2);
        drop(tx);
        h.join().unwrap();
    }
}
