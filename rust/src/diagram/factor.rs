//! The paper's **Factor** procedure (§5.2, Figures 1, 4, 7).
//!
//! `Factor` drags the strings of a `(k,l)`-partition diagram to express it
//! as `σ_l ∘ d_planar ∘ σ_k`: a permutation of the input axes, an
//! algorithmically planar middle diagram, and a permutation of the output
//! axes. The permutations are memory moves (the paper's `Permute`); all
//! arithmetic happens in the planar middle.
//!
//! The returned [`Factored`] carries the two permutations in the exact form
//! [`crate::tensor::Tensor::permute_axes`] consumes, plus a [`PlanarLayout`]
//! describing the middle diagram by block sizes only — which is all
//! `PlanarMult` needs.

use super::{BlockKind, Diagram};
use crate::error::{Error, Result};

/// Structural description of an algorithmically planar diagram: block sizes
/// in planar (left→right) order. `PlanarMult` is driven entirely by this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanarLayout {
    /// Top row length `l`.
    pub l: usize,
    /// Bottom row length `k`.
    pub k: usize,
    /// Sizes of top-row-only blocks, planar order (far left of the top row).
    pub top_blocks: Vec<usize>,
    /// `(upper size, lower size)` of cross blocks, planar order. For Brauer
    /// diagrams every entry is `(1, 1)`.
    pub cross_blocks: Vec<(usize, usize)>,
    /// Sizes of bottom-row-only blocks, planar order left→right
    /// (non-decreasing, per Definition 31 — largest block at the far right).
    pub bottom_blocks: Vec<usize>,
    /// Number of free vertices at the far right of the top row (`s`);
    /// zero for non-jellyfish diagrams.
    pub free_top: usize,
    /// Number of free vertices at the far right of the bottom row
    /// (`n - s`); zero for non-jellyfish diagrams.
    pub free_bottom: usize,
}

impl PlanarLayout {
    /// Number of cross blocks `d`.
    pub fn d(&self) -> usize {
        self.cross_blocks.len()
    }
    /// Number of top-only blocks `t`.
    pub fn t(&self) -> usize {
        self.top_blocks.len()
    }
    /// Number of bottom-only blocks `b`.
    pub fn b(&self) -> usize {
        self.bottom_blocks.len()
    }
}

/// Result of `Factor`: `d == σ_l ∘ d_planar ∘ σ_k` (Figure 1).
#[derive(Debug, Clone)]
pub struct Factored {
    /// Input axis permutation: planar bottom axis `q` carries original
    /// input axis `perm_in[q]`. Apply as `v.permute_axes(&perm_in)` — this
    /// is `Permute(v, σ_k)`.
    pub perm_in: Vec<usize>,
    /// Output axis permutation: final output axis `p` carries planar top
    /// axis `perm_out[p]`. Apply as `w.permute_axes(&perm_out)` — this is
    /// `Permute(w, σ_l)`.
    pub perm_out: Vec<usize>,
    /// The algorithmically planar middle diagram (kept for verification and
    /// display; `PlanarMult` uses only `layout`).
    pub planar: Diagram,
    /// Block-size description of `planar`.
    pub layout: PlanarLayout,
}

/// Factor a `(k,l)`-partition diagram (S_n semantics: singleton blocks are
/// ordinary one-vertex blocks, not free vertices). Also correct for Brauer
/// diagrams, where every block has size 2 (O(n) / Sp(n) / SO(n)-E_β cases).
pub fn factor(d: &Diagram) -> Factored {
    build(d, None).expect("factor of a partition diagram cannot fail")
}

/// Factor an `(l+k)\n`-diagram (SO(n)-H_α case, Figure 7): singleton blocks
/// are free vertices and are pulled to the far right of their rows,
/// preserving their order.
pub fn factor_jellyfish(d: &Diagram, n: usize) -> Result<Factored> {
    if !d.is_jellyfish(n) {
        return Err(Error::InvalidDiagramForGroup {
            group: "SO(n)".into(),
            reason: format!("not an (l+k)\\{n}-diagram"),
        });
    }
    build(d, Some(n))
}

fn build(d: &Diagram, jellyfish_n: Option<usize>) -> Result<Factored> {
    let (l, k) = (d.l, d.k);

    // --- Classify blocks -------------------------------------------------
    let mut top_blocks: Vec<&Vec<usize>> = Vec::new(); // top-row-only
    let mut cross_blocks: Vec<&Vec<usize>> = Vec::new();
    let mut bottom_blocks: Vec<&Vec<usize>> = Vec::new();
    let mut free_top: Vec<usize> = Vec::new();
    let mut free_bottom: Vec<usize> = Vec::new();
    for b in d.blocks() {
        if jellyfish_n.is_some() && b.len() == 1 {
            let v = b[0];
            if v < l {
                free_top.push(v);
            } else {
                free_bottom.push(v);
            }
            continue;
        }
        match d.block_kind(b) {
            BlockKind::Top => top_blocks.push(b),
            BlockKind::Bottom => bottom_blocks.push(b),
            BlockKind::Cross => cross_blocks.push(b),
        }
    }
    free_top.sort_unstable();
    free_bottom.sort_unstable();

    // Blocks are already ordered by min vertex (Diagram normalisation).
    // Bottom-only blocks must be re-ordered ascending by size
    // (|B_1| ≤ … ≤ |B_b| left→right, eq. 92) — stable, so ties keep their
    // original relative order.
    bottom_blocks.sort_by_key(|b| b.len());

    // --- Assign planar positions -----------------------------------------
    // Top row: [T_1 … T_t | D_1^U … D_d^U | TF_1 … TF_s]
    // Bottom:  [D_1^L … D_d^L | B_1 … B_b | BF_1 … BF_{n-s}]
    let mut perm_out = vec![usize::MAX; l]; // original top pos -> planar slot
    let mut perm_in = vec![usize::MAX; k]; // planar bottom slot -> original pos
    let mut planar_blocks: Vec<Vec<usize>> = Vec::new();

    let mut top_slot = 0usize;
    for b in &top_blocks {
        let mut pb = Vec::with_capacity(b.len());
        for &v in b.iter() {
            perm_out[v] = top_slot;
            pb.push(top_slot);
            top_slot += 1;
        }
        planar_blocks.push(pb);
    }
    let mut bottom_slot = 0usize;
    for b in &cross_blocks {
        let mut pb = Vec::new();
        for &v in b.iter().filter(|&&v| v < l) {
            perm_out[v] = top_slot;
            pb.push(top_slot);
            top_slot += 1;
        }
        for &v in b.iter().filter(|&&v| v >= l) {
            perm_in[bottom_slot] = v - l;
            pb.push(l + bottom_slot);
            bottom_slot += 1;
        }
        planar_blocks.push(pb);
    }
    for b in &bottom_blocks {
        let mut pb = Vec::with_capacity(b.len());
        for &v in b.iter() {
            perm_in[bottom_slot] = v - l;
            pb.push(l + bottom_slot);
            bottom_slot += 1;
        }
        planar_blocks.push(pb);
    }
    // Free vertices (jellyfish only): far right of each row, order kept.
    for &v in &free_top {
        perm_out[v] = top_slot;
        planar_blocks.push(vec![top_slot]);
        top_slot += 1;
    }
    for &v in &free_bottom {
        perm_in[bottom_slot] = v - l;
        planar_blocks.push(vec![l + bottom_slot]);
        bottom_slot += 1;
    }
    debug_assert_eq!(top_slot, l);
    debug_assert_eq!(bottom_slot, k);

    let planar = Diagram::from_blocks(l, k, planar_blocks)?;
    let layout = PlanarLayout {
        l,
        k,
        top_blocks: top_blocks.iter().map(|b| b.len()).collect(),
        cross_blocks: cross_blocks
            .iter()
            .map(|b| {
                let up = b.iter().filter(|&&v| v < l).count();
                (up, b.len() - up)
            })
            .collect(),
        bottom_blocks: bottom_blocks.iter().map(|b| b.len()).collect(),
        free_top: free_top.len(),
        free_bottom: free_bottom.len(),
    };
    Ok(Factored {
        perm_in,
        perm_out,
        planar,
        layout,
    })
}

impl Factored {
    /// Recompose `σ_l • d_planar • σ_k` as diagrams and return the result —
    /// must equal the original diagram (the Figure 1 identity). Used by the
    /// verification tests.
    pub fn recompose(&self) -> Result<Diagram> {
        use super::compose::compose;
        // σ_k as a diagram: planar bottom slot q is fed by original input
        // position perm_in[q], i.e. the (k,k)-diagram with top vertex q
        // joined to bottom vertex k + perm_in[q].
        let sigma_k = Diagram::permutation(&self.perm_in);
        // σ_l: final output position p reads planar top slot perm_out[p].
        let sigma_l = Diagram::permutation(&self.perm_out);
        let inner = compose(&self.planar, &sigma_k)?;
        debug_assert_eq!(inner.removed_components, 0);
        let outer = compose(&sigma_l, &inner.diagram)?;
        debug_assert_eq!(outer.removed_components, 0);
        Ok(outer.diagram)
    }
}

#[cfg(test)]
mod tests {
    use super::super::planar::{is_algorithmically_planar, is_algorithmically_planar_jellyfish};
    use super::*;
    use crate::util::Rng;

    /// Figure 1's (5,4)-partition diagram: we use the diagram from the
    /// lib.rs quickstart, which matches Example 10's index pattern
    /// (top: {1},{2,4},{3}-cross, bottom blocks as drawn).
    fn figure1_diagram() -> Diagram {
        Diagram::from_blocks(
            4,
            5,
            vec![vec![0], vec![1, 3], vec![2, 6, 7], vec![4, 5, 8]],
        )
        .unwrap()
    }

    #[test]
    fn factor_recomposes_to_original() {
        let d = figure1_diagram();
        let f = factor(&d);
        assert_eq!(f.recompose().unwrap(), d);
    }

    #[test]
    fn factor_middle_is_algorithmically_planar() {
        let d = figure1_diagram();
        let f = factor(&d);
        assert!(is_algorithmically_planar(&f.planar));
    }

    #[test]
    fn factor_layout_counts() {
        let d = figure1_diagram();
        let f = factor(&d);
        // blocks: {0}, {1,3} top-only; {2,6,7} cross (1 up, 2 down);
        // {4,5,8} bottom-only (vertices >= l = 4), size 3.
        assert_eq!(f.layout.t(), 2);
        assert_eq!(f.layout.d(), 1);
        assert_eq!(f.layout.b(), 1);
        assert_eq!(f.layout.bottom_blocks, vec![3]);
        assert_eq!(f.layout.cross_blocks, vec![(1, 2)]);
    }

    #[test]
    fn factor_random_partition_diagrams() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let l = rng.below(5);
            let k = rng.below(5);
            let d = Diagram::random_partition(l, k, &mut rng);
            let f = factor(&d);
            assert!(
                is_algorithmically_planar(&f.planar),
                "middle not planar for {d}"
            );
            assert_eq!(f.recompose().unwrap(), d, "recompose mismatch for {d}");
        }
    }

    #[test]
    fn factor_random_brauer_diagrams() {
        let mut rng = Rng::new(78);
        for _ in 0..200 {
            let l = rng.below(5);
            let k = if (l + rng.below(5)) % 2 == 0 {
                rng.below(5) / 2 * 2 + (l % 2)
            } else {
                continue;
            };
            if (l + k) % 2 != 0 {
                continue;
            }
            let d = match Diagram::random_brauer(l, k, &mut rng) {
                Ok(d) => d,
                Err(_) => continue,
            };
            let f = factor(&d);
            assert!(f.planar.is_brauer());
            assert!(is_algorithmically_planar(&f.planar));
            assert_eq!(f.recompose().unwrap(), d);
        }
    }

    #[test]
    fn factor_jellyfish_diagrams() {
        let mut rng = Rng::new(79);
        let n = 3;
        for _ in 0..200 {
            let l = rng.below(5);
            let k = rng.below(6);
            if l + k < n || (l + k - n) % 2 != 0 {
                continue;
            }
            let d = Diagram::random_jellyfish(l, k, n, &mut rng).unwrap();
            let f = factor_jellyfish(&d, n).unwrap();
            assert!(
                is_algorithmically_planar_jellyfish(&f.planar, n),
                "middle not planar for {d}"
            );
            assert_eq!(f.recompose().unwrap(), d);
            assert_eq!(f.layout.free_top + f.layout.free_bottom, n);
        }
    }

    #[test]
    fn factor_jellyfish_rejects_non_jellyfish() {
        let d = Diagram::identity(2);
        assert!(factor_jellyfish(&d, 3).is_err());
    }

    #[test]
    fn factor_identity_is_trivial() {
        let d = Diagram::identity(3);
        let f = factor(&d);
        assert_eq!(f.perm_in, vec![0, 1, 2]);
        assert_eq!(f.perm_out, vec![0, 1, 2]);
        assert_eq!(f.planar, d);
    }

    #[test]
    fn figure4_brauer_factor() {
        // Figure 4: (5,5)-Brauer diagram with pairs as in Example 11:
        // bottom pair contracted is original bottom {0,1}; after Permute
        // with (1524) [paper's cycle notation] the planar diagram has the
        // bottom pair at the far right. We check structure, not the exact
        // permutation (any valid factoring is acceptable).
        // Pairs (0-based; top 0..4, bottom 5..9): top pair {1,3},
        // cross {0,9}, {2,7}, {4,8}, bottom pair {5,6}.
        let d = Diagram::from_blocks(
            5,
            5,
            vec![vec![1, 3], vec![0, 9], vec![2, 7], vec![4, 8], vec![5, 6]],
        )
        .unwrap();
        let f = factor(&d);
        assert_eq!(f.layout.t(), 1);
        assert_eq!(f.layout.d(), 3);
        assert_eq!(f.layout.b(), 1);
        assert!(is_algorithmically_planar(&f.planar));
        assert_eq!(f.recompose().unwrap(), d);
    }
}
