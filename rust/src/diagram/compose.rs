//! Categorical operations on partition diagrams.
//!
//! - [`compose`] — vertical composition `d2 • d1` (Definition 18): stack,
//!   merge middle-row connections, drop components stranded in the middle
//!   and record their count `c` so callers can apply the `n^c` scalar.
//! - [`tensor_product`] — horizontal composition `d1 ⊗ d2` (Definition 19):
//!   place side by side.
//!
//! Together with [`crate::functor`] these are exercised by the functoriality
//! tests `Θ(d2 • d1) = Θ(d2)Θ(d1)` and `Θ(d1 ⊗ d2) = Θ(d1) ⊗ Θ(d2)` — the
//! monoidal-functor laws (Theorem 27) that justify the whole fast algorithm.
//!
//! **Scope note**: [`compose`] implements the partition-category
//! composition of Definition 18, which also covers the Brauer category
//! (Brauer diagrams compose to a Brauer diagram times `n^c`). The
//! Brauer–Grood category's *vertical* composition involving free-vertex
//! `(l+k)\n`-diagrams follows the Lehrer–Zhang rules (extra vanishing
//! conditions and scalars beyond `n^c`) that the paper itself omits
//! (Definition 23 is stated "framework only"); we follow the paper and do
//! not implement it — `H_α` diagrams are only ever *applied* (Algorithm 1)
//! and tensored, never vertically composed.

use super::Diagram;
use crate::error::{Error, Result};

/// Result of `d2 • d1`: the concatenated diagram and the number of removed
/// middle components (the exponent of the `n^c` scalar in Definition 18).
#[derive(Debug, Clone, PartialEq)]
pub struct Composed {
    /// The `(k,m)`-partition diagram `d2 ∘ d1`.
    pub diagram: Diagram,
    /// Number of connected components removed from the middle row.
    pub removed_components: usize,
}

/// Union-find with path compression.
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu {
            parent: (0..n).collect(),
        }
    }
    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let r = self.find(self.parent[x]);
            self.parent[x] = r;
        }
        self.parent[x]
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra] = rb;
        }
    }
}

/// Vertical composition `d2 • d1` where `d1 : k → l` and `d2 : l → m`
/// (Definition 18). Errors if the middle orders disagree.
pub fn compose(d2: &Diagram, d1: &Diagram) -> Result<Composed> {
    if d2.k != d1.l {
        return Err(Error::ShapeMismatch {
            expected: format!("d2.k == d1.l (middle row), d2.k = {}", d2.k),
            got: format!("d1.l = {}", d1.l),
        });
    }
    let m = d2.l; // final top
    let l = d2.k; // middle
    let k = d1.k; // final bottom

    // Vertex ids in the stacked picture:
    //   0..m            — final top row (d2's top)
    //   m..m+l          — middle row (d2's bottom == d1's top)
    //   m+l..m+l+k      — final bottom row (d1's bottom)
    let total = m + l + k;
    let mut dsu = Dsu::new(total);

    for b in d2.blocks() {
        // d2's own labels: top 0..m, bottom m..m+l — already aligned.
        for w in b.windows(2) {
            dsu.union(w[0], w[1]);
        }
    }
    for b in d1.blocks() {
        // d1's labels: top 0..l -> middle m..m+l; bottom l..l+k -> m+l..
        let map = |v: usize| if v < l { m + v } else { m + v }; // same shift
        for w in b.windows(2) {
            dsu.union(map(w[0]), map(w[1]));
        }
        if b.len() == 1 {
            // singleton: nothing to union, but the vertex exists already
            let _ = map(b[0]);
        }
    }

    // Gather components.
    let mut comp_members: std::collections::HashMap<usize, Vec<usize>> =
        std::collections::HashMap::new();
    for v in 0..total {
        let r = dsu.find(v);
        comp_members.entry(r).or_default().push(v);
    }

    let mut removed = 0usize;
    let mut blocks: Vec<Vec<usize>> = Vec::new();
    for (_, members) in comp_members {
        // Project away the middle row.
        let projected: Vec<usize> = members
            .iter()
            .filter(|&&v| v < m || v >= m + l)
            .map(|&v| if v < m { v } else { v - l })
            .collect();
        if projected.is_empty() {
            removed += 1;
        } else {
            blocks.push(projected);
        }
    }

    Ok(Composed {
        diagram: Diagram::from_blocks(m, k, blocks)?,
        removed_components: removed,
    })
}

/// Horizontal composition `d1 ⊗ d2` (Definition 19): `d1 : k → l` and
/// `d2 : q → m` side by side give a `(k+q, l+m)`-diagram, `d1` on the left.
pub fn tensor_product(d1: &Diagram, d2: &Diagram) -> Diagram {
    let (l, k) = (d1.l, d1.k);
    let (m, q) = (d2.l, d2.k);
    let mut blocks: Vec<Vec<usize>> = Vec::new();
    for b in d1.blocks() {
        // d1 top stays 0..l; d1 bottom l..l+k shifts past d2's top (m).
        blocks.push(b.iter().map(|&v| if v < l { v } else { v + m }).collect());
    }
    for b in d2.blocks() {
        // d2 top 0..m -> l..l+m; d2 bottom m..m+q -> l+m+k..l+m+k+q.
        blocks.push(
            b.iter()
                .map(|&v| if v < m { l + v } else { v + l + k })
                .collect(),
        );
    }
    Diagram::from_blocks(l + m, k + q, blocks).expect("tensor product of valid diagrams is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The paper's Example 4: composing the (6,4) diagram with the (3,6)
    /// diagram removes two middle components.
    #[test]
    fn example4_removed_components() {
        // d_pi2: (6,4)-partition diagram from Example 2:
        //   {1,2,5,7 | 3,4,10 | 6,8 | 9}  (1-based, top 1..4, bottom 5..10)
        let d2 = Diagram::from_blocks(
            4,
            6,
            vec![vec![0, 1, 4, 6], vec![2, 3, 9], vec![5, 7], vec![8]],
        )
        .unwrap();
        // d_pi1: a (3,6)-partition diagram (the paper's is given as a
        // picture; this one is chosen so that, as in Example 4, exactly two
        // connected components sit entirely in the middle after stacking:
        // d2's bottom blocks {6,8} and {9} meet only d1 singletons).
        let d1 = Diagram::from_blocks(
            6,
            3,
            vec![vec![1], vec![3], vec![4], vec![0, 6], vec![2, 5], vec![7, 8]],
        )
        .unwrap();
        let c = compose(&d2, &d1).unwrap();
        assert_eq!(c.diagram.l, 4);
        assert_eq!(c.diagram.k, 3);
        assert_eq!(c.removed_components, 2);
        // The surviving blocks: the big top component picks up bottom vertex
        // 1 (0-based 4 in the stacked result) and d1's bottom pair survives.
        let want =
            Diagram::from_blocks(4, 3, vec![vec![0, 1, 2, 3, 4], vec![5, 6]]).unwrap();
        assert_eq!(c.diagram, want);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let d = Diagram::random_partition(3, 4, &mut rng);
            let left = compose(&Diagram::identity(d.l), &d).unwrap();
            assert_eq!(left.diagram, d);
            assert_eq!(left.removed_components, 0);
            let right = compose(&d, &Diagram::identity(d.k)).unwrap();
            assert_eq!(right.diagram, d);
            assert_eq!(right.removed_components, 0);
        }
    }

    #[test]
    fn composition_is_associative_up_to_scalar() {
        // (d3 • d2) • d1 == d3 • (d2 • d1) and the total scalar agrees.
        let mut rng = Rng::new(8);
        for _ in 0..30 {
            let d1 = Diagram::random_partition(3, 2, &mut rng); // 2 -> 3
            let d2 = Diagram::random_partition(2, 3, &mut rng); // 3 -> 2
            let d3 = Diagram::random_partition(3, 2, &mut rng); // 2 -> 3
            let left_inner = compose(&d3, &d2).unwrap();
            let left = compose(&left_inner.diagram, &d1).unwrap();
            let right_inner = compose(&d2, &d1).unwrap();
            let right = compose(&d3, &right_inner.diagram).unwrap();
            assert_eq!(left.diagram, right.diagram);
            assert_eq!(
                left_inner.removed_components + left.removed_components,
                right_inner.removed_components + right.removed_components
            );
        }
    }

    #[test]
    fn compose_shape_mismatch_errors() {
        let a = Diagram::identity(2);
        let b = Diagram::identity(3);
        assert!(compose(&a, &b).is_err());
    }

    #[test]
    fn permutation_composition_matches_group_law() {
        // permutation diagrams compose contravariantly or covariantly —
        // pin the convention: perm diagram P(σ) has top i joined to bottom
        // σ(i); stacking P(σ) over P(τ) joins top i → middle σ(i) → bottom
        // τ(σ(i)), i.e. P(σ) • P(τ) = P(τ ∘ σ).
        let sigma = vec![1, 2, 0];
        let tau = vec![2, 0, 1];
        let comp = compose(&Diagram::permutation(&sigma), &Diagram::permutation(&tau)).unwrap();
        let want: Vec<usize> = (0..3).map(|i| tau[sigma[i]]).collect();
        assert_eq!(comp.diagram, Diagram::permutation(&want));
        assert_eq!(comp.removed_components, 0);
    }

    #[test]
    fn tensor_product_example5_shape() {
        // Example 5: (6,4) ⊗ (3,6) = (9,10)-partition diagram.
        let d2 = Diagram::from_blocks(
            4,
            6,
            vec![vec![0, 1, 4, 6], vec![2, 3, 9], vec![5, 7], vec![8]],
        )
        .unwrap();
        let d1 = Diagram::from_blocks(
            6,
            3,
            vec![vec![0, 6], vec![1, 2], vec![3], vec![4, 5], vec![7, 8]],
        )
        .unwrap();
        let t = tensor_product(&d1, &d2);
        assert_eq!(t.l, 6 + 4);
        assert_eq!(t.k, 3 + 6);
        assert_eq!(t.num_blocks(), d1.num_blocks() + d2.num_blocks());
    }

    #[test]
    fn tensor_product_with_empty_diagram_is_identity_op() {
        let mut rng = Rng::new(10);
        let d = Diagram::random_partition(2, 3, &mut rng);
        let unit = Diagram::from_blocks(0, 0, vec![]).unwrap();
        assert_eq!(tensor_product(&d, &unit), d);
        assert_eq!(tensor_product(&unit, &d), d);
    }

    #[test]
    fn tensor_product_associative() {
        let mut rng = Rng::new(12);
        for _ in 0..20 {
            let a = Diagram::random_partition(1, 2, &mut rng);
            let b = Diagram::random_partition(2, 1, &mut rng);
            let c = Diagram::random_partition(1, 1, &mut rng);
            assert_eq!(
                tensor_product(&tensor_product(&a, &b), &c),
                tensor_product(&a, &tensor_product(&b, &c))
            );
        }
    }
}
