//! Tensor-product decomposition of diagrams (§4.4, implication 3).
//!
//! If a `(k,l)`-partition diagram splits as `d = d₁ ⊗ d₂ ⊗ …` then, because
//! the functors are monoidal, its matrix is the Kronecker product
//! `F(d) = F(d₁) ⊗ F(d₂) ⊗ …` of smaller equivariant matrices. The maximal
//! such decomposition cuts the diagram at every *separating column*: a
//! position where no block spans the cut in either row.

use super::Diagram;

/// Split `d` into its maximal tensor-product factors, left to right.
/// Always non-empty; a diagram with no separating column returns `[d]`.
///
/// A cut after top position `a` and bottom position `b` is valid iff every
/// block lies entirely left (top < a, bottom < b) or entirely right of it,
/// and cuts must be consistent: we sweep blocks by their leftmost vertex
/// and close a factor whenever all blocks seen so far are exhausted.
pub fn tensor_factors(d: &Diagram) -> Vec<Diagram> {
    let (l, k) = (d.l, d.k);
    if d.num_blocks() == 0 {
        return vec![d.clone()];
    }
    // For a candidate cut (a, b): all blocks must avoid straddling.
    // Enumerate cuts greedily: scan candidate (a, b) pairs in order of
    // a + b and take every valid cut — valid cuts are nested so greedy
    // left-to-right works.
    let mut cuts: Vec<(usize, usize)> = Vec::new(); // (top cut, bottom cut)
    for a in 0..=l {
        for b in 0..=k {
            if (a, b) == (0, 0) || (a, b) == (l, k) {
                continue;
            }
            let valid = d.blocks().iter().all(|blk| {
                let left = blk
                    .iter()
                    .all(|&v| if v < l { v < a } else { v - l < b });
                let right = blk
                    .iter()
                    .all(|&v| if v < l { v >= a } else { v - l >= b });
                left || right
            });
            if valid {
                cuts.push((a, b));
            }
        }
    }
    cuts.sort();
    cuts.dedup();
    // Valid cuts may be pairwise incomparable (e.g. a lone top vertex next
    // to a lone bottom vertex admits both (0,1) and (1,0)); keep a maximal
    // monotone chain — any chain recomposes correctly, greedy-lex picks
    // one deterministically.
    let mut chain: Vec<(usize, usize)> = vec![(0, 0)];
    for &(a, b) in &cuts {
        let &(pa, pb) = chain.last().unwrap();
        if a >= pa && b >= pb {
            chain.push((a, b));
        }
    }
    chain.push((l, k));
    chain.dedup();
    let boundaries = chain;
    let mut factors = Vec::new();
    for w in boundaries.windows(2) {
        let (a0, b0) = w[0];
        let (a1, b1) = w[1];
        let fl = a1 - a0;
        let fk = b1 - b0;
        if fl == 0 && fk == 0 {
            continue;
        }
        let blocks: Vec<Vec<usize>> = d
            .blocks()
            .iter()
            .filter(|blk| {
                blk.iter().all(|&v| {
                    if v < l {
                        v >= a0 && v < a1
                    } else {
                        v - l >= b0 && v - l < b1
                    }
                })
            })
            .map(|blk| {
                blk.iter()
                    .map(|&v| {
                        if v < l {
                            v - a0
                        } else {
                            fl + (v - l - b0)
                        }
                    })
                    .collect()
            })
            .collect();
        factors.push(
            Diagram::from_blocks(fl, fk, blocks)
                .expect("factor blocks partition their interval"),
        );
    }
    if factors.is_empty() {
        vec![d.clone()]
    } else {
        factors
    }
}

#[cfg(test)]
mod tests {
    use super::super::compose::tensor_product;
    use super::*;
    use crate::fastmult::Group;
    use crate::functor::materialize;
    use crate::util::Rng;

    #[test]
    fn identity_splits_into_single_strands() {
        let d = Diagram::identity(4);
        let f = tensor_factors(&d);
        assert_eq!(f.len(), 4);
        for x in &f {
            assert_eq!(*x, Diagram::identity(1));
        }
    }

    #[test]
    fn indecomposable_diagram_returns_itself() {
        // A single block spanning everything cannot be cut.
        let d = Diagram::from_blocks(2, 2, vec![vec![0, 1, 2, 3]]).unwrap();
        let f = tensor_factors(&d);
        assert_eq!(f, vec![d]);
    }

    #[test]
    fn factors_recompose_to_original() {
        let mut rng = Rng::new(0xDEC0);
        for _ in 0..100 {
            let l = rng.below(5);
            let k = rng.below(5);
            let d = Diagram::random_partition(l, k, &mut rng);
            let factors = tensor_factors(&d);
            let mut acc = Diagram::from_blocks(0, 0, vec![]).unwrap();
            for f in &factors {
                acc = tensor_product(&acc, f);
            }
            assert_eq!(acc, d, "recompose failed for {d}");
        }
    }

    /// §4.4 implication 3: the matrix of a decomposable diagram is the
    /// Kronecker product of its factors' matrices.
    #[test]
    fn matrix_is_kronecker_of_factors() {
        let n = 2;
        // d = ({top pair} over {}) ⊗ identity(1): decomposable by design.
        let d = Diagram::from_blocks(3, 1, vec![vec![0, 1], vec![2, 3]]).unwrap();
        let factors = tensor_factors(&d);
        assert!(factors.len() >= 2, "expected a split, got {factors:?}");
        let whole = materialize(Group::Symmetric, &d, n).unwrap();
        // Kron of factor matrices.
        let mut acc = crate::linalg::Matrix::identity(1);
        for f in &factors {
            let m = materialize(Group::Symmetric, f, n).unwrap();
            let mut next = crate::linalg::Matrix::zeros(acc.rows * m.rows, acc.cols * m.cols);
            for i in 0..acc.rows {
                for j in 0..acc.cols {
                    let v = acc.get(i, j);
                    if v == 0.0 {
                        continue;
                    }
                    for p in 0..m.rows {
                        for q in 0..m.cols {
                            next.set(i * m.rows + p, j * m.cols + q, v * m.get(p, q));
                        }
                    }
                }
            }
            acc = next;
        }
        assert!(whole.max_abs_diff(&acc) < 1e-12);
    }

    #[test]
    fn crossing_blocks_prevent_cuts() {
        // Cross pattern {0,3},{1,2}: no separating column exists.
        let d = Diagram::from_blocks(2, 2, vec![vec![0, 3], vec![1, 2]]).unwrap();
        assert_eq!(tensor_factors(&d).len(), 1);
    }
}
