//! Enumeration of the spanning families of Theorems 5, 7, 9 and 11, plus
//! the combinatorial counting functions used to cross-check them:
//!
//! - all `(k,l)`-partition diagrams (optionally with at most `n` blocks) —
//!   the S_n diagram basis of size `B(l+k, n) = Σ_{t≤n} S(l+k, t)`,
//! - all `(k,l)`-Brauer diagrams — the O(n)/Sp(n) spanning set of size
//!   `(l+k-1)!!`,
//! - all `(l+k)\n`-diagrams — the extra SO(n) spanning elements.

use super::Diagram;
use crate::error::{Error, Result};

/// Stirling number of the second kind `S(m, t)` — partitions of `m` labelled
/// elements into exactly `t` non-empty blocks.
pub fn stirling2(m: usize, t: usize) -> u128 {
    if m == 0 && t == 0 {
        return 1;
    }
    if m == 0 || t == 0 || t > m {
        return 0;
    }
    // S(m, t) = t·S(m-1, t) + S(m-1, t-1)
    let mut row: Vec<u128> = vec![0; t + 1];
    row[0] = 1; // S(0,0)
    for mi in 1..=m {
        let hi = t.min(mi);
        for ti in (1..=hi).rev() {
            row[ti] = (ti as u128) * row[ti] + row[ti - 1];
        }
        row[0] = 0;
    }
    row[t]
}

/// Bounded Bell number `B(m, n) = Σ_{t=1}^{n} S(m, t)` — the size of the
/// S_n diagram basis for `m = l + k` (Theorem 5). `B(0, n) = 1` (the empty
/// partition).
pub fn bell_bounded(m: usize, n: usize) -> u128 {
    if m == 0 {
        return 1;
    }
    (1..=n.min(m)).map(|t| stirling2(m, t)).sum()
}

/// Double factorial `(m)!! = m (m-2) (m-4) …` with `0!! = (-1)!! = 1`; the
/// Brauer spanning set for `l + k = m + 1` even has size `(l+k-1)!!`.
pub fn double_factorial(m: isize) -> u128 {
    if m <= 0 {
        return 1;
    }
    let mut acc: u128 = 1;
    let mut x = m as u128;
    loop {
        acc *= x;
        if x <= 2 {
            break;
        }
        x -= 2;
    }
    acc
}

/// All `(k,l)`-partition diagrams, optionally restricted to at most
/// `max_blocks` blocks (pass `Some(n)` to get the S_n *basis* of Theorem 5
/// rather than the full spanning set).
pub fn all_partition_diagrams(l: usize, k: usize, max_blocks: Option<usize>) -> Vec<Diagram> {
    let total = l + k;
    let mut out = Vec::new();
    if total == 0 {
        out.push(Diagram::from_blocks(l, k, vec![]).unwrap());
        return out;
    }
    // Enumerate restricted growth strings.
    let mut assignment = vec![0usize; total];
    fn rec(
        v: usize,
        num_blocks: usize,
        assignment: &mut Vec<usize>,
        l: usize,
        k: usize,
        cap: usize,
        out: &mut Vec<Diagram>,
    ) {
        let total = l + k;
        if v == total {
            let mut blocks: Vec<Vec<usize>> = vec![Vec::new(); num_blocks];
            for (i, &c) in assignment.iter().enumerate() {
                blocks[c].push(i);
            }
            out.push(Diagram::from_blocks(l, k, blocks).unwrap());
            return;
        }
        let hi = (num_blocks + 1).min(cap);
        for c in 0..hi {
            assignment[v] = c;
            rec(
                v + 1,
                num_blocks.max(c + 1),
                assignment,
                l,
                k,
                cap,
                out,
            );
        }
    }
    let cap = max_blocks.unwrap_or(total);
    rec(1.min(total), 1, &mut assignment, l, k, cap, &mut out);
    out
}

/// All `(k,l)`-Brauer diagrams (perfect matchings of `l + k` vertices).
/// Empty when `l + k` is odd, matching Theorem 7's size-0 case.
pub fn all_brauer_diagrams(l: usize, k: usize) -> Vec<Diagram> {
    let total = l + k;
    if total % 2 != 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut used = vec![false; total];
    let mut pairs: Vec<Vec<usize>> = Vec::new();
    fn rec(
        used: &mut Vec<bool>,
        pairs: &mut Vec<Vec<usize>>,
        l: usize,
        k: usize,
        out: &mut Vec<Diagram>,
    ) {
        let total = l + k;
        // Find the first unused vertex; pair it with every later unused one.
        let first = match used.iter().position(|&u| !u) {
            None => {
                out.push(Diagram::from_blocks(l, k, pairs.clone()).unwrap());
                return;
            }
            Some(f) => f,
        };
        used[first] = true;
        for p in (first + 1)..total {
            if used[p] {
                continue;
            }
            used[p] = true;
            pairs.push(vec![first, p]);
            rec(used, pairs, l, k, out);
            pairs.pop();
            used[p] = false;
        }
        used[first] = false;
    }
    rec(&mut used, &mut pairs, l, k, &mut out);
    out
}

/// All `(l+k)\n`-diagrams: exactly `n` free vertices, the remaining
/// `l + k - n` perfectly matched. Errors if `l + k - n` is odd or negative.
pub fn all_jellyfish_diagrams(l: usize, k: usize, n: usize) -> Result<Vec<Diagram>> {
    let total = l + k;
    if n > total || (total - n) % 2 != 0 {
        return Err(Error::DimensionConstraint(format!(
            "(l+k)\\n-diagrams need l+k-n even and >= 0; l+k={total}, n={n}"
        )));
    }
    let mut out = Vec::new();
    // Choose the free set, then match the rest.
    let mut free: Vec<usize> = Vec::new();
    fn choose(
        start: usize,
        remaining: usize,
        total: usize,
        free: &mut Vec<usize>,
        l: usize,
        k: usize,
        out: &mut Vec<Diagram>,
    ) {
        if remaining == 0 {
            let freeset: std::collections::HashSet<usize> = free.iter().copied().collect();
            let rest: Vec<usize> = (0..total).filter(|v| !freeset.contains(v)).collect();
            let mut pairs: Vec<Vec<usize>> = Vec::new();
            match_rest(&rest, 0, &mut vec![false; rest.len()], &mut pairs, free, l, k, out);
            return;
        }
        for v in start..=(total - remaining) {
            free.push(v);
            choose(v + 1, remaining - 1, total, free, l, k, out);
            free.pop();
        }
    }
    #[allow(clippy::too_many_arguments)]
    fn match_rest(
        rest: &[usize],
        _from: usize,
        used: &mut Vec<bool>,
        pairs: &mut Vec<Vec<usize>>,
        free: &Vec<usize>,
        l: usize,
        k: usize,
        out: &mut Vec<Diagram>,
    ) {
        let first = match used.iter().position(|&u| !u) {
            None => {
                let mut blocks: Vec<Vec<usize>> = free.iter().map(|&v| vec![v]).collect();
                blocks.extend(pairs.iter().cloned());
                out.push(Diagram::from_blocks(l, k, blocks).unwrap());
                return;
            }
            Some(f) => f,
        };
        used[first] = true;
        for p in (first + 1)..rest.len() {
            if used[p] {
                continue;
            }
            used[p] = true;
            pairs.push(vec![rest[first], rest[p]]);
            match_rest(rest, 0, used, pairs, free, l, k, out);
            pairs.pop();
            used[p] = false;
        }
        used[first] = false;
    }
    choose(0, n, total, &mut free, l, k, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stirling_known_values() {
        assert_eq!(stirling2(0, 0), 1);
        assert_eq!(stirling2(4, 2), 7);
        assert_eq!(stirling2(5, 3), 25);
        assert_eq!(stirling2(3, 5), 0);
        assert_eq!(stirling2(6, 1), 1);
        assert_eq!(stirling2(6, 6), 1);
    }

    #[test]
    fn bell_bounded_matches_full_bell_when_unbounded() {
        // Bell numbers: 1, 1, 2, 5, 15, 52, 203, 877
        let bell = [1u128, 1, 2, 5, 15, 52, 203, 877];
        for (m, &b) in bell.iter().enumerate() {
            assert_eq!(bell_bounded(m, m.max(1)), b, "Bell({m})");
        }
        // Bounded: B(4, 2) = S(4,1) + S(4,2) = 1 + 7 = 8
        assert_eq!(bell_bounded(4, 2), 8);
    }

    #[test]
    fn double_factorial_values() {
        assert_eq!(double_factorial(-1), 1);
        assert_eq!(double_factorial(0), 1);
        assert_eq!(double_factorial(5), 15);
        assert_eq!(double_factorial(7), 105);
        assert_eq!(double_factorial(9), 945);
    }

    #[test]
    fn partition_diagram_counts_match_bell() {
        // Theorem 5: count of (k,l)-partition diagrams with at most n blocks
        // is B(l+k, n).
        for (l, k) in [(0usize, 2usize), (1, 2), (2, 2), (1, 3)] {
            let all = all_partition_diagrams(l, k, None);
            assert_eq!(all.len() as u128, bell_bounded(l + k, l + k), "({l},{k})");
            for n in 1..=(l + k) {
                let bounded = all_partition_diagrams(l, k, Some(n));
                assert_eq!(bounded.len() as u128, bell_bounded(l + k, n), "n={n}");
                assert!(bounded.iter().all(|d| d.num_blocks() <= n));
            }
        }
    }

    #[test]
    fn partition_diagrams_distinct() {
        let all = all_partition_diagrams(2, 2, None);
        let set: std::collections::HashSet<_> = all.iter().cloned().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn brauer_counts_match_double_factorial() {
        // Theorem 7: (l+k-1)!! diagrams when l+k even, 0 when odd.
        assert_eq!(all_brauer_diagrams(1, 2).len(), 0);
        for (l, k) in [(1usize, 1usize), (2, 2), (3, 1), (3, 3), (2, 4)] {
            let count = all_brauer_diagrams(l, k).len() as u128;
            assert_eq!(count, double_factorial((l + k) as isize - 1), "({l},{k})");
        }
    }

    #[test]
    fn brauer_diagrams_are_brauer() {
        for d in all_brauer_diagrams(2, 2) {
            assert!(d.is_brauer());
        }
    }

    #[test]
    fn jellyfish_counts() {
        // count = C(l+k, n) * (l+k-n-1)!!
        let n = 3;
        let (l, k) = (2usize, 3usize); // l+k-n = 2, even
        let all = all_jellyfish_diagrams(l, k, n).unwrap();
        let choose_5_3 = 10u128;
        assert_eq!(all.len() as u128, choose_5_3 * double_factorial(1));
        for d in &all {
            assert!(d.is_jellyfish(n));
        }
        assert!(all_jellyfish_diagrams(2, 2, 3).is_err()); // parity violation
    }

    #[test]
    fn empty_diagram_enumeration() {
        let all = all_partition_diagrams(0, 0, None);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].num_blocks(), 0);
    }
}
