//! Planarity and *algorithmic planarity* (Definitions 31–33).
//!
//! `Factor` promises an algorithmically planar middle diagram; the
//! predicates here verify that promise (and encode the paper's Examples
//! 7–9 as tests).

use super::{BlockKind, Diagram};

/// True iff the diagram is planar: no two blocks cross when the vertices
/// are read around the rectangle boundary (top row left→right, then bottom
/// row right→left) — Remark 34's notion.
pub fn is_planar(d: &Diagram) -> bool {
    // Map each vertex to its boundary-cycle position.
    let (l, k) = (d.l, d.k);
    let cycle_pos = |v: usize| -> usize {
        if v < l {
            v
        } else {
            // bottom position p = v - l, traversed right to left
            l + (k - 1 - (v - l))
        }
    };
    // Two blocks cross iff, in the cyclic order, they interleave:
    // a1 < b1 < a2 < b2 for some members. For blocks on a line (we can cut
    // the cycle at position 0 since it is a boundary circle and all blocks
    // are drawn inside), interleaving on the line implies crossing.
    let blocks: Vec<Vec<usize>> = d
        .blocks()
        .iter()
        .map(|b| {
            let mut c: Vec<usize> = b.iter().map(|&v| cycle_pos(v)).collect();
            c.sort_unstable();
            c
        })
        .collect();
    for i in 0..blocks.len() {
        for j in (i + 1)..blocks.len() {
            if interleaves(&blocks[i], &blocks[j]) {
                return false;
            }
        }
    }
    true
}

/// Do two sorted position sets interleave (i.e. cross on a line)?
fn interleaves(a: &[usize], b: &[usize]) -> bool {
    // They interleave iff neither is contained in a single "gap" of the
    // other. Merge-walk: count alternations; > 2 switches means crossing.
    let mut switches = 0;
    let mut last: Option<bool> = None; // true = from a
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() || j < b.len() {
        let take_a = match (i < a.len(), j < b.len()) {
            (true, true) => a[i] < b[j],
            (true, false) => true,
            (false, true) => false,
            (false, false) => unreachable!(),
        };
        if last != Some(take_a) {
            switches += 1;
            last = Some(take_a);
        }
        if take_a {
            i += 1;
        } else {
            j += 1;
        }
    }
    switches > 3
}

/// True iff `d` is an **algorithmically planar** `(k,l)`-partition diagram
/// (Definition 31):
///
/// 1. bottom-row-only blocks sit consecutively at the far right of the
///    bottom row, sizes non-decreasing left→right (largest at the far
///    right),
/// 2. top-row-only blocks sit consecutively at the far left of the top row,
/// 3. cross blocks do not cross each other (and each one's vertices are
///    consecutive within each row, as in the diagrams `Factor` builds).
pub fn is_algorithmically_planar(d: &Diagram) -> bool {
    let (l, k) = (d.l, d.k);
    let mut top_only: Vec<&Vec<usize>> = Vec::new();
    let mut bottom_only: Vec<&Vec<usize>> = Vec::new();
    let mut cross: Vec<&Vec<usize>> = Vec::new();
    for b in d.blocks() {
        match d.block_kind(b) {
            BlockKind::Top => top_only.push(b),
            BlockKind::Bottom => bottom_only.push(b),
            BlockKind::Cross => cross.push(b),
        }
    }

    // Condition 2: top-only blocks fill positions 0.. consecutively, each
    // block contiguous.
    {
        let mut covered: Vec<&Vec<usize>> = top_only.clone();
        covered.sort_by_key(|b| b[0]);
        let mut next = 0usize;
        for b in covered {
            if b[0] != next || !contiguous(b) {
                return false;
            }
            next += b.len();
        }
        // they must start at the far left: enforced by next starting at 0.
    }

    // Condition 1: bottom-only blocks fill the far right of the bottom row,
    // contiguous, sizes ascending left→right.
    {
        let mut covered: Vec<&Vec<usize>> = bottom_only.clone();
        covered.sort_by_key(|b| b[0]);
        let total: usize = covered.iter().map(|b| b.len()).sum();
        let mut next = l + k - total;
        let mut prev_size = 0usize;
        for b in covered {
            if b[0] != next || !contiguous(b) {
                return false;
            }
            if b.len() < prev_size {
                return false; // must be non-decreasing left→right
            }
            prev_size = b.len();
            next += b.len();
        }
    }

    // Condition 3: cross blocks pairwise non-crossing — same relative order
    // on both rows, no interleaving.
    for i in 0..cross.len() {
        for j in (i + 1)..cross.len() {
            if cross_blocks_cross(cross[i], cross[j], l) {
                return false;
            }
        }
    }
    true
}

/// True iff `d` is an algorithmically planar `(l+k)\n`-diagram
/// (Definition 33): free vertices at the far right of each row (in order),
/// bottom pairs immediately left of the bottom free vertices, top pairs at
/// the far left, cross pairs non-crossing.
pub fn is_algorithmically_planar_jellyfish(d: &Diagram, n: usize) -> bool {
    if !d.is_jellyfish(n) {
        return false;
    }
    let (l, k) = (d.l, d.k);
    let free: Vec<usize> = d.free_vertices();
    let free_top: Vec<usize> = free.iter().copied().filter(|&v| v < l).collect();
    let free_bottom: Vec<usize> = free.iter().copied().filter(|&v| v >= l).collect();
    let s = free_top.len();
    // Free vertices are at the far right of each row.
    for (i, &v) in free_top.iter().enumerate() {
        if v != l - s + i {
            return false;
        }
    }
    for (i, &v) in free_bottom.iter().enumerate() {
        if v != l + k - (n - s) + i {
            return false;
        }
    }
    // The paired part must be algorithmically planar once the free
    // vertices are removed; removing them keeps indices of the pairs left
    // of the free zone intact, so reuse the partition predicate on the
    // restriction.
    let pairs: Vec<Vec<usize>> = d
        .blocks()
        .iter()
        .filter(|b| b.len() == 2)
        .cloned()
        .collect();
    let sub = match Diagram::from_blocks_loose(l - s, k - (n - s), pairs, l) {
        Some(x) => x,
        None => return false,
    };
    is_algorithmically_planar(&sub)
}

impl Diagram {
    /// Internal helper: reinterpret pair blocks of a jellyfish diagram as a
    /// smaller diagram after dropping the trailing free vertices of each
    /// row. `orig_l` is the original top-row length. Returns `None` if any
    /// pair touches the free zone (which would make the layout invalid).
    fn from_blocks_loose(
        new_l: usize,
        new_k: usize,
        pairs: Vec<Vec<usize>>,
        orig_l: usize,
    ) -> Option<Diagram> {
        let mut blocks = Vec::new();
        for b in pairs {
            let mut nb = Vec::new();
            for v in b {
                if v < orig_l {
                    if v >= new_l {
                        return None; // pair inside the top free zone
                    }
                    nb.push(v);
                } else {
                    let p = v - orig_l;
                    if p >= new_k {
                        return None; // pair inside the bottom free zone
                    }
                    nb.push(new_l + p);
                }
            }
            blocks.push(nb);
        }
        Diagram::from_blocks(new_l, new_k, blocks).ok()
    }
}

fn contiguous(sorted_block: &[usize]) -> bool {
    sorted_block
        .windows(2)
        .all(|w| w[1] == w[0] + 1)
}

/// Two cross blocks cross iff their top parts or bottom parts interleave,
/// or their relative order differs between rows.
fn cross_blocks_cross(a: &[usize], b: &[usize], l: usize) -> bool {
    let part = |blk: &[usize], top: bool| -> Vec<usize> {
        blk.iter()
            .copied()
            .filter(|&v| (v < l) == top)
            .collect()
    };
    let (at, ab) = (part(a, true), part(a, false));
    let (bt, bb) = (part(b, true), part(b, false));
    let before = |x: &[usize], y: &[usize]| x.last().unwrap() < y.first().unwrap();
    let top_ab = before(&at, &bt);
    let top_ba = before(&bt, &at);
    let bot_ab = before(&ab, &bb);
    let bot_ba = before(&bb, &ab);
    if !(top_ab || top_ba) || !(bot_ab || bot_ba) {
        return true; // interleaved within a row
    }
    top_ab != bot_ab // order flips between rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Example 7, first diagram: algorithmically planar (6,5)-partition
    /// diagram. We reconstruct a diagram with the stated structure: top-only
    /// blocks far left, cross blocks nested, bottom-only blocks far right.
    #[test]
    fn algorithmically_planar_accepts_factor_shape() {
        // top row: block {0,1} (top-only), cross uppers {2,3}, {4,5};
        // bottom row: cross lowers {0},{1} then bottom blocks {2}, {3,4}.
        let d = Diagram::from_blocks(
            6,
            5,
            vec![
                vec![0, 1],
                vec![2, 3, 6],
                vec![4, 5, 7],
                vec![8],
                vec![9, 10],
            ],
        )
        .unwrap();
        assert!(is_algorithmically_planar(&d));
        assert!(is_planar(&d));
    }

    /// Example 7, second diagram: a lone bottom block NOT at the far right
    /// relative to the other bottom blocks breaks condition 1 — model the
    /// spirit: bottom-only blocks in decreasing size left→right is invalid.
    #[test]
    fn wrong_bottom_order_rejected() {
        // bottom-only blocks {2,3} then {4}: sizes 2 then 1 — decreasing,
        // must be rejected.
        let d = Diagram::from_blocks(
            2,
            5,
            vec![vec![0, 1, 2, 3], vec![4, 5], vec![6]],
        )
        .unwrap();
        assert!(!is_algorithmically_planar(&d));
    }

    /// Example 7, third diagram: non-consecutive vertices in a bottom block.
    #[test]
    fn non_consecutive_block_rejected() {
        // bottom block {1,3} (positions 1 and 3) is not contiguous.
        let d = Diagram::from_blocks(
            1,
            4,
            vec![vec![0, 1], vec![2, 4], vec![3]],
        )
        .unwrap();
        assert!(!is_algorithmically_planar(&d));
    }

    #[test]
    fn crossing_cross_blocks_rejected() {
        // Two cross pairs that swap order between rows: 0-bottom1, 1-bottom0.
        let d = Diagram::from_blocks(2, 2, vec![vec![0, 3], vec![1, 2]]).unwrap();
        assert!(!is_algorithmically_planar(&d));
        assert!(!is_planar(&d));
    }

    #[test]
    fn identity_is_algorithmically_planar() {
        for k in 0..5 {
            assert!(is_algorithmically_planar(&Diagram::identity(k)));
        }
    }

    /// Example 9 shape: an algorithmically planar (5+6)\3-diagram has its
    /// free vertices at the far right of both rows.
    #[test]
    fn jellyfish_planarity() {
        // l = 5, k = 6, n = 3, s = 1 free on top, 2 free on bottom.
        // top: pair {0,1}, cross uppers {2}, {3}, free {4}
        // bottom: cross lowers {5+0},{5+1}, pair {5+2,5+3}, free {5+4},{5+5}
        let d = Diagram::from_blocks(
            5,
            6,
            vec![
                vec![0, 1],
                vec![2, 5],
                vec![3, 6],
                vec![4],
                vec![7, 8],
                vec![9],
                vec![10],
            ],
        )
        .unwrap();
        assert!(is_algorithmically_planar_jellyfish(&d, 3));
        // Move the top free vertex away from the far right: invalid
        // (Example 9's second diagram).
        let bad = Diagram::from_blocks(
            5,
            6,
            vec![
                vec![0],
                vec![1, 2],
                vec![3, 5],
                vec![4, 6],
                vec![7, 8],
                vec![9],
                vec![10],
            ],
        )
        .unwrap();
        assert!(!is_algorithmically_planar_jellyfish(&bad, 3));
    }

    #[test]
    fn planar_nested_brauer_ok() {
        // nested top pairs {0,3},{1,2} do not cross
        let d = Diagram::from_blocks(4, 0, vec![vec![0, 3], vec![1, 2]]).unwrap();
        assert!(is_planar(&d));
        // interleaved top pairs {0,2},{1,3} cross
        let x = Diagram::from_blocks(4, 0, vec![vec![0, 2], vec![1, 3]]).unwrap();
        assert!(!is_planar(&x));
    }
}
