//! Set partition diagrams and the categorical structure on them.
//!
//! A `(k,l)`-partition diagram (Definition 2) has `l` top vertices (labelled
//! `0..l` here, `1..l` in the paper) and `k` bottom vertices (labelled
//! `l..l+k`); its blocks are the blocks of a set partition of `[l+k]`.
//! Sub-families:
//!
//! - **Brauer diagrams** (Definition 3): every block has size exactly 2 —
//!   the spanning diagrams for O(n) and Sp(n).
//! - **`(l+k)\n`-diagrams** (Definition 3): exactly `n` singleton blocks
//!   ("free" vertices), all other blocks of size 2 — together with Brauer
//!   diagrams these span SO(n).
//!
//! The categorical operations live in [`compose`] (vertical composition
//! with the `n^c` scalar of Definition 18, and the tensor product of
//! Definition 19); spanning-set enumeration in [`enumerate`]; the planarity
//! notions of Definitions 31–33 in [`planar`]; and the paper's `Factor`
//! procedure in [`factor`].

pub mod compose;
pub mod decompose;
pub mod enumerate;
pub mod factor;
pub mod planar;

pub use compose::{compose, tensor_product, Composed};
pub use decompose::tensor_factors;
pub use enumerate::{
    all_brauer_diagrams, all_jellyfish_diagrams, all_partition_diagrams, bell_bounded,
    double_factorial, stirling2,
};
pub use factor::{factor, factor_jellyfish, Factored, PlanarLayout};

use crate::error::{Error, Result};
use crate::util::Rng;

/// A `(k,l)`-partition diagram: a set partition of `l + k` vertices where
/// `0..l` is the top row and `l..l+k` the bottom row.
///
/// Blocks are kept normalised (each block sorted ascending, blocks sorted by
/// their minimum), so `==` is diagram equality in the sense of the paper's
/// equivalence classes.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Diagram {
    /// Number of bottom (input) vertices — the domain order `k`.
    pub k: usize,
    /// Number of top (output) vertices — the codomain order `l`.
    pub l: usize,
    blocks: Vec<Vec<usize>>,
}

/// Classification of one block by which rows it touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// All vertices in the top row.
    Top,
    /// All vertices in the bottom row.
    Bottom,
    /// Vertices in both rows.
    Cross,
}

impl Diagram {
    /// Construct from blocks, validating that they partition `[l+k]`.
    pub fn from_blocks(l: usize, k: usize, blocks: Vec<Vec<usize>>) -> Result<Self> {
        let total = l + k;
        let mut seen = vec![false; total];
        let mut count = 0usize;
        for b in &blocks {
            if b.is_empty() {
                return Err(Error::InvalidPartition {
                    expected: total,
                    reason: "empty block".into(),
                });
            }
            for &v in b {
                if v >= total {
                    return Err(Error::InvalidPartition {
                        expected: total,
                        reason: format!("vertex {v} out of range"),
                    });
                }
                if seen[v] {
                    return Err(Error::InvalidPartition {
                        expected: total,
                        reason: format!("vertex {v} appears twice"),
                    });
                }
                seen[v] = true;
                count += 1;
            }
        }
        if count != total {
            return Err(Error::InvalidPartition {
                expected: total,
                reason: format!("covers {count} of {total} vertices"),
            });
        }
        let mut blocks: Vec<Vec<usize>> = blocks
            .into_iter()
            .map(|mut b| {
                b.sort_unstable();
                b
            })
            .collect();
        blocks.sort_by_key(|b| b[0]);
        Ok(Diagram { k, l, blocks })
    }

    /// The identity `(k,k)`-diagram (eq. 73): vertex `i` on top joined to
    /// vertex `i` on the bottom.
    pub fn identity(k: usize) -> Self {
        let blocks = (0..k).map(|i| vec![i, k + i]).collect();
        Diagram::from_blocks(k, k, blocks).expect("identity diagram is valid")
    }

    /// The `(m,m)`-diagram of a permutation `σ` (one-line notation over
    /// `0..m`): top vertex `i` is joined to bottom vertex `m + σ(i)`.
    pub fn permutation(sigma: &[usize]) -> Self {
        let m = sigma.len();
        let blocks = (0..m).map(|i| vec![i, m + sigma[i]]).collect();
        Diagram::from_blocks(m, m, blocks).expect("permutation diagram is valid")
    }

    /// Normalised blocks (sorted members, sorted by min).
    pub fn blocks(&self) -> &[Vec<usize>] {
        &self.blocks
    }

    /// Number of blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Classify one block.
    pub fn block_kind(&self, block: &[usize]) -> BlockKind {
        let has_top = block.iter().any(|&v| v < self.l);
        let has_bottom = block.iter().any(|&v| v >= self.l);
        match (has_top, has_bottom) {
            (true, true) => BlockKind::Cross,
            (true, false) => BlockKind::Top,
            (false, true) => BlockKind::Bottom,
            (false, false) => unreachable!("blocks are non-empty"),
        }
    }

    /// True iff every block has size exactly 2 (a Brauer diagram).
    pub fn is_brauer(&self) -> bool {
        self.blocks.iter().all(|b| b.len() == 2)
    }

    /// The singleton ("free") vertices — non-empty only for
    /// `(l+k)\n`-diagrams.
    pub fn free_vertices(&self) -> Vec<usize> {
        self.blocks
            .iter()
            .filter(|b| b.len() == 1)
            .map(|b| b[0])
            .collect()
    }

    /// True iff this is an `(l+k)\n`-diagram for the given `n`: exactly `n`
    /// singleton blocks and every other block of size 2.
    pub fn is_jellyfish(&self, n: usize) -> bool {
        let singles = self.blocks.iter().filter(|b| b.len() == 1).count();
        singles == n && self.blocks.iter().all(|b| b.len() == 1 || b.len() == 2)
    }

    /// Transpose: swap the rows, giving the `(l,k)`-diagram whose matrix
    /// (under Θ/Φ/Ψ) is the matrix transpose of this one's. Used for the
    /// backward pass.
    pub fn transpose(&self) -> Diagram {
        let (l, k) = (self.l, self.k);
        let blocks = self
            .blocks
            .iter()
            .map(|b| {
                b.iter()
                    .map(|&v| if v < l { k + v } else { v - l })
                    .collect()
            })
            .collect();
        Diagram::from_blocks(k, l, blocks).expect("transpose of valid diagram is valid")
    }

    /// Block id for each vertex (for delta tests): `membership()[v]` is the
    /// index into `blocks()` of the block containing `v`.
    pub fn membership(&self) -> Vec<usize> {
        let mut m = vec![usize::MAX; self.l + self.k];
        for (bi, b) in self.blocks.iter().enumerate() {
            for &v in b {
                m[v] = bi;
            }
        }
        m
    }

    /// A uniformly random `(k,l)`-partition diagram, via a random restricted
    /// growth string. (Uniform over RGS, which is uniform over partitions.)
    pub fn random_partition(l: usize, k: usize, rng: &mut Rng) -> Self {
        let total = l + k;
        let mut assignment = vec![0usize; total];
        let mut num_blocks = if total > 0 { 1 } else { 0 };
        for v in 1..total {
            // RGS step: join an existing block or open a new one.
            let c = rng.below(num_blocks + 1);
            assignment[v] = c;
            if c == num_blocks {
                num_blocks += 1;
            }
        }
        let mut blocks: Vec<Vec<usize>> = vec![Vec::new(); num_blocks];
        for (v, &c) in assignment.iter().enumerate() {
            blocks[c].push(v);
        }
        Diagram::from_blocks(l, k, blocks).expect("RGS yields a valid partition")
    }

    /// A random `(k,l)`-Brauer diagram (uniform perfect matching).
    /// Requires `l + k` even.
    pub fn random_brauer(l: usize, k: usize, rng: &mut Rng) -> Result<Self> {
        let total = l + k;
        if total % 2 != 0 {
            return Err(Error::DimensionConstraint(format!(
                "Brauer diagram needs l+k even, got {total}"
            )));
        }
        let mut verts: Vec<usize> = (0..total).collect();
        rng.shuffle(&mut verts);
        let blocks = verts.chunks(2).map(|c| c.to_vec()).collect();
        Diagram::from_blocks(l, k, blocks)
    }

    /// A random `(l+k)\n`-diagram: choose `n` free vertices uniformly, match
    /// the rest. Requires `l + k - n` even and non-negative.
    pub fn random_jellyfish(l: usize, k: usize, n: usize, rng: &mut Rng) -> Result<Self> {
        let total = l + k;
        if n > total || (total - n) % 2 != 0 {
            return Err(Error::DimensionConstraint(format!(
                "(l+k)\\n-diagram needs l+k-n even and >= 0; l+k={total}, n={n}"
            )));
        }
        let mut verts: Vec<usize> = (0..total).collect();
        rng.shuffle(&mut verts);
        let mut blocks: Vec<Vec<usize>> = verts[..n].iter().map(|&v| vec![v]).collect();
        for c in verts[n..].chunks(2) {
            blocks.push(c.to_vec());
        }
        Diagram::from_blocks(l, k, blocks)
    }

    /// Validate this diagram for a group's spanning family.
    pub fn validate_for(&self, group: crate::fastmult::Group, n: usize) -> Result<()> {
        use crate::fastmult::Group;
        match group {
            Group::Symmetric => Ok(()),
            Group::Orthogonal => {
                if self.is_brauer() {
                    Ok(())
                } else {
                    Err(Error::InvalidDiagramForGroup {
                        group: "O(n)".into(),
                        reason: "not a Brauer diagram".into(),
                    })
                }
            }
            Group::Symplectic => {
                if n % 2 != 0 {
                    Err(Error::DimensionConstraint("Sp(n) needs even n".into()))
                } else if self.is_brauer() {
                    Ok(())
                } else {
                    Err(Error::InvalidDiagramForGroup {
                        group: "Sp(n)".into(),
                        reason: "not a Brauer diagram".into(),
                    })
                }
            }
            Group::SpecialOrthogonal => {
                if self.is_brauer() || self.is_jellyfish(n) {
                    Ok(())
                } else {
                    Err(Error::InvalidDiagramForGroup {
                        group: "SO(n)".into(),
                        reason: format!("neither Brauer nor (l+k)\\{n}-diagram"),
                    })
                }
            }
        }
    }
}

impl std::fmt::Display for Diagram {
    /// Paper-style notation, e.g. `{1, 2, 5, 7 | 3, 4, 10 | 6, 8 | 9}` with
    /// 1-based labels (Example 1).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{})-diagram {{", self.k, self.l)?;
        for (i, b) in self.blocks.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            for (j, v) in b.iter().enumerate() {
                if j > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{}", v + 1)?;
            }
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastmult::Group;

    #[test]
    fn example1_paper_partition() {
        // Example 1: {1,2,5,7 | 3,4,10 | 6,8 | 9} over [4+6] (1-based).
        let d = Diagram::from_blocks(
            4,
            6,
            vec![vec![0, 1, 4, 6], vec![2, 3, 9], vec![5, 7], vec![8]],
        )
        .unwrap();
        assert_eq!(d.num_blocks(), 4);
        assert_eq!(d.l, 4);
        assert_eq!(d.k, 6);
    }

    #[test]
    fn rejects_bad_partitions() {
        assert!(Diagram::from_blocks(1, 1, vec![vec![0]]).is_err()); // misses 1
        assert!(Diagram::from_blocks(1, 1, vec![vec![0, 0], vec![1]]).is_err()); // dup
        assert!(Diagram::from_blocks(1, 1, vec![vec![0, 2], vec![1]]).is_err()); // range
        assert!(Diagram::from_blocks(1, 1, vec![vec![0, 1], vec![]]).is_err()); // empty
    }

    #[test]
    fn normalisation_makes_equality_structural() {
        let a = Diagram::from_blocks(2, 2, vec![vec![3, 0], vec![2, 1]]).unwrap();
        let b = Diagram::from_blocks(2, 2, vec![vec![1, 2], vec![0, 3]]).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn identity_shape() {
        let d = Diagram::identity(3);
        assert_eq!(d.num_blocks(), 3);
        assert!(d.is_brauer());
        assert_eq!(d.blocks()[0], vec![0, 3]);
    }

    #[test]
    fn permutation_diagram() {
        // sigma = (0 1) swap on 2 points
        let d = Diagram::permutation(&[1, 0]);
        assert_eq!(d.blocks()[0], vec![0, 3]);
        assert_eq!(d.blocks()[1], vec![1, 2]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let d = Diagram::random_partition(3, 4, &mut rng);
            assert_eq!(d.transpose().transpose(), d);
            assert_eq!(d.transpose().l, d.k);
            assert_eq!(d.transpose().k, d.l);
        }
    }

    #[test]
    fn block_kind_classification() {
        let d = Diagram::from_blocks(2, 2, vec![vec![0, 1], vec![2, 3]]).unwrap();
        assert_eq!(d.block_kind(&d.blocks()[0]), BlockKind::Top);
        assert_eq!(d.block_kind(&d.blocks()[1]), BlockKind::Bottom);
        let id = Diagram::identity(2);
        assert_eq!(id.block_kind(&id.blocks()[0]), BlockKind::Cross);
    }

    #[test]
    fn brauer_and_jellyfish_predicates() {
        let mut rng = Rng::new(7);
        let b = Diagram::random_brauer(3, 3, &mut rng).unwrap();
        assert!(b.is_brauer());
        assert!(!b.is_jellyfish(2));
        let j = Diagram::random_jellyfish(3, 4, 3, &mut rng).unwrap();
        assert!(j.is_jellyfish(3));
        assert_eq!(j.free_vertices().len(), 3);
        assert!(Diagram::random_brauer(2, 1, &mut rng).is_err());
        assert!(Diagram::random_jellyfish(2, 2, 3, &mut rng).is_err());
    }

    #[test]
    fn validate_for_groups() {
        let mut rng = Rng::new(9);
        let part = Diagram::from_blocks(2, 2, vec![vec![0, 1, 2], vec![3]]).unwrap();
        assert!(part.validate_for(Group::Symmetric, 3).is_ok());
        assert!(part.validate_for(Group::Orthogonal, 3).is_err());
        let b = Diagram::random_brauer(2, 2, &mut rng).unwrap();
        assert!(b.validate_for(Group::Orthogonal, 3).is_ok());
        assert!(b.validate_for(Group::Symplectic, 4).is_ok());
        assert!(b.validate_for(Group::Symplectic, 3).is_err());
        assert!(b.validate_for(Group::SpecialOrthogonal, 3).is_ok());
        let j = Diagram::random_jellyfish(2, 3, 3, &mut rng).unwrap();
        assert!(j.validate_for(Group::SpecialOrthogonal, 3).is_ok());
        assert!(j.validate_for(Group::Orthogonal, 3).is_err());
    }

    #[test]
    fn display_is_one_based() {
        let d = Diagram::from_blocks(1, 1, vec![vec![0, 1]]).unwrap();
        assert_eq!(format!("{d}"), "(1,1)-diagram {1, 2}");
    }

    #[test]
    fn random_partition_valid_and_varied() {
        let mut rng = Rng::new(11);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..50 {
            let d = Diagram::random_partition(2, 3, &mut rng);
            assert_eq!(d.l + d.k, 5);
            seen.insert(d);
        }
        assert!(seen.len() > 10, "should sample many distinct partitions");
    }
}
