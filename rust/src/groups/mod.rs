//! Samplers for elements of S_n, O(n), SO(n) and Sp(n) (as `n×n` matrices
//! in the standard / symplectic basis), plus the diagonal tensor-power
//! action `ρ_k` (eq. 2).
//!
//! These exist to *test* the equivariance property (eq. 3)
//! `W ρ_k(g) v = ρ_l(g) W v` for every spanning matrix `W` — the
//! theorem-level validation that our functors and fast multiplication
//! implement the right maps.

use crate::error::{Error, Result};
use crate::fastmult::Group;
use crate::linalg::Matrix;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Sample a group element of `G(n)` as a row-major `n×n` matrix.
pub fn sample(group: Group, n: usize, rng: &mut Rng) -> Result<Matrix> {
    match group {
        Group::Symmetric => Ok(permutation_matrix(&rng.permutation(n))),
        Group::Orthogonal => {
            let q = random_orthogonal(n, rng);
            Ok(q)
        }
        Group::SpecialOrthogonal => {
            let mut q = random_orthogonal(n, rng);
            if q.det() < 0.0 {
                // Flip one column to land in SO(n).
                for r in 0..n {
                    let v = -q.get(r, 0);
                    q.set(r, 0, v);
                }
            }
            Ok(q)
        }
        Group::Symplectic => {
            if n % 2 != 0 {
                return Err(Error::DimensionConstraint(
                    "Sp(n) requires even n".into(),
                ));
            }
            Ok(random_symplectic(n, rng))
        }
    }
}

/// Permutation matrix: column `j` is `e_{σ(j)}` so that `M e_j = e_{σ(j)}`.
pub fn permutation_matrix(sigma: &[usize]) -> Matrix {
    let n = sigma.len();
    let mut m = Matrix::zeros(n, n);
    for (j, &i) in sigma.iter().enumerate() {
        m.set(i, j, 1.0);
    }
    m
}

/// Haar-ish random orthogonal matrix: Gram–Schmidt of a Gaussian matrix
/// (retries on the measure-zero rank-deficient case).
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Matrix {
    loop {
        let g = Matrix::gaussian(n, n, rng);
        if let Some(q) = g.gram_schmidt() {
            return q;
        }
    }
}

/// Random symplectic matrix w.r.t. the interleaved form
/// `ε_{2i,2i+1} = 1 = -ε_{2i+1,2i}` (the basis `1,1',…,m,m'`).
///
/// Built from the standard generators in the block basis
/// `(x_1…x_m, y_1…y_m)` — `diag(A, A^{-T})`, `[[I,B],[0,I]]` with `B`
/// symmetric, `[[I,0],[C,I]]` with `C` symmetric — then conjugated into the
/// interleaved ordering.
pub fn random_symplectic(n: usize, rng: &mut Rng) -> Matrix {
    let m = n / 2;
    // A invertible, well conditioned: use an orthogonal matrix, so
    // A^{-T} = A.
    let a = random_orthogonal(m, rng);
    let mut block = Matrix::zeros(n, n);
    for r in 0..m {
        for c in 0..m {
            block.set(r, c, a.get(r, c));
            block.set(m + r, m + c, a.get(r, c)); // A^{-T} = A (orthogonal)
        }
    }
    // Right-multiply by [[I, B], [0, I]] and [[I, 0], [C, I]] with small
    // symmetric B, C to leave the "trivial" subgroup.
    let b = small_symmetric(m, rng);
    let c = small_symmetric(m, rng);
    let upper = block_upper(&b);
    let lower = block_lower(&c);
    let g_block = block.matmul(&upper).unwrap().matmul(&lower).unwrap();
    // Conjugate into the interleaved basis: interleaved index 2i ↔ block i,
    // 2i+1 ↔ block m+i.
    let mut s = Matrix::zeros(n, n);
    for i in 0..m {
        s.set(2 * i, i, 1.0);
        s.set(2 * i + 1, m + i, 1.0);
    }
    s.matmul(&g_block).unwrap().matmul(&s.transpose()).unwrap()
}

fn small_symmetric(m: usize, rng: &mut Rng) -> Matrix {
    let mut b = Matrix::zeros(m, m);
    for r in 0..m {
        for c in r..m {
            let v = 0.3 * rng.gaussian();
            b.set(r, c, v);
            b.set(c, r, v);
        }
    }
    b
}

fn block_upper(b: &Matrix) -> Matrix {
    let m = b.rows;
    let mut u = Matrix::identity(2 * m);
    for r in 0..m {
        for c in 0..m {
            u.set(r, m + c, b.get(r, c));
        }
    }
    u
}

fn block_lower(c: &Matrix) -> Matrix {
    let m = c.rows;
    let mut l = Matrix::identity(2 * m);
    for r in 0..m {
        for cc in 0..m {
            l.set(m + r, cc, c.get(r, cc));
        }
    }
    l
}

/// The symplectic form as a matrix in the interleaved basis.
pub fn symplectic_form(n: usize) -> Matrix {
    let mut j = Matrix::zeros(n, n);
    for i in 0..n / 2 {
        j.set(2 * i, 2 * i + 1, 1.0);
        j.set(2 * i + 1, 2 * i, -1.0);
    }
    j
}

/// Apply `ρ_k(g)` to a tensor: `g` along every axis (eq. 2).
pub fn rho(g: &Matrix, v: &Tensor) -> Tensor {
    debug_assert_eq!(g.rows, v.n);
    debug_assert_eq!(g.cols, v.n);
    v.rho_apply(&g.data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_matrix_action() {
        let m = permutation_matrix(&[2, 0, 1]);
        // M e_0 = e_2
        let v = m.matvec(&[1.0, 0.0, 0.0]).unwrap();
        assert_eq!(v, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn orthogonal_is_orthogonal() {
        let mut rng = Rng::new(61);
        for _ in 0..5 {
            let q = random_orthogonal(5, &mut rng);
            let qtq = q.transpose().matmul(&q).unwrap();
            assert!(qtq.max_abs_diff(&Matrix::identity(5)) < 1e-9);
        }
    }

    #[test]
    fn special_orthogonal_has_unit_det() {
        let mut rng = Rng::new(62);
        for _ in 0..10 {
            let q = sample(Group::SpecialOrthogonal, 4, &mut rng).unwrap();
            assert!((q.det() - 1.0).abs() < 1e-8, "det {}", q.det());
            let qtq = q.transpose().matmul(&q).unwrap();
            assert!(qtq.max_abs_diff(&Matrix::identity(4)) < 1e-9);
        }
    }

    #[test]
    fn symplectic_preserves_form() {
        let mut rng = Rng::new(63);
        for n in [2usize, 4, 6] {
            for _ in 0..5 {
                let g = random_symplectic(n, &mut rng);
                let j = symplectic_form(n);
                let gtjg = g.transpose().matmul(&j).unwrap().matmul(&g).unwrap();
                assert!(
                    gtjg.max_abs_diff(&j) < 1e-8,
                    "n={n}: form not preserved, diff {}",
                    gtjg.max_abs_diff(&j)
                );
            }
        }
    }

    #[test]
    fn symplectic_rejects_odd_n() {
        let mut rng = Rng::new(64);
        assert!(sample(Group::Symplectic, 3, &mut rng).is_err());
    }

    #[test]
    fn rho_is_multiplicative() {
        // ρ_k(g h) = ρ_k(g) ρ_k(h)
        let mut rng = Rng::new(65);
        let g = random_orthogonal(3, &mut rng);
        let h = random_orthogonal(3, &mut rng);
        let gh = g.matmul(&h).unwrap();
        let v = Tensor::random(3, 3, &mut rng);
        let a = rho(&gh, &v);
        let b = rho(&g, &rho(&h, &v));
        assert!(a.allclose(&b, 1e-9));
    }

    #[test]
    fn symmetric_sample_is_permutation() {
        let mut rng = Rng::new(66);
        let g = sample(Group::Symmetric, 5, &mut rng).unwrap();
        // Exactly one 1 per row and column.
        for r in 0..5 {
            let ones = (0..5).filter(|&c| g.get(r, c) == 1.0).count();
            let zeros = (0..5).filter(|&c| g.get(r, c) == 0.0).count();
            assert_eq!(ones, 1);
            assert_eq!(zeros, 4);
        }
    }
}
