//! Minimal benchmark timer used by `rust/benches/*` (criterion substitute).
//!
//! Semantics: warm up, run the closure repeatedly in timed batches until a
//! time budget is met, report the median per-iteration time. All bench
//! tables in EXPERIMENTS.md come from this.

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Median wall time per iteration, seconds.
    pub median_s: f64,
    /// Minimum observed per-iteration time, seconds.
    pub min_s: f64,
    /// Total iterations executed in the measurement phase.
    pub iters: u64,
}

impl BenchResult {
    /// Human-readable time with unit scaling.
    pub fn pretty(&self) -> String {
        format_seconds(self.median_s)
    }
}

/// Format a duration in seconds with an appropriate unit.
pub fn format_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f`, returning the median per-iteration time.
///
/// Runs a warmup phase (~10% of budget) to stabilise caches and the
/// allocator, then measures in batches sized so each sample takes ~1ms,
/// collecting at least 5 samples.
pub fn bench_median<F: FnMut()>(budget: Duration, mut f: F) -> BenchResult {
    // Warmup + batch size calibration.
    let warmup_deadline = Instant::now() + budget.mul_f64(0.1).max(Duration::from_millis(5));
    let mut calib_iters: u64 = 0;
    let calib_start = Instant::now();
    loop {
        f();
        calib_iters += 1;
        if Instant::now() >= warmup_deadline {
            break;
        }
    }
    let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters as f64;
    let batch = ((1e-3 / per_iter.max(1e-12)).ceil() as u64).clamp(1, 1_000_000);

    // Measurement phase.
    let mut samples: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let deadline = Instant::now() + budget;
    while Instant::now() < deadline || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        total_iters += batch;
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let min = samples[0];
    BenchResult {
        median_s: median,
        min_s: min,
        iters: total_iters,
    }
}

/// Ordinary least-squares slope of `log(y)` against `log(x)` — the measured
/// complexity exponent used by the scaling benches.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let mx = lx.iter().sum::<f64>() / lx.len() as f64;
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_cubic_is_three() {
        let xs = [2.0, 4.0, 8.0, 16.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x * x * x).collect();
        let s = loglog_slope(&xs, &ys);
        assert!((s - 3.0).abs() < 1e-9, "slope {s}");
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let r = bench_median(Duration::from_millis(20), || {
            acc = acc.wrapping_add(1);
        });
        assert!(r.iters > 0);
        assert!(r.median_s >= 0.0);
    }

    #[test]
    fn format_units() {
        assert!(format_seconds(2.0).ends_with(" s"));
        assert!(format_seconds(2e-3).ends_with(" ms"));
        assert!(format_seconds(2e-6).ends_with(" us"));
        assert!(format_seconds(2e-9).ends_with(" ns"));
    }
}
