//! Plain-text table printer for benchmark and experiment output.
//!
//! The benches print paper-style rows; EXPERIMENTS.md quotes them verbatim.

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["n", "time"]);
        t.row(vec!["2", "1.0 us"]);
        t.row(vec!["16", "123.0 us"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("n"));
        assert!(lines[2].starts_with("2"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }
}
