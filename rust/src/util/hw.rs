//! Hardware cache-topology probe.
//!
//! The tiled schedule walk (`fastmult::schedule`) sizes its streaming
//! tiles to the last-level *private* cache so every interior
//! intermediate of a chain stays resident while the tile flows through
//! it. That budget comes from here: a once-per-process probe of the
//! OS-reported cache hierarchy with an environment override for
//! benchmarking and a conservative compile-time fallback when the
//! platform exposes nothing.
//!
//! Resolution order (first hit wins), cached in a `OnceLock` like
//! [`crate::util::executor::hw_threads`]:
//!
//! 1. `PALLAS_CACHE_BYTES` — explicit byte count (plain integer, or
//!    with a `K`/`M` suffix); `0` or garbage falls through.
//! 2. Linux sysfs: `/sys/devices/system/cpu/cpu0/cache/index*/size`,
//!    preferring the level-2 `Unified`/`Data` cache (the per-core
//!    private cache on every current x86/ARM server part), falling
//!    back to the largest data-carrying cache reported.
//! 3. [`DEFAULT_CACHE_BYTES`] (256 KiB) — small enough to be L2-safe
//!    on anything made this century, large enough that small shapes
//!    never tile.

use std::sync::OnceLock;

/// Conservative fallback when the platform reports nothing: 256 KiB,
/// the smallest per-core L2 on currently common server hardware.
pub const DEFAULT_CACHE_BYTES: usize = 256 * 1024;

/// Parse a cache size string: a plain byte count, or an integer with a
/// trailing `K`/`M` (sysfs writes e.g. `512K`, `8M`; the env override
/// accepts the same forms). Returns `None` for empty/garbage/zero.
fn parse_size(s: &str) -> Option<usize> {
    let t = s.trim();
    if t.is_empty() {
        return None;
    }
    let (digits, mult) = match t.as_bytes()[t.len() - 1] {
        b'K' | b'k' => (&t[..t.len() - 1], 1024),
        b'M' | b'm' => (&t[..t.len() - 1], 1024 * 1024),
        _ => (t, 1),
    };
    let v: usize = digits.trim().parse().ok()?;
    let bytes = v.checked_mul(mult)?;
    if bytes == 0 {
        None
    } else {
        Some(bytes)
    }
}

/// Probe `/sys/devices/system/cpu/cpu0/cache/index*/` for the level-2
/// unified/data cache size, falling back to the largest data-carrying
/// cache listed. Returns `None` off Linux or when sysfs is absent.
fn sysfs_cache_bytes() -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    let entries = std::fs::read_dir(base).ok()?;
    let mut level2: Option<usize> = None;
    let mut largest: Option<usize> = None;
    for entry in entries.flatten() {
        let path = entry.path();
        if !path
            .file_name()
            .and_then(|f| f.to_str())
            .is_some_and(|f| f.starts_with("index"))
        {
            continue;
        }
        let read = |name: &str| std::fs::read_to_string(path.join(name)).ok();
        // Instruction caches never hold tensor data; skip them.
        let ctype = read("type").unwrap_or_default();
        let ctype = ctype.trim();
        if ctype != "Unified" && ctype != "Data" {
            continue;
        }
        let Some(size) = read("size").and_then(|s| parse_size(&s)) else {
            continue;
        };
        let level = read("level").and_then(|s| s.trim().parse::<usize>().ok());
        if level == Some(2) {
            level2 = Some(level2.map_or(size, |c: usize| c.max(size)));
        }
        largest = Some(largest.map_or(size, |c: usize| c.max(size)));
    }
    level2.or(largest)
}

/// Per-core cache budget in bytes, queried once per process (see the
/// module docs for the resolution order). Always ≥ 1.
pub fn cache_bytes() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(s) = std::env::var("PALLAS_CACHE_BYTES") {
            if let Some(bytes) = parse_size(&s) {
                return bytes;
            }
        }
        sysfs_cache_bytes().unwrap_or(DEFAULT_CACHE_BYTES)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_forms() {
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("512K"), Some(512 * 1024));
        assert_eq!(parse_size("512k"), Some(512 * 1024));
        assert_eq!(parse_size("8M"), Some(8 * 1024 * 1024));
        assert_eq!(parse_size(" 1024K\n"), Some(1024 * 1024));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("0"), None);
        assert_eq!(parse_size("0K"), None);
        assert_eq!(parse_size("lots"), None);
    }

    #[test]
    fn cache_bytes_is_cached_and_positive() {
        let a = cache_bytes();
        let b = cache_bytes();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
