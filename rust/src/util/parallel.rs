//! Scoped-thread fan-out helper (rayon substitute, see DESIGN.md §3).
//!
//! The batched layer/model/coordinator paths are embarrassingly parallel
//! across batch items and across diagram terms; [`parallel_map`] is the one
//! primitive they all share. It slices the input into contiguous chunks,
//! runs each chunk on a `std::thread::scope` worker and preserves input
//! order in the output — no work queue, no dependencies, deterministic
//! results.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide cap on per-call fan-out (`0` = uncapped). Set by the
/// coordinator so that N serving workers each fanning out batches do not
/// oversubscribe the machine N-fold.
static THREAD_BUDGET: AtomicUsize = AtomicUsize::new(0);

/// Cap [`max_threads`] at `budget` threads per `parallel_map` call
/// (`0` removes the cap). The coordinator sets this to
/// `available_parallelism / workers` on start so nested parallelism
/// (worker threads × per-batch fan-out) stays at one thread per core,
/// and restores the prior value (see [`thread_budget`]) on shutdown.
pub fn set_thread_budget(budget: usize) {
    THREAD_BUDGET.store(budget, Ordering::Relaxed);
}

/// The current fan-out cap (`0` = uncapped) — read it before
/// [`set_thread_budget`] to restore it afterwards.
pub fn thread_budget() -> usize {
    THREAD_BUDGET.load(Ordering::Relaxed)
}

/// Number of worker threads worth spawning per fan-out on this machine:
/// the hardware parallelism, capped by [`set_thread_budget`].
pub fn max_threads() -> usize {
    let hw = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    match THREAD_BUDGET.load(Ordering::Relaxed) {
        0 => hw,
        budget => hw.min(budget),
    }
}

/// Contiguous-span length that divides `len` items across the available
/// worker threads — the one chunking rule the batched layer/net/backward
/// paths share. Spans are floored at `MIN_SPAN` items (when the batch has
/// that many): a batched schedule walk amortises its per-node index maps
/// across the span, so degenerating to 1-item spans on many-core machines
/// would pay map construction per item with nothing amortised.
pub fn span_len(len: usize) -> usize {
    const MIN_SPAN: usize = 4;
    len.div_ceil(max_threads()).max(MIN_SPAN.min(len)).max(1)
}

/// Apply `f` to every item of `items`, fanning contiguous chunks out over
/// up to `threads` scoped worker threads. Output order matches input order.
///
/// With `threads <= 1` (or one item) this degenerates to a plain
/// sequential map with zero overhead, so callers can pass
/// `max_threads().min(items.len())` unconditionally.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.min(items.len());
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        let mut chunks = items.chunks(chunk).zip(slots.chunks_mut(chunk));
        // The calling thread is a worker too: it takes the first chunk
        // itself, so `threads` workers cost only `threads - 1` spawns (and
        // a nested caller — e.g. a coordinator worker — never goes fully
        // idle while its helpers run).
        let own = chunks.next();
        for (in_chunk, out_chunk) in chunks {
            s.spawn(move || {
                for (item, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
        if let Some((in_chunk, out_chunk)) = own {
            for (item, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                *slot = Some(f(item));
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("scoped worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1, 2, 3, 8, 200] {
            let out = parallel_map(&items, threads, |&x| x * 2);
            assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn visits_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn thread_budget_caps_and_uncaps() {
        // Note: the budget is process-global; restore 0 before exiting so
        // concurrently-running tests are not capped afterwards.
        set_thread_budget(1);
        assert_eq!(max_threads(), 1);
        set_thread_budget(0);
        assert!(max_threads() >= 1);
    }
}
