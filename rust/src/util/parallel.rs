//! Ordered fan-out helper (rayon substitute, see DESIGN.md §3).
//!
//! The batched layer/model/coordinator paths are embarrassingly parallel
//! across batch items and across diagram terms; [`parallel_map`] is the
//! one primitive they all share. It slices the input into contiguous
//! chunks and runs each chunk as a task on the persistent work-stealing
//! pool ([`crate::util::executor`]) — no per-call thread spawns. Output
//! order matches input order and every chunk is computed sequentially by
//! exactly one thread, so results are deterministic regardless of which
//! worker (or steal order) ran each chunk.

use crate::util::executor::{self, Executor};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide cap on per-call fan-out (`0` = uncapped). Set by the
/// coordinator so that N serving workers each fanning out batches do not
/// oversubscribe the machine N-fold.
static THREAD_BUDGET: AtomicUsize = AtomicUsize::new(0);

/// Cap [`max_threads`] at `budget` threads per `parallel_map` call
/// (`0` removes the cap). The coordinator sets this to
/// `available_parallelism / workers` on start so nested parallelism
/// (worker threads × per-batch fan-out) stays at one thread per core,
/// and restores the prior value (see [`thread_budget`]) on shutdown.
pub fn set_thread_budget(budget: usize) {
    THREAD_BUDGET.store(budget, Ordering::Relaxed);
}

/// The current fan-out cap (`0` = uncapped) — read it before
/// [`set_thread_budget`] to restore it afterwards.
pub fn thread_budget() -> usize {
    THREAD_BUDGET.load(Ordering::Relaxed)
}

/// Number of chunks worth fanning out per call on this machine: the
/// hardware parallelism (cached once per process, see
/// [`executor::hw_threads`]), capped by [`set_thread_budget`]. The
/// budget shapes *chunking*, not the pool — the global pool keeps one
/// worker per hardware thread and parks the idle ones.
pub fn max_threads() -> usize {
    let hw = executor::hw_threads();
    match THREAD_BUDGET.load(Ordering::Relaxed) {
        0 => hw,
        budget => hw.min(budget),
    }
}

/// Contiguous-span length that divides `len` items across the available
/// worker threads — the one chunking rule the batched layer/net/backward
/// paths share. Spans are floored at `MIN_SPAN` items (when the batch has
/// that many): a batched schedule walk amortises its per-node index maps
/// across the span, so degenerating to 1-item spans on many-core machines
/// would pay map construction per item with nothing amortised.
pub fn span_len(len: usize) -> usize {
    const MIN_SPAN: usize = 4;
    len.div_ceil(max_threads()).max(MIN_SPAN.min(len)).max(1)
}

/// Apply `f` to every item of `items`, fanning contiguous chunks out
/// over the process-wide executor with a concurrency of up to `threads`.
/// Output order matches input order.
///
/// With `threads <= 1` (or one item) this degenerates to a plain
/// sequential map with zero overhead, so callers can pass
/// `max_threads().min(items.len())` unconditionally.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_on(executor::global(), items, threads, f)
}

/// [`parallel_map`] on an explicit pool — the determinism suites use
/// this to pin results across pool sizes 1/2/hardware.
pub fn parallel_map_on<T, R, F>(exec: &Executor, items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.min(items.len());
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    // Chunk boundaries depend only on `items.len()` and `threads` —
    // never on the pool size or steal order — so accumulation inside a
    // chunk (and the caller's in-order reduction over chunks) is fixed.
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    let tasks: Vec<_> = items
        .chunks(chunk)
        .zip(slots.chunks_mut(chunk))
        .map(|(in_chunk, out_chunk)| {
            move || {
                for (item, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            }
        })
        .collect();
    exec.join_all(tasks);
    slots
        .into_iter()
        .map(|r| r.expect("executor ran every chunk"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1, 2, 3, 8, 200] {
            let out = parallel_map(&items, threads, |&x| x * 2);
            assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn visits_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn thread_budget_caps_and_uncaps() {
        // Note: the budget is process-global; restore 0 before exiting so
        // concurrently-running tests are not capped afterwards.
        set_thread_budget(1);
        assert_eq!(max_threads(), 1);
        set_thread_budget(0);
        assert!(max_threads() >= 1);
    }

    #[test]
    fn explicit_pools_agree_with_global() {
        let items: Vec<u64> = (0..257).collect();
        let reference = parallel_map(&items, 8, |&x| x.wrapping_mul(0x9e37_79b9));
        for workers in [1, 2, crate::util::executor::hw_threads()] {
            let exec = Executor::new(workers);
            let out = parallel_map_on(&exec, &items, 8, |&x| x.wrapping_mul(0x9e37_79b9));
            assert_eq!(out, reference);
        }
    }
}
