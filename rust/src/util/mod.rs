//! Shared utilities: deterministic PRNG, timing, table printing and the
//! in-house property-testing harness.
//!
//! The offline crate registry for this build ships neither `rand` nor
//! `proptest` nor `criterion`; these small substrates replace exactly the
//! parts of each that the rest of the crate needs (see DESIGN.md §3).

pub mod executor;
pub mod hw;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod table;
pub mod timing;

pub use executor::{Executor, ExecutorStats};
pub use parallel::{max_threads, parallel_map, parallel_map_on, set_thread_budget, thread_budget};
pub use rng::Rng;
pub use table::Table;
pub use timing::{bench_median, BenchResult};
