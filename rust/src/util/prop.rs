//! In-house property-testing harness (proptest substitute, see DESIGN.md §3).
//!
//! A property is a closure from a seeded [`Rng`] to `Result<(), String>`.
//! The runner executes `cases` independent cases with derived seeds and, on
//! failure, reports the failing seed so the case replays deterministically:
//!
//! ```
//! use equidiag::util::prop::{check, Config};
//! check(Config::default().cases(64), "addition commutes", |rng| {
//!     let a = rng.uniform();
//!     let b = rng.uniform();
//!     if (a + b - (b + a)).abs() < 1e-15 { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Master seed; case `i` runs with seed `splitmix(seed, i)`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xE1_D1A6_2024,
        }
    }
}

impl Config {
    /// Override the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the master seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

fn splitmix(seed: u64, i: u64) -> u64 {
    let mut z = seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run `prop` over `cfg.cases` random cases; panic with the failing seed and
/// message on the first failure.
pub fn check<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let case_seed = splitmix(cfg.seed, i as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {i}/{} (replay seed {case_seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Replay a single case with an explicit seed (for debugging failures).
pub fn replay<F>(seed: u64, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' replay (seed {seed:#x}) failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(16), "tautology", |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_reports() {
        check(Config::default().cases(4), "always fails", |_| {
            Err("always fails".into())
        });
    }

    #[test]
    fn seeds_differ_across_cases() {
        let a = splitmix(1, 0);
        let b = splitmix(1, 1);
        assert_ne!(a, b);
    }
}
