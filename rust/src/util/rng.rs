//! Deterministic xorshift64* PRNG, dependency-free.
//!
//! All randomness in tests, property checks and samplers flows through
//! [`Rng`] so every failure is reproducible from its seed.

/// xorshift64* generator: tiny, fast, and statistically adequate for
/// sampling test inputs and initialising weights.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a non-zero seed (zero is remapped).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64_raw(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64_raw() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, bound)`.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64_raw() % bound as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let mut u1 = self.uniform();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..m` (one-line notation).
    pub fn permutation(&mut self, m: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..m).collect();
        self.shuffle(&mut p);
        p
    }

    /// Vector of iid standard normals.
    pub fn gaussian_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.gaussian()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64_raw(), b.next_u64_raw());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let xs = r.gaussian_vec(20_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(5);
        let p = r.permutation(20);
        let mut seen = vec![false; 20];
        for &x in &p {
            assert!(!seen[x]);
            seen[x] = true;
        }
    }
}
