//! Persistent work-stealing executor (ROADMAP: serving runtime).
//!
//! Every parallel fan-out in the crate used to pay a `std::thread::scope`
//! spawn per call. This module replaces that with one fixed pool of
//! long-lived workers shared by the whole process: each worker owns a
//! deque (LIFO local pop for cache locality, FIFO steal so thieves take
//! the oldest — largest-remaining — work), external threads submit
//! through a global injector, and idle workers park on a condvar.
//!
//! Determinism contract: the executor never decides *what* a task
//! computes or *where* its result lands — callers pre-assign output
//! slots and reduce in a fixed order on their own thread (see
//! [`crate::util::parallel::parallel_map`]). Steal order therefore
//! affects wall-clock only, never bits.
//!
//! The scoped API is [`Executor::join_all`]: the calling thread submits
//! a batch of borrowing closures, then *helps* — it runs queued tasks
//! (its own first, then steals) until the batch's latch reaches zero.
//! Help-while-waiting is what makes nested fan-outs (a coordinator
//! batch task that itself calls `parallel_map`) deadlock-free: a thread
//! blocked on a latch only sleeps when every pending task is already
//! running on some other thread.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Hardware thread count, queried from the OS once per process. The old
/// helper re-derived `available_parallelism()` on every fan-out; this is
/// the cached replacement every sizing decision now shares.
pub fn hw_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1)
    })
}

/// Safety net while a worker parks: bounds the cost of any wakeup race
/// to one re-scan (the sleep-lock handshake in `submit_batch` should
/// make lost wakeups impossible on its own).
const PARK_TIMEOUT: Duration = Duration::from_millis(100);

/// Poll interval while a thread waits on a latch with nothing to help
/// with: the latch condvar fires on completion, the timeout only lets
/// the helper notice tasks that arrived for *other* latches.
const HELP_POLL: Duration = Duration::from_micros(500);

/// A panicking task never unwinds while holding an executor lock (the
/// payload is caught inside the task wrapper), so a poisoned mutex here
/// only ever guards consistent state — recover and continue.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

type TaskFn = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// `(pool id, worker index)` when the current thread is a pool
    /// worker — routes nested submissions to the local deque.
    static WORKER: std::cell::Cell<Option<(u64, usize)>> =
        const { std::cell::Cell::new(None) };
}

fn current_worker(pool_id: u64) -> Option<usize> {
    WORKER.with(|w| match w.get() {
        Some((id, idx)) if id == pool_id => Some(idx),
        _ => None,
    })
}

/// Monotonic executor counters (process lifetime, never reset).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecutorStats {
    /// Pool size (long-lived worker threads).
    pub workers: usize,
    /// Tasks taken from another worker's deque (FIFO end).
    pub steals: u64,
    /// Times a worker went to sleep on the idle condvar.
    pub parks: u64,
    /// Tasks submitted through the global injector (i.e. from threads
    /// outside the pool; nested submissions go to the local deque).
    pub injector_pushes: u64,
    /// Total tasks executed (by workers and by helping callers).
    pub executed: u64,
}

#[derive(Default)]
struct Counters {
    steals: AtomicU64,
    parks: AtomicU64,
    injector_pushes: AtomicU64,
    executed: AtomicU64,
}

struct Sleep {
    sleepers: usize,
    shutdown: bool,
}

/// Completion latch for one `join_all` batch. Plays the role of the
/// `thread::scope` join: the submitting thread blocks (helping) until
/// `pending` reaches zero, which is what makes the borrowed closures
/// sound. The first panic payload is kept and re-thrown at the caller.
struct Latch {
    pending: AtomicUsize,
    state: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    cv: Condvar,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch {
            pending: AtomicUsize::new(count),
            state: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn record_panic(&self, payload: Box<dyn std::any::Any + Send>) {
        let mut slot = lock_recover(&self.state);
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn complete_one(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Take the lock so a waiter between its `pending` check and
            // its `wait_timeout` cannot miss this notification.
            let _guard = lock_recover(&self.state);
            self.cv.notify_all();
        }
    }

    fn take_panic(&self) -> Option<Box<dyn std::any::Any + Send>> {
        lock_recover(&self.state).take()
    }
}

struct Shared {
    pool_id: u64,
    injector: Mutex<VecDeque<TaskFn>>,
    deques: Vec<Mutex<VecDeque<TaskFn>>>,
    sleep: Mutex<Sleep>,
    wake: Condvar,
    counters: Counters,
}

impl Shared {
    /// Pop the next task: own deque back (LIFO), injector front, then
    /// steal the front (FIFO) of the other deques in index order.
    fn find_task(&self, own: Option<usize>) -> Option<TaskFn> {
        if let Some(idx) = own {
            if let Some(task) = lock_recover(&self.deques[idx]).pop_back() {
                return Some(task);
            }
        }
        if let Some(task) = lock_recover(&self.injector).pop_front() {
            return Some(task);
        }
        let n = self.deques.len();
        let start = own.map_or(0, |idx| idx + 1);
        for offset in 0..n {
            let victim = (start + offset) % n;
            if Some(victim) == own {
                continue;
            }
            if let Some(task) = lock_recover(&self.deques[victim]).pop_front() {
                self.counters.steals.fetch_add(1, Ordering::Relaxed);
                return Some(task);
            }
        }
        None
    }

    fn has_queued_work(&self) -> bool {
        if !lock_recover(&self.injector).is_empty() {
            return true;
        }
        self.deques
            .iter()
            .any(|deque| !lock_recover(deque).is_empty())
    }

    fn run_task(&self, task: TaskFn) {
        self.counters.executed.fetch_add(1, Ordering::Relaxed);
        task();
    }

    /// Queue a batch: onto the local deque when called from a pool
    /// worker (nested fan-out), through the injector otherwise. The
    /// sleep lock is taken *after* the queue push — a parker re-checks
    /// the queues under that same lock, so a push either lands before
    /// the re-check or observes `sleepers > 0` and notifies.
    fn submit_batch(&self, tasks: Vec<TaskFn>) {
        match current_worker(self.pool_id) {
            Some(idx) => {
                lock_recover(&self.deques[idx]).extend(tasks);
                self.notify_sleepers();
            }
            None => self.inject(tasks),
        }
    }

    /// Queue through the global injector unconditionally — even from a
    /// pool worker. Detached slot tasks re-submit themselves this way:
    /// the injector's FIFO gives round-robin fairness, where the local
    /// deque's LIFO would let a yielding slot immediately re-pop itself
    /// and starve other slots on a small pool.
    fn inject(&self, tasks: Vec<TaskFn>) {
        self.counters
            .injector_pushes
            .fetch_add(tasks.len() as u64, Ordering::Relaxed);
        lock_recover(&self.injector).extend(tasks);
        self.notify_sleepers();
    }

    fn notify_sleepers(&self) {
        let sleep = lock_recover(&self.sleep);
        if sleep.sleepers > 0 {
            self.wake.notify_all();
        }
    }

    /// Run tasks until `latch` completes; sleep on the latch condvar
    /// only when no task is runnable anywhere.
    fn help_until(&self, latch: &Latch) {
        let own = current_worker(self.pool_id);
        loop {
            if latch.pending.load(Ordering::Acquire) == 0 {
                return;
            }
            if let Some(task) = self.find_task(own) {
                self.run_task(task);
                continue;
            }
            let guard = lock_recover(&latch.state);
            if latch.pending.load(Ordering::Acquire) == 0 {
                return;
            }
            match latch.cv.wait_timeout(guard, HELP_POLL) {
                Ok((guard, _timeout)) => drop(guard),
                Err(poisoned) => drop(poisoned.into_inner()),
            }
        }
    }
}

fn worker_loop(shared: &Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set(Some((shared.pool_id, idx))));
    loop {
        if let Some(task) = shared.find_task(Some(idx)) {
            shared.run_task(task);
            continue;
        }
        let mut sleep = lock_recover(&shared.sleep);
        if sleep.shutdown {
            return;
        }
        if shared.has_queued_work() {
            // A task landed between our scan and taking the sleep lock.
            drop(sleep);
            continue;
        }
        sleep.sleepers += 1;
        shared.counters.parks.fetch_add(1, Ordering::Relaxed);
        let mut sleep = match shared.wake.wait_timeout(sleep, PARK_TIMEOUT) {
            Ok((guard, _timeout)) => guard,
            Err(poisoned) => poisoned.into_inner().0,
        };
        sleep.sleepers -= 1;
        if sleep.shutdown {
            return;
        }
    }
}

/// A fixed pool of persistent work-stealing workers. Most code uses the
/// process-wide [`global`] pool via
/// [`crate::util::parallel::parallel_map`]; tests construct private
/// pools of specific sizes to pin down determinism under stealing.
pub struct Executor {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn a pool of `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Executor {
        static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            pool_id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(Sleep {
                sleepers: 0,
                shutdown: false,
            }),
            wake: Condvar::new(),
            counters: Counters::default(),
        });
        let handles = (0..workers)
            .map(|idx| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("wsx-worker-{idx}"))
                    .spawn(move || worker_loop(&shared, idx))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor { shared, handles }
    }

    /// Pool size.
    pub fn workers(&self) -> usize {
        self.shared.deques.len()
    }

    /// Run every closure in `tasks` to completion before returning; the
    /// calling thread helps execute them. A single task runs inline
    /// with zero queueing. If any task panics, the first payload is
    /// re-thrown here after all tasks finish — the same contract as
    /// `std::thread::scope`.
    pub fn join_all<'scope, F>(&self, tasks: Vec<F>)
    where
        F: FnOnce() + Send + 'scope,
    {
        match tasks.len() {
            0 => return,
            1 => {
                for task in tasks {
                    task();
                }
                return;
            }
            _ => {}
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        let mut queued: Vec<TaskFn> = Vec::with_capacity(tasks.len());
        for task in tasks {
            let latch = Arc::clone(&latch);
            let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(task)) {
                    latch.record_panic(payload);
                }
                latch.complete_one();
            });
            // SAFETY: `join_all` blocks in `help_until` until the latch
            // reaches zero, i.e. until every wrapped closure has been
            // consumed — so no borrow inside `task` is used after
            // 'scope ends. This is the `std::thread::scope` argument
            // with the latch playing the role of the scope join; the
            // transmute only erases the lifetime, the layout of the
            // boxed trait object is unchanged.
            let wrapped: TaskFn = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, TaskFn>(wrapped)
            };
            queued.push(wrapped);
        }
        self.shared.submit_batch(queued);
        self.shared.help_until(&latch);
        if let Some(payload) = latch.take_panic() {
            panic::resume_unwind(payload);
        }
    }

    /// Queue a detached `'static` task and return immediately — the
    /// fire-and-forget complement of [`Executor::join_all`], used for
    /// long-lived slot tasks (the coordinator's worker slots re-submit
    /// themselves through this to yield their thread between batches).
    /// A panic inside `f` is caught and dropped so it can never unwind
    /// a pool worker; callers that care about panics must catch and
    /// report them inside `f` (the coordinator's slot wrapper does).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'static,
    {
        self.shared.inject(vec![Box::new(move || {
            let _ = panic::catch_unwind(AssertUnwindSafe(f));
        })]);
    }

    /// Snapshot of the monotonic counters.
    pub fn stats(&self) -> ExecutorStats {
        let c = &self.shared.counters;
        ExecutorStats {
            workers: self.shared.deques.len(),
            steals: c.steals.load(Ordering::Relaxed),
            parks: c.parks.load(Ordering::Relaxed),
            injector_pushes: c.injector_pushes.load(Ordering::Relaxed),
            executed: c.executed.load(Ordering::Relaxed),
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut sleep = lock_recover(&self.shared.sleep);
            sleep.shutdown = true;
        }
        self.shared.wake.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

static GLOBAL: OnceLock<Executor> = OnceLock::new();

/// The process-wide pool, sized to [`hw_threads`] and created on first
/// use. Never torn down; its workers park when idle.
pub fn global() -> &'static Executor {
    GLOBAL.get_or_init(|| Executor::new(hw_threads()))
}

/// Counters of the [`global`] pool. Reading stats does not spin the
/// pool up — before the first fan-out it reports zeros.
pub fn global_stats() -> ExecutorStats {
    GLOBAL.get().map(Executor::stats).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn join_all_runs_every_task() {
        let exec = Executor::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let counter = &counter;
                move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        exec.join_all(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn borrows_are_sound_and_slots_disjoint() {
        let exec = Executor::new(3);
        let mut slots = vec![0usize; 40];
        let tasks: Vec<_> = slots
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                move || {
                    *slot = i * i;
                }
            })
            .collect();
        exec.join_all(tasks);
        for (i, slot) in slots.iter().enumerate() {
            assert_eq!(*slot, i * i);
        }
    }

    #[test]
    fn single_task_runs_inline_without_queueing() {
        let exec = Executor::new(2);
        let before = exec.stats().executed;
        let ran = AtomicUsize::new(0);
        exec.join_all(vec![|| {
            ran.fetch_add(1, Ordering::Relaxed);
        }]);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        assert_eq!(exec.stats().executed, before);
    }

    #[test]
    fn panic_propagates_after_all_tasks_finish() {
        let exec = Executor::new(2);
        let finished = Arc::new(AtomicUsize::new(0));
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let finished = Arc::clone(&finished);
            let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..8)
                .map(|i| {
                    let finished = Arc::clone(&finished);
                    let task: Box<dyn FnOnce() + Send> = Box::new(move || {
                        if i == 3 {
                            panic!("task boom");
                        }
                        finished.fetch_add(1, Ordering::Relaxed);
                    });
                    task
                })
                .collect();
            exec.join_all(tasks);
        }));
        assert!(result.is_err(), "panic must re-throw at the caller");
        assert_eq!(finished.load(Ordering::Relaxed), 7);
        // The pool survives a panicking batch.
        let counter = AtomicUsize::new(0);
        exec.join_all(
            (0..4)
                .map(|_| {
                    let counter = &counter;
                    move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect(),
        );
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn nested_join_all_from_worker_does_not_deadlock() {
        let exec = Arc::new(Executor::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..4)
            .map(|_| {
                let exec = Arc::clone(&exec);
                let total = Arc::clone(&total);
                move || {
                    let inner: Vec<_> = (0..8)
                        .map(|_| {
                            let total = Arc::clone(&total);
                            move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            }
                        })
                        .collect();
                    exec.join_all(inner);
                }
            })
            .collect();
        exec.join_all(tasks);
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn counters_are_monotone_and_injector_counts_external_pushes() {
        let exec = Executor::new(2);
        let before = exec.stats();
        exec.join_all(
            (0..16)
                .map(|_| move || std::thread::yield_now())
                .collect::<Vec<_>>(),
        );
        let after = exec.stats();
        assert_eq!(after.workers, 2);
        assert!(after.executed >= before.executed + 16);
        assert!(after.injector_pushes >= before.injector_pushes + 16);
        assert!(after.steals >= before.steals);
        assert!(after.parks >= before.parks);
    }

    #[test]
    fn spawn_runs_detached_tasks_and_survives_panics() {
        let exec = Executor::new(2);
        exec.spawn(|| panic!("detached boom"));
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = Arc::clone(&done);
        exec.spawn(move || {
            d2.fetch_add(1, Ordering::Relaxed);
        });
        // The panicking task must not take a pool worker down with it.
        for _ in 0..5000 {
            if done.load(Ordering::Relaxed) == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(done.load(Ordering::Relaxed), 1, "detached task never ran");
    }

    #[test]
    fn hw_threads_is_cached_and_positive() {
        let a = hw_threads();
        let b = hw_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }

    #[test]
    fn same_inputs_same_outputs_across_pool_sizes() {
        // Bitwise determinism: the executor only runs slot-writing
        // closures, so pool size and steal order cannot change results.
        let reference: Vec<f64> = (0..33).map(|i| (i as f64).sin()).collect();
        for workers in [1, 2, hw_threads()] {
            let exec = Executor::new(workers);
            let mut out = vec![0.0f64; 33];
            let tasks: Vec<_> = out
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    move || {
                        *slot = (i as f64).sin();
                    }
                })
                .collect();
            exec.join_all(tasks);
            assert_eq!(out, reference, "pool size {workers} changed bits");
        }
    }
}
