//! equidiag launcher: train, serve and inspect equivariant networks from a
//! config file.
//!
//! ```text
//! equidiag train  [--config cfg.toml] [--steps N]
//! equidiag serve  [--config cfg.toml] [--artifact path.hlo.txt] [--requests N]
//! equidiag bench  [--config cfg.toml] [--n N --k K --l L]
//! equidiag basis  --group G --n N --k K --l L
//! equidiag info
//! ```
//!
//! (Hand-rolled arg parsing — `clap` is not in the offline registry.)

use equidiag::config::AppConfig;
use equidiag::coordinator::{Coordinator, ModelKind};
use equidiag::diagram::{
    all_brauer_diagrams, all_partition_diagrams, bell_bounded, double_factorial,
};
use equidiag::fastmult::{matrix_mult, Group, MultPlan};
use equidiag::functor::naive_apply;
use equidiag::layer::Init;
use equidiag::nn::{train, Adam, EquivariantNet, Optimizer, Sgd, TrainConfig};
use equidiag::runtime::{HloService, PjrtRuntime};
use equidiag::tensor::Tensor;
use equidiag::util::{bench_median, Rng, Table};
use equidiag::Result;
use std::collections::HashMap;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "bench" => cmd_bench(&flags),
        "basis" => cmd_basis(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "equidiag — diagrammatic fast multiplication for group equivariant networks

USAGE:
  equidiag train  [--config cfg.toml] [--steps N] [--save ckpt]
  equidiag serve  [--config cfg.toml] [--load ckpt] [--artifact path.hlo.txt] [--requests N]
  equidiag bench  [--config cfg.toml] [--group G] [--n N] [--k K] [--l L]
  equidiag basis  [--group sn|on|son|spn] [--n N] [--k K] [--l L]
  equidiag info"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            eprintln!("ignoring stray argument '{a}'");
            i += 1;
        }
    }
    m
}

fn load_config(flags: &HashMap<String, String>) -> Result<AppConfig> {
    match flags.get("config") {
        Some(path) => Ok(AppConfig::from_file(path)?),
        None => Ok(AppConfig::default()),
    }
}

fn flag_usize(flags: &HashMap<String, String>, key: &str) -> Option<usize> {
    flags.get(key).and_then(|v| v.parse().ok())
}

/// Train an equivariant network on the built-in synthetic regression task
/// (an invariant contraction target — see `synthetic_target`).
fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = load_config(flags)?;
    if let Some(steps) = flag_usize(flags, "steps") {
        cfg.training.steps = steps;
    }
    let net_cfg = &cfg.network;
    let mut rng = Rng::new(net_cfg.seed);
    let init = if net_cfg.init_std > 0.0 {
        Init::Normal(net_cfg.init_std)
    } else {
        Init::ScaledNormal
    };
    let mut net = EquivariantNet::new(
        net_cfg.group,
        net_cfg.n,
        &net_cfg.orders,
        net_cfg.activation,
        init,
        &mut rng,
    )?;
    println!(
        "training {} network over R^{} with orders {:?} — {} parameters",
        net_cfg.group,
        net_cfg.n,
        net_cfg.orders,
        net.num_params()
    );
    let kin = net_cfg.orders[0];
    let lout = *net_cfg.orders.last().unwrap();
    let data: Vec<(Tensor, Tensor)> = (0..128)
        .map(|_| {
            let x = Tensor::random(net_cfg.n, kin, &mut rng);
            let y = synthetic_target(&x, lout);
            (x, y)
        })
        .collect();
    let mut opt: Box<dyn Optimizer> = if cfg.training.optimizer == "sgd" {
        Box::new(Sgd::new(cfg.training.lr, cfg.training.momentum))
    } else {
        Box::new(Adam::new(cfg.training.lr))
    };
    let report = train(
        &mut net,
        &data,
        &mut *opt,
        &TrainConfig {
            steps: cfg.training.steps,
            batch_size: cfg.training.batch_size,
            log_every: cfg.training.log_every,
            // The CLI wants progress lines; library embedders get the
            // silent `logged` vec instead.
            verbose: true,
            ..TrainConfig::default()
        },
    )?;
    println!(
        "final loss (mean of last 20 steps): {:.6}",
        report.final_loss(20)
    );
    if let Some(path) = flags.get("save") {
        equidiag::nn::save_checkpoint(&net, std::path::Path::new(path))?;
        println!("saved checkpoint to {path}");
    }
    Ok(())
}

/// A simple invariant/equivariant synthetic target for smoke training.
fn synthetic_target(x: &Tensor, lout: usize) -> Tensor {
    let mut t = if x.order >= 2 {
        x.trace_trailing_pair()
    } else {
        x.clone()
    };
    while t.order > lout {
        t = t.contract_trailing_diagonal(1);
    }
    while t.order < lout {
        t = t.broadcast_leading(1);
    }
    t
}

/// Serve the configured network (and optionally an HLO artifact) through
/// the coordinator; drive it with a synthetic client and print metrics.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let net_cfg = &cfg.network;
    let mut rng = Rng::new(net_cfg.seed);
    let mut net = EquivariantNet::new(
        net_cfg.group,
        net_cfg.n,
        &net_cfg.orders,
        net_cfg.activation,
        Init::ScaledNormal,
        &mut rng,
    )?;
    if let Some(path) = flags.get("load") {
        equidiag::nn::load_checkpoint(&mut net, std::path::Path::new(path))?;
        println!("loaded checkpoint from {path}");
    }
    let mut coord = Coordinator::new(cfg.server.clone());
    coord.set_brownout_f32(cfg.model.brownout_f32);
    println!("serving precision: {}", cfg.model.precision);
    println!(
        "integrity: numeric guard {}  shadow verification {}‰  watchdog factor {}  \
         arena budget {}",
        if cfg.server.numeric_guard { "on" } else { "off" },
        cfg.server.verify_per_mille,
        if cfg.server.watchdog_factor > 0.0 {
            format!("{:.1}x p99", cfg.server.watchdog_factor)
        } else {
            "off".to_string()
        },
        match cfg.server.arena_budget_bytes {
            Some(b) => format!(
                "{b} bytes (brownout may narrow to f32: {})",
                if cfg.model.brownout_f32 { "yes" } else { "no" }
            ),
            None => "off".to_string(),
        }
    );
    // Fix the tiled-walk cache budget before any schedule compiles: the
    // plan cache keys schedules by the resolved budget, so setting it
    // here means every route serves tiling plans sized to it.
    equidiag::fastmult::set_tile_budget(cfg.model.tile_bytes);
    match equidiag::fastmult::resolve_tile_budget() {
        0 => println!("tile budget: off (tile_bytes = 0)"),
        b => println!(
            "tile budget: {b} bytes ({})",
            if cfg.model.tile_bytes.is_some() {
                "from config"
            } else {
                "auto-detected cache size"
            }
        ),
    }
    coord.register(
        "net",
        ModelKind::net_with_precision(net, cfg.model.precision),
    );
    let artifact = flags
        .get("artifact")
        .cloned()
        .or_else(|| cfg.artifact.clone());
    let mut routes = vec!["net".to_string()];
    if let Some(path) = artifact {
        let service = HloService::spawn(&path)?;
        println!("loaded artifact '{}' onto its PJRT owner thread", service.name());
        coord.register("hlo", ModelKind::hlo(service));
        routes.push("hlo".to_string());
    }
    let handle = coord.start();
    let requests = flag_usize(flags, "requests").unwrap_or(200);
    println!("serving {requests} synthetic requests on routes {routes:?} …");
    let kin = net_cfg.orders[0];
    for i in 0..requests {
        let route = &routes[i % routes.len()];
        let v = Tensor::random(net_cfg.n, kin, &mut rng);
        handle.infer(route, v)?;
    }
    let snap = handle.metrics();
    println!(
        "completed {} / failed {} / rejected {}  batches {}  mean batch {:.2}  \
         mean latency {:.1} us  max latency {:.1} us",
        snap.completed,
        snap.failed,
        snap.rejected,
        snap.batches,
        snap.mean_batch_size,
        snap.mean_latency_s * 1e6,
        snap.max_latency_s * 1e6
    );
    println!(
        "latency p50 {:.1} us  p95 {:.1} us  p99 {:.1} us  \
         batch exec p50 {:.1} us  p99 {:.1} us",
        snap.p50_latency_s * 1e6,
        snap.p95_latency_s * 1e6,
        snap.p99_latency_s * 1e6,
        snap.p50_batch_exec_s * 1e6,
        snap.p99_batch_exec_s * 1e6
    );
    println!(
        "robustness: shed {} expired / {} admission  worker restarts {}  \
         batch panics caught {}",
        snap.shed_expired, snap.shed_admission, snap.worker_restarts, snap.batch_panics
    );
    println!(
        "integrity: numeric faults {}  watchdog kills {}  shadow verifications {} \
         ({} mismatches, {} quarantines, {} recompiles)  degraded models {}  \
         brownout {} ({} engagements / {} recoveries)",
        snap.numeric_faults,
        snap.watchdog_kills,
        snap.shadow_verifications,
        snap.integrity_mismatches,
        snap.schedule_quarantines,
        snap.schedule_recompiles,
        snap.degraded_models,
        snap.brownout_state_name(),
        snap.brownout_engagements,
        snap.brownout_recoveries
    );
    println!(
        "batch execs {}  mean batch exec {:.1} us  plan cache {:.1}% hit ({} hits / {} misses)",
        snap.batch_execs,
        snap.mean_batch_exec_s * 1e6,
        snap.plan_cache_hit_rate * 100.0,
        snap.plan_cache_hits,
        snap.plan_cache_misses
    );
    println!(
        "planner: {} nodes / {} classes compiled (est {} flops, {} bytes per forward)  \
         executed nodes {}  scatter passes {}",
        snap.schedule_nodes,
        snap.schedule_classes,
        snap.schedule_estimated_flops,
        snap.schedule_estimated_bytes,
        snap.executed_nodes,
        snap.scatter_passes
    );
    println!(
        "kernels: measured bytes moved {}  index scratch {} allocs / {} reuses",
        snap.measured_bytes_moved, snap.arena_index_allocations, snap.arena_index_reuses
    );
    println!(
        "arena: peak resident {} bytes  tiled chains walked {}",
        snap.arena_peak_bytes, snap.tiled_chains
    );
    println!(
        "executor: {} workers  {} tasks  {} steals  {} parks  {} injector pushes",
        snap.executor_workers,
        snap.executor_executed,
        snap.executor_steals,
        snap.executor_parks,
        snap.executor_injector_pushes
    );
    let shard_rates = snap
        .plan_cache_shard_hit_rates
        .iter()
        .map(|r| format!("{:.0}%", r * 100.0))
        .collect::<Vec<_>>()
        .join(" ");
    println!(
        "plan cache shards: {} (per-shard hit rates: {})  evictions {} plans / {} schedules",
        snap.plan_cache_shards,
        shard_rates,
        snap.plan_cache_evictions,
        snap.schedule_cache_evictions
    );
    if snap.target_p95_s > 0.0 {
        println!(
            "adaptive window: {:.1} us (target p95 {:.1} ms, live p95 {:.2} ms)",
            snap.batch_window_s * 1e6,
            snap.target_p95_s * 1e3,
            snap.p95_latency_s * 1e3
        );
    }
    handle.shutdown();
    Ok(())
}

/// Quick fast-vs-naïve comparison at one (group, n, k, l).
fn cmd_bench(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = load_config(flags)?;
    let group = match flags.get("group") {
        Some(g) => Group::parse(g)?,
        None => cfg.network.group,
    };
    let n = flag_usize(flags, "n").unwrap_or(cfg.network.n);
    let k = flag_usize(flags, "k").unwrap_or(2);
    let l = flag_usize(flags, "l").unwrap_or(2);
    let mut rng = Rng::new(7);
    let diagram = match group {
        Group::Symmetric => equidiag::diagram::Diagram::random_partition(l, k, &mut rng),
        _ => equidiag::diagram::Diagram::random_brauer(l, k, &mut rng)?,
    };
    println!("group {group}, n = {n}: diagram {diagram}");
    let v = Tensor::random(n, k, &mut rng);
    let plan = MultPlan::new(group, &diagram, n)?;
    let fast = bench_median(Duration::from_millis(300), || {
        let _ = plan.apply(&v).unwrap();
    });
    let naive = bench_median(Duration::from_millis(300), || {
        let _ = naive_apply(group, &diagram, &v).unwrap();
    });
    let check_fast = matrix_mult(group, &diagram, &v)?;
    let check_naive = naive_apply(group, &diagram, &v)?;
    let mut t = Table::new(vec!["method", "median", "speedup"]);
    t.row(vec!["naive".to_string(), naive.pretty(), "1.0x".to_string()]);
    t.row(vec![
        "fast (Algorithm 1)".to_string(),
        fast.pretty(),
        format!("{:.1}x", naive.median_s / fast.median_s),
    ]);
    t.print();
    println!(
        "results agree to {:.2e}",
        check_fast.max_abs_diff(&check_naive)
    );
    Ok(())
}

/// Print spanning-set sizes (Theorems 5/7/9/11) for a layer shape.
fn cmd_basis(flags: &HashMap<String, String>) -> Result<()> {
    let group = match flags.get("group") {
        Some(g) => Group::parse(g)?,
        None => Group::Symmetric,
    };
    let n = flag_usize(flags, "n").unwrap_or(5);
    let k = flag_usize(flags, "k").unwrap_or(2);
    let l = flag_usize(flags, "l").unwrap_or(2);
    let count = match group {
        Group::Symmetric => all_partition_diagrams(l, k, Some(n)).len() as u128,
        _ => all_brauer_diagrams(l, k).len() as u128,
    };
    println!("group {group}, n={n}, k={k}, l={l}");
    println!("spanning-set size: {count}");
    match group {
        Group::Symmetric => println!("closed form B(l+k, n) = {}", bell_bounded(l + k, n)),
        _ => println!(
            "closed form (l+k-1)!! = {}",
            if (l + k) % 2 == 0 {
                double_factorial((l + k) as isize - 1)
            } else {
                0
            }
        ),
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "equidiag {} — Pearce-Crump & Knottenbelt (2024) reproduction",
        env!("CARGO_PKG_VERSION")
    );
    match PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT platform: unavailable ({e})"),
    }
    println!("groups: S_n, O(n), SO(n), Sp(n)");
    println!(
        "complexities: naive O(n^(l+k)); fast O(n^k) [S_n], O(n^(k-1)) [O(n), Sp(n)], \
         O(n^(k-(n-s))(n! + n^(s-1))) [SO(n)]"
    );
    Ok(())
}
