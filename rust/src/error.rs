//! Error types for the equidiag library.
//!
//! Hand-implemented `Display`/`Error` (the offline build environment has no
//! `thiserror`), with the same variant set and message formats.

use std::fmt;

/// Errors produced by diagram construction, the fast multiplication
/// algorithm, layers, the coordinator and the PJRT runtime.
#[derive(Debug)]
pub enum Error {
    /// A set partition did not cover `[l+k]` exactly once.
    InvalidPartition {
        /// Size of the vertex set the partition should cover.
        expected: usize,
        /// What went wrong.
        reason: String,
    },

    /// A diagram was used with a group it is not valid for
    /// (e.g. a general partition diagram fed to the O(n) path).
    InvalidDiagramForGroup {
        /// Display name of the group.
        group: String,
        /// What went wrong.
        reason: String,
    },

    /// Tensor shape mismatch.
    ShapeMismatch {
        /// What the callee needed.
        expected: String,
        /// What it was given.
        got: String,
    },

    /// Dimension constraint violated (e.g. Sp(n) needs even n,
    /// an (l+k)\n-diagram needs l+k-n even and non-negative).
    DimensionConstraint(String),

    /// A batched call failed on one item; carries which item and why, so
    /// callers fanning a batch out (and the coordinator reporting per-item
    /// results) keep the failing index.
    BatchItem {
        /// Zero-based position of the failing item in the batch.
        index: usize,
        /// The underlying failure.
        source: Box<Error>,
    },

    /// Configuration file / CLI errors.
    Config(String),

    /// Coordinator / serving errors.
    Coordinator(String),

    /// A request's deadline passed before a worker produced its response.
    /// Returned by the serving path at any of its shed points (batcher,
    /// worker pre-execution, client-side bounded wait) — see
    /// `docs/serving_robustness.md`.
    DeadlineExceeded,

    /// A request named a route no model is registered under.
    ModelNotFound(String),

    /// A request was malformed at the door (e.g. its tensor shape does not
    /// match the registered model), rejected before entering the queue.
    BadRequest(String),

    /// Per-model admission control shed the request: the route already had
    /// `max_inflight_per_model` requests in flight.
    Overloaded {
        /// The route that was at capacity.
        model: String,
    },

    /// Model execution panicked; the panic was caught at the worker so the
    /// client still gets a typed terminal outcome instead of a hang.
    WorkerPanic(String),

    /// A numeric integrity check failed: a non-finite value escaped a
    /// kernel (the `numeric_guard` canary), the training loss went NaN, or
    /// a sampled shadow verification disagreed with the per-term reference
    /// path.
    NumericFault(String),

    /// The hung-batch watchdog shed this request: the batch it rode in
    /// exceeded the watchdog threshold and its worker slot was respawned.
    BatchStuck,

    /// PJRT runtime errors.
    Runtime(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidPartition { expected, reason } => {
                write!(f, "invalid set partition over [{expected}]: {reason}")
            }
            Error::InvalidDiagramForGroup { group, reason } => {
                write!(f, "diagram not valid for group {group}: {reason}")
            }
            Error::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::DimensionConstraint(msg) => {
                write!(f, "dimension constraint violated: {msg}")
            }
            Error::BatchItem { index, source } => {
                write!(f, "batch item {index}: {source}")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::DeadlineExceeded => write!(f, "deadline exceeded"),
            Error::ModelNotFound(name) => write!(f, "model not found: '{name}'"),
            Error::BadRequest(msg) => write!(f, "bad request: {msg}"),
            Error::Overloaded { model } => {
                write!(f, "overloaded: model '{model}' is at its inflight limit")
            }
            Error::WorkerPanic(msg) => write!(f, "worker panicked during execution: {msg}"),
            Error::NumericFault(msg) => write!(f, "numeric fault: {msg}"),
            Error::BatchStuck => write!(f, "batch stuck: shed by the hung-batch watchdog"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::BatchItem { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Config(format!("io error: {e}"))
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = Error::InvalidPartition {
            expected: 4,
            reason: "empty block".into(),
        };
        assert_eq!(
            e.to_string(),
            "invalid set partition over [4]: empty block"
        );
        let e = Error::ShapeMismatch {
            expected: "a".into(),
            got: "b".into(),
        };
        assert_eq!(e.to_string(), "shape mismatch: expected a, got b");
        assert_eq!(
            Error::Config("x".into()).to_string(),
            "config error: x"
        );
        assert_eq!(
            Error::Coordinator("x".into()).to_string(),
            "coordinator error: x"
        );
        assert_eq!(Error::Runtime("x".into()).to_string(), "runtime error: x");
        assert_eq!(Error::DeadlineExceeded.to_string(), "deadline exceeded");
        assert_eq!(
            Error::ModelNotFound("gnn".into()).to_string(),
            "model not found: 'gnn'"
        );
        assert_eq!(
            Error::BadRequest("x".into()).to_string(),
            "bad request: x"
        );
        assert_eq!(
            Error::Overloaded { model: "gnn".into() }.to_string(),
            "overloaded: model 'gnn' is at its inflight limit"
        );
        assert_eq!(
            Error::WorkerPanic("boom".into()).to_string(),
            "worker panicked during execution: boom"
        );
        assert_eq!(
            Error::NumericFault("non-finite output".into()).to_string(),
            "numeric fault: non-finite output"
        );
        assert_eq!(
            Error::BatchStuck.to_string(),
            "batch stuck: shed by the hung-batch watchdog"
        );
        assert_eq!(
            Error::DimensionConstraint("x".into()).to_string(),
            "dimension constraint violated: x"
        );
        assert_eq!(
            Error::InvalidDiagramForGroup {
                group: "O(n)".into(),
                reason: "odd block".into()
            }
            .to_string(),
            "diagram not valid for group O(n): odd block"
        );
        assert_eq!(
            Error::BatchItem {
                index: 3,
                source: Box::new(Error::Coordinator("x".into()))
            }
            .to_string(),
            "batch item 3: coordinator error: x"
        );
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
