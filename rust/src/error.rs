//! Error types for the equidiag library.

use thiserror::Error;

/// Errors produced by diagram construction, the fast multiplication
/// algorithm, layers, the coordinator and the PJRT runtime.
#[derive(Debug, Error)]
pub enum Error {
    /// A set partition did not cover `[l+k]` exactly once.
    #[error("invalid set partition over [{expected}]: {reason}")]
    InvalidPartition { expected: usize, reason: String },

    /// A diagram was used with a group it is not valid for
    /// (e.g. a general partition diagram fed to the O(n) path).
    #[error("diagram not valid for group {group}: {reason}")]
    InvalidDiagramForGroup { group: String, reason: String },

    /// Tensor shape mismatch.
    #[error("shape mismatch: expected {expected}, got {got}")]
    ShapeMismatch { expected: String, got: String },

    /// Dimension constraint violated (e.g. Sp(n) needs even n,
    /// an (l+k)\n-diagram needs l+k-n even and non-negative).
    #[error("dimension constraint violated: {0}")]
    DimensionConstraint(String),

    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Coordinator / serving errors.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// PJRT runtime errors (wraps the xla crate's error).
    #[error("runtime error: {0}")]
    Runtime(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
