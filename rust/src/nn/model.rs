//! Sequential equivariant network: alternating equivariant linear layers
//! and pointwise activations, with manual reverse-mode differentiation.

use crate::error::{Error, Result};
use crate::fastmult::{Group, ScheduleStats};
use crate::layer::{BatchInput, BatchOutput, EquivariantLinear, Init, LayerGrads};
use crate::nn::activation::Activation;
use crate::tensor::{BatchTensorOf, Scalar, TensorOf};
use crate::util::parallel::{max_threads, parallel_map, span_len};
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

static FUSED_BATCHES: AtomicU64 = AtomicU64::new(0);
static FUSED_ITEMS: AtomicU64 = AtomicU64::new(0);

/// Process-wide counters for the batched serving path: how many whole
/// batches (and items) went through the fused batched walk inside
/// [`EquivariantNet::apply`] — the packed `[B, n^k]` path for multi-item
/// batches, the DAG-subtree fan-out for single-item ones — as opposed to
/// the per-item error-isolation fallback. Reported by the coordinator
/// metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusedBatchStats {
    /// Batches executed through the fused batched path.
    pub batches: u64,
    /// Items those batches contained.
    pub items: u64,
}

impl FusedBatchStats {
    /// Mean items per fused batch (0 when none ran).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }
}

/// Snapshot of the process-wide fused-batch counters.
pub fn fused_batch_stats() -> FusedBatchStats {
    FusedBatchStats {
        batches: FUSED_BATCHES.load(Ordering::Relaxed),
        items: FUSED_ITEMS.load(Ordering::Relaxed),
    }
}

/// A stack of equivariant linear layers with activations between them.
///
/// Orders flow `orders[0] → orders[1] → … → orders[L]`; layer `i` maps
/// `(R^n)^{⊗orders[i]} → (R^n)^{⊗orders[i+1]}`.
#[derive(Debug, Clone)]
pub struct EquivariantNet {
    group: Group,
    n: usize,
    /// The linear layers.
    pub layers: Vec<EquivariantLinear>,
    /// Activation after each layer (same length as `layers`; the last is
    /// typically `Identity`).
    pub activations: Vec<Activation>,
}

/// Per-layer gradient buffers for one backward pass.
#[derive(Debug, Clone)]
pub struct NetGrads {
    /// One `LayerGrads` per linear layer.
    pub layers: Vec<LayerGrads>,
}

impl NetGrads {
    /// Accumulate another gradient set (for minibatch averaging).
    pub fn add(&mut self, other: &NetGrads) {
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            for (x, y) in a.coeffs.iter_mut().zip(&b.coeffs) {
                *x += y;
            }
            for (x, y) in a.bias_coeffs.iter_mut().zip(&b.bias_coeffs) {
                *x += y;
            }
        }
    }

    /// Scale all gradients (e.g. by 1/batch).
    pub fn scale(&mut self, s: f64) {
        for g in &mut self.layers {
            for x in &mut g.coeffs {
                *x *= s;
            }
            for x in &mut g.bias_coeffs {
                *x *= s;
            }
        }
    }
}

/// Backprop intermediates from [`EquivariantNet::apply_trace`], shaped
/// like the input that produced them. Feed back into
/// [`EquivariantNet::apply_grad`] together with an output gradient in the
/// matching packaging.
#[derive(Debug, Clone)]
pub enum NetTrace<S: Scalar> {
    /// Per-layer `(input, pre-activation)` pairs for one item.
    Single(Vec<(TensorOf<S>, TensorOf<S>)>),
    /// One per-layer trace per batch item, in order.
    Batch(Vec<Vec<(TensorOf<S>, TensorOf<S>)>>),
    /// Per-layer `(input batch, pre-activation batch)` pairs for a packed
    /// batch.
    Packed(Vec<(BatchTensorOf<S>, BatchTensorOf<S>)>),
}

impl<S: Scalar> NetTrace<S> {
    /// Short name of the packaging, for shape-mismatch error messages.
    pub(crate) fn kind(&self) -> &'static str {
        match self {
            NetTrace::Single(_) => "single",
            NetTrace::Batch(_) => "batch",
            NetTrace::Packed(_) => "packed",
        }
    }
}

impl EquivariantNet {
    /// Build a network with the given tensor orders and one activation per
    /// layer (the final activation is forced to `Identity` if `activations`
    /// is shorter than the layer count).
    pub fn new(
        group: Group,
        n: usize,
        orders: &[usize],
        hidden_activation: Activation,
        init: Init,
        rng: &mut Rng,
    ) -> Result<Self> {
        assert!(orders.len() >= 2, "need at least input and output orders");
        let mut layers = Vec::new();
        let mut activations = Vec::new();
        for w in orders.windows(2) {
            layers.push(EquivariantLinear::new(group, n, w[0], w[1], init, rng)?);
            activations.push(hidden_activation);
        }
        // Output layer: no nonlinearity.
        *activations.last_mut().unwrap() = Activation::Identity;
        Ok(EquivariantNet {
            group,
            n,
            layers,
            activations,
        })
    }

    /// Group of the network.
    pub fn group(&self) -> Group {
        self.group
    }

    /// Representation dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Tensor order the first layer expects (`orders[0]`): together with
    /// [`Self::n`] this is the exact input shape, which the serving door
    /// validates before admitting a request.
    pub fn input_order(&self) -> usize {
        self.layers
            .first()
            .map(EquivariantLinear::k)
            .unwrap_or(0)
    }

    /// Total learnable parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// Aggregate folded-schedule statistics over every layer: interior ops
    /// shared by global CSE, scatter passes saved by λ-class folding
    /// (`classes` vs `terms`), and the cost model's flops/bytes estimate of
    /// one full forward pass across the whole network (reported by the
    /// benches and the serving metrics).
    pub fn schedule_stats(&self) -> ScheduleStats {
        let mut total = ScheduleStats::default();
        for layer in &self.layers {
            total.merge(&layer.schedule_stats());
        }
        total
    }

    /// Unified forward entry point: accepts any [`BatchInput`] packaging —
    /// a single tensor, a slice of owned or borrowed tensors, or an
    /// already-packed `[B, n^k]` batch — and returns a [`BatchOutput`]
    /// shaped like the input. Replaces the `forward`/`forward_batch`/
    /// `forward_batch_refs`/`forward_batched` method family.
    pub fn apply<'a, S: Scalar>(
        &self,
        input: impl Into<BatchInput<'a, S>>,
    ) -> Result<BatchOutput<S>> {
        match input.into() {
            BatchInput::Single(v) => Ok(BatchOutput::Single(self.forward_one(v)?)),
            BatchInput::Slice(vs) => {
                let refs: Vec<&TensorOf<S>> = vs.iter().collect();
                Ok(BatchOutput::Batch(self.forward_refs_core(&refs)?))
            }
            BatchInput::Refs(vs) => Ok(BatchOutput::Batch(self.forward_refs_core(vs)?)),
            BatchInput::Packed(vb) => Ok(BatchOutput::Packed(self.forward_packed_core(vb)?)),
        }
    }

    /// Forward one tensor. Use [`EquivariantNet::apply`] instead.
    #[deprecated(note = "use `apply` with a single tensor instead")]
    pub fn forward<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        self.forward_one(v)
    }

    /// Forward a batch of owned tensors. Use [`EquivariantNet::apply`]
    /// instead.
    #[deprecated(note = "use `apply` with a slice of tensors instead")]
    pub fn forward_batch<S: Scalar>(&self, inputs: &[TensorOf<S>]) -> Result<Vec<TensorOf<S>>> {
        let refs: Vec<&TensorOf<S>> = inputs.iter().collect();
        self.forward_refs_core(&refs)
    }

    /// Forward a batch of borrowed tensors. Use [`EquivariantNet::apply`]
    /// instead.
    #[deprecated(note = "use `apply` with a slice of tensor refs instead")]
    pub fn forward_batch_refs<S: Scalar>(
        &self,
        inputs: &[&TensorOf<S>],
    ) -> Result<Vec<TensorOf<S>>> {
        self.forward_refs_core(inputs)
    }

    /// Forward a packed batch. Use [`EquivariantNet::apply`] instead.
    #[deprecated(note = "use `apply` with a packed batch instead")]
    pub fn forward_batched<S: Scalar>(&self, v: &BatchTensorOf<S>) -> Result<BatchTensorOf<S>> {
        self.forward_packed_core(v)
    }

    /// Forward pass over one tensor.
    pub(crate) fn forward_one<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        let mut x = v.clone();
        for (layer, act) in self.layers.iter().zip(&self.activations) {
            x = act.forward(&layer.forward_one(&x)?);
        }
        Ok(x)
    }

    /// Reference forward: every layer runs its per-term path
    /// ([`EquivariantLinear::forward_per_term`], one `MultPlan` apply per
    /// spanning term — no schedule fusion, no cached `LayerSchedule`).
    /// This is the integrity oracle the shadow verifier compares the fused
    /// serving path against: it matches [`EquivariantNet::apply`] to
    /// rounding error (folded classes reassociate additions), and it
    /// shares *nothing* with the compiled-schedule machinery a corruption
    /// could hide in.
    pub fn forward_reference<S: Scalar>(&self, v: &TensorOf<S>) -> Result<TensorOf<S>> {
        let mut x = v.clone();
        for (layer, act) in self.layers.iter().zip(&self.activations) {
            x = act.forward(&layer.forward_per_term(&x)?);
        }
        Ok(x)
    }

    /// Forward through an explicit per-layer schedule list instead of each
    /// layer's own `Arc<LayerSchedule>` (fixed at construction). `schedules`
    /// must hold one forward schedule per layer, compiled for that layer's
    /// shape. Used by the integrity verifier to re-verify freshly
    /// recompiled schedules after a quarantine and by the brownout to walk
    /// shrunken-tile-budget schedules.
    pub fn forward_with_schedules<S: Scalar>(
        &self,
        schedules: &[std::sync::Arc<crate::fastmult::LayerSchedule>],
        v: &TensorOf<S>,
    ) -> Result<TensorOf<S>> {
        if schedules.len() != self.layers.len() {
            return Err(Error::ShapeMismatch {
                expected: format!("{} schedules (one per layer)", self.layers.len()),
                got: format!("{}", schedules.len()),
            });
        }
        let mut x = v.clone();
        for ((layer, act), schedule) in
            self.layers.iter().zip(&self.activations).zip(schedules)
        {
            x = act.forward(&layer.forward_one_with(schedule, &x)?);
        }
        Ok(x)
    }

    /// Batched forward over borrowed inputs: the batch is split into one
    /// contiguous span per worker thread; each span is packed once at the
    /// entry, walks **one schedule per layer**, keeps activations batched
    /// between layers and unpacks only at the exit. Output order matches
    /// input order.
    pub(crate) fn forward_refs_core<S: Scalar>(
        &self,
        inputs: &[&TensorOf<S>],
    ) -> Result<Vec<TensorOf<S>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if inputs.len() == 1 {
            // Single request: batching buys nothing, so keep the
            // DAG-subtree fan-out inside each layer (the B == 1 branch of
            // [`EquivariantLinear::apply`]'s refs path) for low-latency
            // serving.
            let mut xs = vec![inputs[0].clone()];
            for (layer, act) in self.layers.iter().zip(&self.activations) {
                let refs: Vec<&TensorOf<S>> = xs.iter().collect();
                let pre = layer.forward_refs_core(&refs)?;
                xs = pre.iter().map(|t| act.forward(t)).collect();
            }
            FUSED_BATCHES.fetch_add(1, Ordering::Relaxed);
            FUSED_ITEMS.fetch_add(1, Ordering::Relaxed);
            return Ok(xs);
        }
        // Each layer's bias tensor is materialised once per batch here and
        // shared read-only across the worker spans.
        let biases: Vec<Option<TensorOf<S>>> = self
            .layers
            .iter()
            .map(|l| l.batch_bias::<S>())
            .collect::<Result<Vec<_>>>()?;
        let spans: Vec<&[&TensorOf<S>]> = inputs.chunks(span_len(inputs.len())).collect();
        let span_outs = parallel_map(&spans, spans.len(), |span| -> Result<Vec<TensorOf<S>>> {
            let vb = BatchTensorOf::pack_refs(span)?;
            Ok(self.forward_batched_shared(&vb, &biases)?.unpack())
        });
        let mut out = Vec::with_capacity(inputs.len());
        for span in span_outs {
            out.extend(span?);
        }
        FUSED_BATCHES.fetch_add(1, Ordering::Relaxed);
        FUSED_ITEMS.fetch_add(inputs.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Fused forward over an already-packed batch: every layer walks its
    /// schedule once for the whole batch and activations stay batched
    /// between layers. The first layer reads `v` directly (no defensive
    /// copy of the input batch).
    pub(crate) fn forward_packed_core<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
    ) -> Result<BatchTensorOf<S>> {
        let biases: Vec<Option<TensorOf<S>>> = self
            .layers
            .iter()
            .map(|l| l.batch_bias::<S>())
            .collect::<Result<Vec<_>>>()?;
        self.forward_batched_shared(v, &biases)
    }

    /// [`EquivariantNet::forward_packed_core`] over pre-materialised
    /// per-layer bias tensors (one entry per layer), so span fan-outs build
    /// each bias once per batch.
    fn forward_batched_shared<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
        biases: &[Option<TensorOf<S>>],
    ) -> Result<BatchTensorOf<S>> {
        let mut x = self.layers[0].forward_batched_with_bias(v, biases[0].as_ref())?;
        self.activations[0].forward_batch_in_place(&mut x);
        for (i, (layer, act)) in self.layers.iter().zip(&self.activations).enumerate().skip(1) {
            x = layer.forward_batched_with_bias(&x, biases[i].as_ref())?;
            act.forward_batch_in_place(&mut x);
        }
        Ok(x)
    }

    /// Per-item batched inference for the serving path: one `Result` per
    /// input, in order. The fast uniform path handles the whole batch at
    /// once; if any item is malformed the batch falls back to per-item
    /// forwards (still parallel) so one bad request cannot fail its
    /// neighbours. Per-item failures are wrapped in [`Error::BatchItem`],
    /// so every error carries the index of the input that produced it.
    pub fn apply_results<S: Scalar>(&self, inputs: &[&TensorOf<S>]) -> Vec<Result<TensorOf<S>>> {
        let uniform = inputs
            .windows(2)
            .all(|w| w[0].order == w[1].order && w[0].n == w[1].n);
        if uniform {
            if let Ok(outs) = self.forward_refs_core(inputs) {
                return outs.into_iter().map(Ok).collect();
            }
        }
        let indexed: Vec<(usize, &TensorOf<S>)> = inputs.iter().copied().enumerate().collect();
        parallel_map(&indexed, max_threads(), |&(i, v)| {
            self.forward_one(v).map_err(|e| Error::BatchItem {
                index: i,
                source: Box::new(e),
            })
        })
    }

    /// Per-item batched inference. Use [`EquivariantNet::apply_results`]
    /// instead.
    #[deprecated(note = "use `apply_results` instead")]
    pub fn forward_batch_results<S: Scalar>(
        &self,
        inputs: &[&TensorOf<S>],
    ) -> Vec<Result<TensorOf<S>>> {
        self.apply_results(inputs)
    }

    /// Forward pass retaining intermediates for backprop, in whatever
    /// packaging the caller has: the returned [`NetTrace`] mirrors the
    /// input shape and pairs with [`EquivariantNet::apply_grad`] — the
    /// backward half of the unified API.
    pub fn apply_trace<'a, S: Scalar>(
        &self,
        input: impl Into<BatchInput<'a, S>>,
    ) -> Result<(NetTrace<S>, BatchOutput<S>)> {
        match input.into() {
            BatchInput::Single(v) => {
                let (trace, out) = self.forward_trace(v)?;
                Ok((NetTrace::Single(trace), BatchOutput::Single(out)))
            }
            BatchInput::Slice(vs) => {
                let traced = self.forward_trace_batch(vs)?;
                let (traces, outs) = traced.into_iter().unzip();
                Ok((NetTrace::Batch(traces), BatchOutput::Batch(outs)))
            }
            BatchInput::Refs(vs) => {
                let owned: Vec<TensorOf<S>> = vs.iter().map(|&v| v.clone()).collect();
                let traced = self.forward_trace_batch(&owned)?;
                let (traces, outs) = traced.into_iter().unzip();
                Ok((NetTrace::Batch(traces), BatchOutput::Batch(outs)))
            }
            BatchInput::Packed(vb) => {
                let (trace, out) = self.forward_trace_batched(vb)?;
                Ok((NetTrace::Packed(trace), BatchOutput::Packed(out)))
            }
        }
    }

    /// Backward half of the unified API: consumes a trace from
    /// [`EquivariantNet::apply_trace`] and an output gradient packaged
    /// like the traced input (`Single` with `Single`, `Slice` with
    /// `Batch`, `Packed` with `Packed`). Returns summed parameter
    /// gradients and the input gradient shaped like the input.
    pub fn apply_grad<'a, S: Scalar>(
        &self,
        trace: &NetTrace<S>,
        grad_out: impl Into<BatchInput<'a, S>>,
    ) -> Result<(NetGrads, BatchOutput<S>)> {
        match (trace, grad_out.into()) {
            (NetTrace::Single(trace), BatchInput::Single(g)) => {
                let (grads, gv) = self.backward(trace, g)?;
                Ok((grads, BatchOutput::Single(gv)))
            }
            (NetTrace::Batch(traces), BatchInput::Slice(gs)) => {
                let (grads, gvs) = self.backward_batch(traces, gs)?;
                Ok((grads, BatchOutput::Batch(gvs)))
            }
            (NetTrace::Packed(trace), BatchInput::Packed(g)) => {
                let (grads, gb) = self.backward_batched(trace, g)?;
                Ok((grads, BatchOutput::Packed(gb)))
            }
            (t, g) => Err(Error::ShapeMismatch {
                expected: format!("gradient packaged like the trace (`{}`)", t.kind()),
                got: format!("`{}`", g.kind()),
            }),
        }
    }

    /// Forward pass retaining intermediates for backprop: returns
    /// `(per-layer (input, pre-activation), output)`.
    #[allow(clippy::type_complexity)]
    pub fn forward_trace<S: Scalar>(
        &self,
        v: &TensorOf<S>,
    ) -> Result<(Vec<(TensorOf<S>, TensorOf<S>)>, TensorOf<S>)> {
        let mut trace = Vec::with_capacity(self.layers.len());
        let mut x = v.clone();
        for (layer, act) in self.layers.iter().zip(&self.activations) {
            let pre = layer.forward_one(&x)?;
            let post = act.forward(&pre);
            trace.push((x, pre));
            x = post;
        }
        Ok((trace, x))
    }

    /// Backward pass from `grad_out` (gradient at the network output) using
    /// a trace from [`EquivariantNet::forward_trace`]. Returns parameter
    /// gradients and the input gradient.
    pub fn backward<S: Scalar>(
        &self,
        trace: &[(TensorOf<S>, TensorOf<S>)],
        grad_out: &TensorOf<S>,
    ) -> Result<(NetGrads, TensorOf<S>)> {
        let mut grads = NetGrads {
            layers: self.layers.iter().map(|l| l.zero_grads()).collect(),
        };
        let mut g = grad_out.clone();
        for i in (0..self.layers.len()).rev() {
            let (input, pre) = &trace[i];
            g = self.activations[i].backward(pre, &g);
            g = self.layers[i].backward(input, &g, &mut grads.layers[i])?;
        }
        Ok((grads, g))
    }

    /// Batched [`EquivariantNet::forward_trace`]: traces for a whole batch,
    /// computed in parallel across items.
    #[allow(clippy::type_complexity)]
    pub fn forward_trace_batch<S: Scalar>(
        &self,
        inputs: &[TensorOf<S>],
    ) -> Result<Vec<(Vec<(TensorOf<S>, TensorOf<S>)>, TensorOf<S>)>> {
        let workers = max_threads().min(inputs.len());
        parallel_map(inputs, workers, |v| self.forward_trace(v))
            .into_iter()
            .collect()
    }

    /// Batched backward pass: one trace and output-gradient per batch item.
    /// Parameter gradients are **summed** over the batch (matching repeated
    /// [`EquivariantNet::backward`] + [`NetGrads::add`]); the per-item
    /// input gradients are returned in order. Parallel across items.
    #[allow(clippy::type_complexity)]
    pub fn backward_batch<S: Scalar>(
        &self,
        traces: &[Vec<(TensorOf<S>, TensorOf<S>)>],
        grad_outs: &[TensorOf<S>],
    ) -> Result<(NetGrads, Vec<TensorOf<S>>)> {
        if traces.len() != grad_outs.len() {
            return Err(Error::ShapeMismatch {
                expected: format!("{} output gradients", traces.len()),
                got: format!("{}", grad_outs.len()),
            });
        }
        let mut total = NetGrads {
            layers: self.layers.iter().map(|l| l.zero_grads()).collect(),
        };
        if traces.is_empty() {
            return Ok((total, Vec::new()));
        }
        let pairs: Vec<(&Vec<(TensorOf<S>, TensorOf<S>)>, &TensorOf<S>)> =
            traces.iter().zip(grad_outs).collect();
        let workers = max_threads().min(pairs.len());
        let per_item = parallel_map(&pairs, workers, |&(trace, g)| self.backward(trace, g));
        let mut grad_inputs = Vec::with_capacity(traces.len());
        for item in per_item {
            let (grads, gv) = item?;
            total.add(&grads);
            grad_inputs.push(gv);
        }
        Ok((total, grad_inputs))
    }

    /// Batched [`EquivariantNet::forward_trace`] over a packed batch:
    /// returns per-layer `(input batch, pre-activation batch)` pairs and
    /// the output batch, with **one schedule walk per layer per batch**.
    /// This is the training loop's forward: the whole minibatch flows
    /// through the network as `[B, n^k]` tensors.
    #[allow(clippy::type_complexity)]
    pub fn forward_trace_batched<S: Scalar>(
        &self,
        v: &BatchTensorOf<S>,
    ) -> Result<(Vec<(BatchTensorOf<S>, BatchTensorOf<S>)>, BatchTensorOf<S>)> {
        let mut trace = Vec::with_capacity(self.layers.len());
        let mut x = v.clone();
        for (layer, act) in self.layers.iter().zip(&self.activations) {
            let pre = layer.forward_packed_core(&x)?;
            let post = act.forward_batch(&pre);
            trace.push((x, pre));
            x = post;
        }
        Ok((trace, x))
    }

    /// Batched backward from a [`EquivariantNet::forward_trace_batched`]
    /// trace: one transposed-schedule walk per layer per batch, parameter
    /// gradients **summed** over the batch in a single reduction, and the
    /// input-gradient batch returned packed.
    pub fn backward_batched<S: Scalar>(
        &self,
        trace: &[(BatchTensorOf<S>, BatchTensorOf<S>)],
        grad_out: &BatchTensorOf<S>,
    ) -> Result<(NetGrads, BatchTensorOf<S>)> {
        let mut grads = NetGrads {
            layers: self.layers.iter().map(|l| l.zero_grads()).collect(),
        };
        // The last layer reads `grad_out` directly (activation backward
        // already copies), avoiding a defensive clone of the batch.
        let last = self.layers.len() - 1;
        let (input, pre) = &trace[last];
        let mut g = self.activations[last].backward_batch(pre, grad_out);
        g = self.layers[last].backward_batched(input, &g, &mut grads.layers[last])?;
        for i in (0..last).rev() {
            let (input, pre) = &trace[i];
            g = self.activations[i].backward_batch(pre, &g);
            g = self.layers[i].backward_batched(input, &g, &mut grads.layers[i])?;
        }
        Ok((grads, g))
    }

    /// Flatten parameters into one vector (for the optimisers).
    pub fn params_flat(&self) -> Vec<f64> {
        let mut p = Vec::new();
        for l in &self.layers {
            p.extend_from_slice(&l.coeffs);
            p.extend_from_slice(&l.bias_coeffs);
        }
        p
    }

    /// Write a flat parameter vector back into the layers.
    pub fn set_params_flat(&mut self, flat: &[f64]) {
        let mut off = 0usize;
        for l in &mut self.layers {
            let nc = l.coeffs.len();
            l.coeffs.copy_from_slice(&flat[off..off + nc]);
            off += nc;
            let nb = l.bias_coeffs.len();
            l.bias_coeffs.copy_from_slice(&flat[off..off + nb]);
            off += nb;
        }
        debug_assert_eq!(off, flat.len());
    }

    /// Flatten gradients to match [`EquivariantNet::params_flat`].
    pub fn grads_flat(&self, grads: &NetGrads) -> Vec<f64> {
        let mut g = Vec::new();
        for lg in &grads.layers {
            g.extend_from_slice(&lg.coeffs);
            g.extend_from_slice(&lg.bias_coeffs);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    // The legacy forward names stay exercised until their removal.
    #![allow(deprecated)]
    use super::*;
    use crate::groups;
    use crate::nn::loss::Loss;
    use crate::tensor::Tensor;

    #[test]
    fn network_shapes() {
        let mut rng = Rng::new(201);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 1, 0],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let out = net.forward(&v).unwrap();
        assert_eq!(out.order, 0);
        assert!(net.num_params() > 0);
    }

    #[test]
    fn network_equivariance_with_relu_sn() {
        // ReLU is pointwise, hence S_n-equivariant; the whole net must be.
        let mut rng = Rng::new(202);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let g = groups::sample(Group::Symmetric, 3, &mut rng).unwrap();
        let lhs = net.forward(&groups::rho(&g, &v)).unwrap();
        let rhs = groups::rho(&g, &net.forward(&v).unwrap());
        assert!(lhs.allclose(&rhs, 1e-8), "diff {}", lhs.max_abs_diff(&rhs));
    }

    #[test]
    fn network_invariance_to_scalar_output() {
        // orders ending in 0 give an S_n-invariant scalar.
        let mut rng = Rng::new(203);
        let net = EquivariantNet::new(
            Group::Symmetric,
            4,
            &[2, 1, 0],
            Activation::Tanh,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(4, 2, &mut rng);
        let g = groups::sample(Group::Symmetric, 4, &mut rng).unwrap();
        let a = net.forward(&v).unwrap();
        let b = net.forward(&groups::rho(&g, &v)).unwrap();
        assert!((a.data[0] - b.data[0]).abs() < 1e-8);
    }

    #[test]
    fn full_network_gradient_check() {
        let mut rng = Rng::new(204);
        let net = EquivariantNet::new(
            Group::Symmetric,
            2,
            &[2, 1, 0],
            Activation::Tanh,
            Init::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(2, 2, &mut rng);
        let target = Tensor::from_vec(2, 0, vec![0.7]).unwrap();
        let (trace, out) = net.forward_trace(&v).unwrap();
        let gout = Loss::Mse.grad(&out, &target);
        let (grads, _) = net.backward(&trace, &gout).unwrap();
        let flat_g = net.grads_flat(&grads);
        let flat_p = net.params_flat();
        let eps = 1e-6;
        for i in 0..flat_p.len() {
            let mut pp = flat_p.clone();
            pp[i] += eps;
            let mut netp = net.clone();
            netp.set_params_flat(&pp);
            let lp = Loss::Mse.value(&netp.forward(&v).unwrap(), &target);
            let mut pm = flat_p.clone();
            pm[i] -= eps;
            let mut netm = net.clone();
            netm.set_params_flat(&pm);
            let lm = Loss::Mse.value(&netm.forward(&v).unwrap(), &target);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - flat_g[i]).abs() < 1e-5,
                "param {i}: fd {fd} vs {}",
                flat_g[i]
            );
        }
    }

    #[test]
    fn forward_batch_matches_per_item() {
        let mut rng = Rng::new(206);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 1],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..9).map(|_| Tensor::random(3, 2, &mut rng)).collect();
        let batched = net.forward_batch(&inputs).unwrap();
        assert_eq!(batched.len(), 9);
        for (v, b) in inputs.iter().zip(&batched) {
            let want = net.forward(v).unwrap();
            assert!(want.allclose(b, 1e-9), "diff {}", want.max_abs_diff(b));
        }
        assert!(net.forward_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn forward_batch_results_isolates_bad_items() {
        let mut rng = Rng::new(207);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let good = Tensor::random(3, 2, &mut rng);
        let bad = Tensor::zeros(3, 1); // wrong order
        let results = net.apply_results(&[&good, &bad, &good]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        let want = net.forward(&good).unwrap();
        assert!(results[0].as_ref().unwrap().allclose(&want, 1e-9));
        // The per-item error carries the index of the failing input.
        let msg = results[1].as_ref().unwrap_err().to_string();
        assert!(msg.starts_with("batch item 1:"), "got: {msg}");
        // The deprecated name routes through the same path.
        let legacy = net.forward_batch_results(&[&good, &bad]);
        assert!(legacy[1]
            .as_ref()
            .unwrap_err()
            .to_string()
            .starts_with("batch item 1:"));
    }

    #[test]
    fn backward_batch_matches_sequential() {
        let mut rng = Rng::new(208);
        let net = EquivariantNet::new(
            Group::Symmetric,
            2,
            &[2, 1, 0],
            Activation::Tanh,
            Init::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..6).map(|_| Tensor::random(2, 2, &mut rng)).collect();
        let traced = net.forward_trace_batch(&inputs).unwrap();
        let gouts: Vec<Tensor> = traced
            .iter()
            .map(|(_, out)| out.clone()) // dL/dout = out for L = ||out||²/2
            .collect();
        // Sequential reference.
        let mut want = NetGrads {
            layers: net.layers.iter().map(|l| l.zero_grads()).collect(),
        };
        let mut want_gv = Vec::new();
        for (v, g) in inputs.iter().zip(&gouts) {
            let (trace, _) = net.forward_trace(v).unwrap();
            let (grads, gv) = net.backward(&trace, g).unwrap();
            want.add(&grads);
            want_gv.push(gv);
        }
        // Batched.
        let traces: Vec<Vec<(Tensor, Tensor)>> =
            traced.into_iter().map(|(trace, _)| trace).collect();
        let (got, got_gv) = net.backward_batch(&traces, &gouts).unwrap();
        for (lw, lg) in want.layers.iter().zip(&got.layers) {
            for (a, b) in lw.coeffs.iter().zip(&lg.coeffs) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
            for (a, b) in lw.bias_coeffs.iter().zip(&lg.bias_coeffs) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
        for (a, b) in want_gv.iter().zip(&got_gv) {
            assert!(a.allclose(b, 1e-9));
        }
        // Length mismatch is rejected.
        assert!(net.backward_batch(&traces, &gouts[..2]).is_err());
    }

    #[test]
    fn apply_matches_legacy_entry_points() {
        use crate::tensor::BatchTensor;
        let mut rng = Rng::new(209);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 1],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..5).map(|_| Tensor::random(3, 2, &mut rng)).collect();
        let single = net.apply(&inputs[0]).unwrap().into_single().unwrap();
        assert!(single.allclose(&net.forward(&inputs[0]).unwrap(), 0.0));
        let legacy = net.forward_batch(&inputs).unwrap();
        let got = net.apply(inputs.as_slice()).unwrap().into_vec();
        for (a, b) in got.iter().zip(&legacy) {
            assert!(a.allclose(b, 0.0));
        }
        let packed = BatchTensor::pack(&inputs).unwrap();
        let got_packed = net.apply(&packed).unwrap().into_packed().unwrap();
        assert_eq!(
            got_packed.max_abs_diff(&net.forward_batched(&packed).unwrap()),
            0.0
        );
    }

    #[test]
    fn apply_trace_and_grad_match_legacy_backward() {
        let mut rng = Rng::new(210);
        let net = EquivariantNet::new(
            Group::Symmetric,
            2,
            &[2, 1, 0],
            Activation::Tanh,
            Init::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(2, 2, &mut rng);
        let (trace, out) = net.apply_trace(&v).unwrap();
        let out = out.into_single().unwrap();
        assert!(out.allclose(&net.forward(&v).unwrap(), 0.0));
        let (grads, gv) = net.apply_grad(&trace, &out).unwrap();
        let gv = gv.into_single().unwrap();
        let (want_trace, _) = net.forward_trace(&v).unwrap();
        let (want_grads, want_gv) = net.backward(&want_trace, &out).unwrap();
        assert!(gv.allclose(&want_gv, 0.0));
        assert_eq!(net.grads_flat(&grads), net.grads_flat(&want_grads));
        // Mismatched trace/gradient packagings are rejected.
        let gs = vec![out];
        assert!(net.apply_grad(&trace, gs.as_slice()).is_err());
    }

    #[test]
    fn f32_net_tracks_f64_within_tolerance() {
        use crate::tensor::{Scalar, TensorOf};
        let mut rng = Rng::new(211);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 1],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let want = net.apply(&v).unwrap().into_single().unwrap();
        let v32: TensorOf<f32> = v.cast();
        let got = net.apply(&v32).unwrap().into_single().unwrap();
        let scale = want.data.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
        let tol = 64.0 * <f32 as Scalar>::TOLERANCE * scale;
        assert!(
            got.cast::<f64>().allclose(&want, tol),
            "f32 net diverges by {}",
            got.cast::<f64>().max_abs_diff(&want)
        );
    }

    #[test]
    fn reference_and_explicit_schedule_forwards_match_apply() {
        use crate::fastmult::{LayerSchedule, PlanCache};
        use crate::layer::spanning_plans;
        use std::sync::Arc;
        let mut rng = Rng::new(212);
        let net = EquivariantNet::new(
            Group::Symmetric,
            3,
            &[2, 2, 1],
            Activation::Relu,
            Init::ScaledNormal,
            &mut rng,
        )
        .unwrap();
        let v = Tensor::random(3, 2, &mut rng);
        let want = net.apply(&v).unwrap().into_single().unwrap();
        // The per-term oracle agrees to rounding error.
        let got = net.forward_reference(&v).unwrap();
        assert!(got.allclose(&want, 1e-12), "diff {}", got.max_abs_diff(&want));
        // Freshly compiled schedules (same shapes, explicit budget) agree
        // to rounding error too.
        let schedules: Vec<Arc<LayerSchedule>> = net
            .layers
            .iter()
            .map(|layer| {
                let plans =
                    spanning_plans(net.group(), net.n(), layer.k(), layer.l()).unwrap();
                PlanCache::global()
                    .get_or_build_schedule_budgeted(
                        net.group(),
                        net.n(),
                        layer.k(),
                        layer.l(),
                        false,
                        &plans,
                        0,
                    )
                    .unwrap()
            })
            .collect();
        let got = net.forward_with_schedules(&schedules, &v).unwrap();
        assert!(got.allclose(&want, 1e-12), "diff {}", got.max_abs_diff(&want));
        // Wrong schedule count is rejected.
        assert!(net.forward_with_schedules(&schedules[..1], &v).is_err());
    }

    #[test]
    fn params_flat_roundtrip() {
        let mut rng = Rng::new(205);
        let mut net = EquivariantNet::new(
            Group::Orthogonal,
            3,
            &[2, 2],
            Activation::Identity,
            Init::Normal(1.0),
            &mut rng,
        )
        .unwrap();
        let p = net.params_flat();
        let mut q = p.clone();
        for x in &mut q {
            *x += 1.0;
        }
        net.set_params_flat(&q);
        assert_eq!(net.params_flat(), q);
    }
}
